"""Mixture-of-Experts FFN layer for the Table 2 models (~8.5M MoE).

Small-scale, dense-dispatch MoE: every expert computes on every token and a
top-k routing mask weights the combination. At the paper's MoE scale
(hidden 128, a handful of experts) dense dispatch is both simpler and
faster under XLA-CPU than gather/scatter dispatch, and it is numerically
identical to sparse dispatch for the same router.

Includes the standard load-balancing auxiliary loss (Switch-style):
    aux = n_experts * sum_e( frac_tokens_e * mean_router_prob_e )
which is 1.0 under perfect balance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int):
    """Router + per-expert SwiGLU stacks (experts batched on axis 0)."""
    kr, kg, ku, kd = jax.random.split(key, 4)

    def init(k, *shape):
        fan_in, fan_out = shape[-2], shape[-1]
        std = (2.0 / (fan_in + fan_out)) ** 0.5
        return std * jax.random.normal(k, shape, jnp.float32)

    return {
        "router": init(kr, d_model, n_experts),
        "w_gate": init(kg, n_experts, d_model, d_ff),
        "w_up": init(ku, n_experts, d_model, d_ff),
        "w_down": init(kd, n_experts, d_ff, d_model),
    }


def moe_layer(params, x: jnp.ndarray, top_k: int = 1):
    """x: [batch, seq, d_model] -> (out, aux_loss).

    Routing: softmax over experts, keep top-k, renormalize kept weights.
    """
    n_experts = params["router"].shape[1]
    logits = x @ params["router"]  # [b, s, E]
    probs = jax.nn.softmax(logits, axis=-1)

    if top_k >= n_experts:
        weights = probs
    else:
        # k-th largest via iterated masked max — avoids jnp.sort, whose
        # batched-gather lowering the image's xla_client converter rejects
        # (GatherDimensionNumbers.operand_batching_dims is post-0.5.1).
        masked = probs
        for _ in range(top_k - 1):
            top = jnp.max(masked, axis=-1, keepdims=True)
            masked = jnp.where(masked >= top, -jnp.inf, masked)
        kth = jnp.max(masked, axis=-1, keepdims=True)
        keep = probs >= kth
        weights = jnp.where(keep, probs, 0.0)
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)

    # Dense dispatch: expert e output for all tokens, shape [E, b, s, d].
    def expert(wg, wu, wd):
        return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd

    expert_out = jax.vmap(expert)(params["w_gate"], params["w_up"], params["w_down"])
    out = jnp.einsum("ebsd,bse->bsd", expert_out, weights)

    # Load-balancing aux loss over the *kept* assignment distribution.
    frac_tokens = jnp.mean((weights > 0).astype(jnp.float32), axis=(0, 1))  # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))  # [E]
    aux = n_experts * jnp.sum(frac_tokens * mean_prob) / max(top_k, 1)
    return out, aux
