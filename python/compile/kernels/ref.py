"""Pure-jnp reference attention — the correctness oracle for the Pallas kernels.

Implements the unified attention family of the SQA paper (§3.2): the input
is projected into ``Hq`` query heads and ``Hkv`` key/value heads; K/V heads
are repeated ``G = Hq // Hkv`` times (eq. 7's ``K'``/``V'``) and scaled
dot-product attention runs over the ``Hq`` heads. Every named variant
(MHA, GQA, MQA, SQA, sSQA, xSQA, xSMQA) is a point in (Hq, Hkv) space;
sliding-window attention (SWA / SW-SQA) adds a banded mask.

This file must stay dependency-light and obviously-correct: it is the
oracle that both the Pallas kernel (pytest) and the Rust native
implementation (golden files) are validated against.
"""

from __future__ import annotations

import jax.numpy as jnp


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """Repeat K/V heads along the head axis (GQA-style broadcast).

    x: [batch, Hkv, seq, d_head] -> [batch, Hkv * n_rep, seq, d_head]

    Head ``h`` of the output reads from input head ``h // n_rep``.
    """
    if n_rep == 1:
        return x
    b, hkv, s, d = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, :], (b, hkv, n_rep, s, d))
    return x.reshape(b, hkv * n_rep, s, d)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    window: int | None = None,
) -> jnp.ndarray:
    """Reference scaled-dot-product attention with K/V head repetition.

    q: [batch, Hq,  Sq, d_head]
    k: [batch, Hkv, Sk, d_head]
    v: [batch, Hkv, Sk, d_head]
    window: if set, token i attends only to j with i - window < j <= i
        (causal sliding window, the SWA/SW-SQA pattern of §2.5/§3.4).
    returns: [batch, Hq, Sq, d_head]
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, f"Hq={hq} must be a multiple of Hkv={hkv}"
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale

    mask = None
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    if causal or window is not None:
        # When Sq != Sk align the last query with the last key (decode-style).
        offset = sk - sq
        rel = (qi + offset) - kj  # >= 0 means key is at/before query
        if causal:
            mask = rel >= 0
        if window is not None:
            w = (rel >= 0) & (rel < window)
            mask = w if mask is None else (mask & w)
    if mask is not None:
        scores = jnp.where(mask[None, None, :, :], scores, jnp.finfo(scores.dtype).min)

    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def sqa_layer_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    hq: int,
    hkv: int,
    *,
    causal: bool = False,
    window: int | None = None,
) -> jnp.ndarray:
    """Full SQA layer (paper eqs. 4-8): project, attend over Hq heads, merge.

    x:  [batch, seq, d_model]
    wq: [d_model, hq * d_head]     wk/wv: [d_model, hkv * d_head]
    wo: [hq * d_head, d_model]
    """
    b, s, _ = x.shape
    dh = wq.shape[1] // hq
    q = (x @ wq).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    o = attention_ref(q, k, v, causal=causal, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return o @ wo


def attention_flops(
    batch: int, hq: int, sq: int, sk: int, d_head: int, window: int | None = None
) -> int:
    """Analytic FLOPs of the attention core (scores + aggregation), §3.2.1.

    Two matmuls of [Sq, d] x [d, Sk] per head -> 2 * 2 * Sq * Sk * d each.
    A sliding window limits Sk to min(Sk, window) per query row.
    """
    eff_k = sk if window is None else min(sk, window)
    return batch * hq * (2 * 2 * sq * eff_k * d_head)
