"""Pallas SQA kernel: tiled flash-attention with query-head reduction.

The SQA paper's contribution is *structural*: the attention core runs over
``Hq < H`` query heads, cutting score/aggregation FLOPs by ``H/Hq`` (§3.2.1).
In this kernel that shows up directly in the grid: the head axis has ``Hq``
entries, so the number of MXU tile-matmuls launched falls by the same factor.

Design (TPU-shaped, executed with ``interpret=True`` on CPU PJRT):

* Grid ``(batch, Hq, num_q_blocks, num_k_blocks)`` — K-blocks innermost so a
  query tile's online-softmax state lives in VMEM scratch across K steps.
* BlockSpecs stage ``(block_q, d_head)`` Q tiles and ``(block_k, d_head)``
  K/V tiles HBM->VMEM; the N x N score matrix never materializes.
* GQA-style K/V sharing is an *index map*: query head ``h`` reads K/V head
  ``h * Hkv // Hq`` — zero-copy, no repeated tensors (paper eq. 7's K'/V'
  broadcast is free).
* Online softmax: running row-max ``m``, normalizer ``l`` and un-normalized
  accumulator ``acc`` carried in scratch; output written on the last K step.
* Causal and sliding-window (SWA / SW-SQA, §3.4) masks are computed from
  grid coordinates per tile.

VMEM footprint per grid cell (f32):
    q tile  block_q * d_head
    k,v     2 * block_k * d_head
    scratch block_q * (d_head + 2)
which is independent of sequence length — the property FlashAttention gets
from SRAM tiling and we get from BlockSpecs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = float("-inf")


def _pick_block(n: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that divides n."""
    b = min(preferred, n)
    while b > 1 and n % b != 0:
        b //= 2
    return max(b, 1)


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    """One (batch, head, q-block, k-block) grid cell."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    # --- reset the online-softmax state at the first K block -------------
    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :]  # [block_q, d]
    k = k_ref[0, 0, :, :]  # [block_k, d]
    v = v_ref[0, 0, :, :]  # [block_k, d]

    # MXU tile-matmul: scores for this (q-block, k-block) pair.
    s = jax.lax.dot_general(
        q,
        k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * scale  # [block_q, block_k]

    # --- banded masking from global coordinates --------------------------
    if causal or window is not None:
        rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        rel = rows - cols
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (rel >= 0)
        if window is not None:
            mask = mask & (rel >= 0) & (rel < window)
        s = jnp.where(mask, s, NEG_INF)

    # --- online softmax update -------------------------------------------
    m_prev = m_ref[...]  # [block_q]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # A fully-masked row keeps m = -inf; guard exp(-inf - -inf) -> use 0.
    safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - safe_m))
    p = jnp.exp(s - safe_m[:, None])
    p = jnp.where(jnp.isneginf(s), 0.0, p)

    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype),
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    # --- finalize on the last K block -------------------------------------
    @pl.when(ik == num_k_blocks - 1)
    def _final():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def sqa_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled SQA attention core.

    q: [batch, Hq,  S, d_head]; k, v: [batch, Hkv, S, d_head], Hkv | Hq.
    Returns [batch, Hq, S, d_head]. Matches ``ref.attention_ref``.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} must be a multiple of Hkv={hkv}")
    if window is not None and window <= 0:
        raise ValueError(f"window must be positive, got {window}")

    bq = _pick_block(sq, block_q)
    bk = _pick_block(sk, block_k)
    nq = sq // bq
    nk = sk // bk
    group = hq // hkv  # query heads per kv head

    kernel = functools.partial(
        _attn_kernel,
        scale=1.0 / math.sqrt(d),
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        num_k_blocks=nk,
    )

    grid = (b, hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            # SQA/GQA head sharing as an index map: query head ih reads
            # kv head ih // group. This is where the repeated-K' of paper
            # eq. (7) becomes zero-copy.
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),  # running max m
            pltpu.VMEM((bq,), jnp.float32),  # normalizer l
        ],
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(block_q: int, block_k: int, d_head: int, dtype_bytes: int = 4) -> int:
    """Per-grid-cell VMEM bytes for the BlockSpecs above (perf model, §7)."""
    q_tile = block_q * d_head
    kv_tiles = 2 * block_k * d_head
    scratch = block_q * d_head + 2 * block_q
    out = block_q * d_head
    return dtype_bytes * (q_tile + kv_tiles + scratch + out)


def mxu_tile_matmuls(batch: int, hq: int, seq: int, block_q: int, block_k: int) -> int:
    """Number of (block_q x d)@(d x block_k) tile matmuls the grid launches.

    Proportional to Hq — the paper's H/Hq FLOP reduction, visible in the
    launch geometry itself.
    """
    return batch * hq * (seq // block_q) * (seq // block_k) * 2  # QK^T and PV
