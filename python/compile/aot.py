"""AOT pipeline: lower every (family, variant, kind) to HLO text + manifest.

Build-time only — Python never runs on the Rust request path. For each model
family and attention variant this emits:

  <family>_<variant>[_<impl>]_init.hlo.txt
      (seed i32[]) -> flat_params f32[P]
  <family>_<variant>[_<impl>]_train.hlo.txt
      (state f32[3P+2], step i32[], lr f32[], tokens i32[B,S],
       targets i32[B,S]) -> state' f32[3P+2]
      where state = concat(params, adam_m, adam_v, [loss, acc])
  <family>_<variant>[_<impl>]_eval.hlo.txt
      (flat_p f32[P], tokens, targets) -> f32[2]  (loss, acc)
  <family>_<variant>[_<impl>]_fwd_b<B>_s<S>.hlo.txt
      (flat_p, tokens) -> logits f32[B,S,V]

**Every artifact takes and returns plain arrays — never tuples.** The PJRT
C-API wrapper in this image flattens tuple *parameters* into per-leaf
buffers but returns tuple *results* as one opaque tuple buffer, so a tuple
output could never be fed back as an input. Fusing the whole AdamW state
(params, moments, last-step loss/acc) into a single f32 vector keeps
training state fully device-resident: Rust feeds the output buffer of step
N directly into step N+1 and reads back only a 2-float metrics slice (via
an XlaBuilder-built slicer, see rust/src/runtime/client.rs).
`manifest.json` records each parameter's (name, shape, offset) within the
flat params vector.

Interchange format is **HLO text** (not serialized HloModuleProto): jax ≥0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly.

Incremental: existing .hlo.txt files are skipped unless --force; the
manifest is always rewritten (derived, fast, must stay in sync).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs
from .model import ModelConfig, OptConfig, forward, init_params, loss_and_acc, train_step

jax.config.update("jax_platform_name", "cpu")

# Training batch geometry per family (CPU-scaled; see DESIGN.md §3).
TRAIN_GEOM = {  # family -> (batch, seq)
    "tiny": (8, 128),
    "dense_sm": (4, 256),
    "moe_sm": (8, 256),
}
FWD_GEOM = {  # family -> (batch, [seqs])
    "tiny": (8, configs.TINY_SEQS),
    "bench": (1, configs.BENCH_SEQS),
}
# Pallas-kernel-impl artifacts (the kernel path must compose end-to-end).
PALLAS_FWD = [("bench", "sqa", 1024), ("bench", "mha", 1024)]


def dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every artifact returns exactly one array, so the
    # HLO root is that array — its output buffer feeds the next execution
    # directly (PJRT tuple outputs are opaque to this wrapper; see module
    # docstring).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _prod(shape):
    r = 1
    for s in shape:
        r *= s
    return r


class Packer:
    """Pack/unpack a parameter pytree to/from one flat f32 vector."""

    def __init__(self, cfg: ModelConfig):
        shapes = jax.eval_shape(
            lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32)
        )
        self.leaves, self.treedef = jax.tree_util.tree_flatten(shapes)
        named = jax.tree_util.tree_flatten_with_path(shapes)[0]
        self.specs = []
        offset = 0
        for path, leaf in named:
            name = (
                jax.tree_util.keystr(path)
                .replace("'", "")
                .strip("[]")
                .replace("][", ".")
            )
            size = _prod(leaf.shape)
            self.specs.append(
                {
                    "name": name,
                    "shape": list(leaf.shape),
                    "dtype": dtype_str(leaf.dtype),
                    "offset": offset,
                }
            )
            offset += size
        self.total = offset

    def pack(self, tree):
        return jnp.concatenate(
            [jnp.ravel(x) for x in jax.tree_util.tree_leaves(tree)]
        )

    def unpack(self, vec):
        parts = []
        for spec, leaf in zip(self.specs, self.leaves):
            o, n = spec["offset"], _prod(spec["shape"])
            parts.append(vec[o : o + n].reshape(leaf.shape))
        return jax.tree_util.tree_unflatten(self.treedef, parts)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Emitter:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.artifacts = []
        self.families: dict[str, dict] = {}

    def family_entry(self, cfg: ModelConfig, variant: str, packer: Packer):
        fam = self.families.setdefault(
            cfg.name,
            {
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "h_total": cfg.h_total,
                "d_head": cfg.d_head,
                "d_ff": cfg.ff_dim(),
                "n_experts": cfg.n_experts,
                "moe_top_k": cfg.moe_top_k,
                "causal": cfg.causal,
                "variants": {},
            },
        )
        if variant not in fam["variants"]:
            fam["variants"][variant] = {
                "hq": cfg.spec.hq,
                "hkv": cfg.spec.hkv,
                "window": cfg.spec.window,
                "n_params": packer.total,
                "params": packer.specs,
            }
        return fam

    def emit(self, cfg, variant, kind, fn, in_specs, packer, entry_extra):
        impl_tag = f"_{cfg.attn_impl}" if cfg.attn_impl != "xla" else ""
        stem = f"{cfg.name}_{variant}{impl_tag}_{kind}"
        if kind == "fwd":
            stem += f"_b{entry_extra['batch']}_s{entry_extra['seq']}"
        path = os.path.join(self.out_dir, stem + ".hlo.txt")
        self.family_entry(cfg, variant, packer)

        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        out_shapes = [
            {"shape": list(o.shape), "dtype": dtype_str(o.dtype)}
            for o in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        if self.force or not os.path.exists(path):
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            status = f"wrote {len(text) // 1024}KiB"
        else:
            status = "kept"
        self.artifacts.append(
            {
                "family": cfg.name,
                "variant": variant,
                "impl": cfg.attn_impl,
                "kind": kind,
                "path": os.path.basename(path),
                "inputs": [
                    {"shape": list(s.shape), "dtype": dtype_str(s.dtype)}
                    for s in in_specs
                ],
                "outputs": out_shapes,
                **entry_extra,
            }
        )
        print(f"  [{time.time() - t0:6.1f}s] {stem}: {status}", flush=True)


def emit_model(em, cfg, variant, kinds, train_geom=None, fwd_geom=None):
    packer = Packer(cfg)
    pvec = sds((packer.total,))
    opt = OptConfig()

    p = packer.total
    state_len = 3 * p + 2
    svec = sds((state_len,))

    if "init" in kinds:

        def init_fn(seed):
            return packer.pack(init_params(cfg, jax.random.PRNGKey(seed)))

        em.emit(cfg, variant, "init", init_fn, [sds((), jnp.int32)], packer, {})

    if "train" in kinds:
        b, s = train_geom

        def train_fn(state, step, lr, tokens, targets):
            p2, m2, v2, loss, acc = train_step(
                packer.unpack(state[0:p]),
                packer.unpack(state[p : 2 * p]),
                packer.unpack(state[2 * p : 3 * p]),
                step,
                lr,
                cfg,
                opt,
                tokens,
                targets,
            )
            return jnp.concatenate(
                [
                    packer.pack(p2),
                    packer.pack(m2),
                    packer.pack(v2),
                    jnp.stack([loss, acc]),
                ]
            )

        in_specs = [
            svec,
            sds((), jnp.int32),
            sds((), jnp.float32),
            sds((b, s), jnp.int32),
            sds((b, s), jnp.int32),
        ]
        em.emit(cfg, variant, "train", train_fn, in_specs, packer, {"batch": b, "seq": s})

    if "eval" in kinds:
        b, s = train_geom

        def eval_fn(fp, tokens, targets):
            loss, acc = loss_and_acc(packer.unpack(fp), cfg, tokens, targets)
            return jnp.stack([loss, acc])

        in_specs = [pvec, sds((b, s), jnp.int32), sds((b, s), jnp.int32)]
        em.emit(cfg, variant, "eval", eval_fn, in_specs, packer, {"batch": b, "seq": s})

    if "fwd" in kinds:
        b, seqs = fwd_geom
        for s in seqs:

            def fwd_fn(fp, tokens):
                return forward(packer.unpack(fp), cfg, tokens)

            in_specs = [pvec, sds((b, s), jnp.int32)]
            em.emit(cfg, variant, "fwd", fwd_fn, in_specs, packer, {"batch": b, "seq": s})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--only",
        default="",
        help="comma-separated family filter (tiny,dense_sm,moe_sm,bench)",
    )
    ap.add_argument(
        "--max-seq", type=int, default=0, help="cap fwd sequence buckets (0 = all)"
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out_dir, exist_ok=True)
    em = Emitter(args.out_dir, args.force)

    def want(fam):
        return only is None or fam in only

    t0 = time.time()

    if want("tiny"):
        print("family tiny", flush=True)
        for variant in ["mha", "sqa", "ssqa", "xsqa"]:
            emit_model(
                em,
                configs.tiny(variant),
                variant,
                {"init", "train", "eval", "fwd"},
                train_geom=TRAIN_GEOM["tiny"],
                fwd_geom=FWD_GEOM["tiny"],
            )
        # The Pallas-kernel path must compose through fwd+bwd (tiny scale).
        emit_model(
            em,
            configs.tiny("sqa", attn_impl="pallas"),
            "sqa",
            {"train", "init"},
            train_geom=(2, 128),
        )

    if want("dense_sm"):
        print("family dense_sm (Table 1)", flush=True)
        for variant in configs.TABLE1_VARIANTS:
            emit_model(
                em,
                configs.dense_sm(variant),
                variant,
                {"init", "train", "eval"},
                train_geom=TRAIN_GEOM["dense_sm"],
            )

    if want("moe_sm"):
        print("family moe_sm (Table 2)", flush=True)
        for variant in configs.TABLE2_VARIANTS:
            emit_model(
                em,
                configs.moe_sm(variant),
                variant,
                {"init", "train", "eval"},
                train_geom=TRAIN_GEOM["moe_sm"],
            )

    if want("bench"):
        print("family bench (Table 3)", flush=True)
        b, seqs = FWD_GEOM["bench"]
        if args.max_seq:
            seqs = [s for s in seqs if s <= args.max_seq]
        for variant in configs.TABLE3_VARIANTS:
            emit_model(
                em,
                configs.bench(variant),
                variant,
                {"init", "fwd"},
                fwd_geom=(b, seqs),
            )
        for _, variant, seq in PALLAS_FWD:
            emit_model(
                em,
                configs.bench(variant, attn_impl="pallas"),
                variant,
                {"fwd"},
                fwd_geom=(b, [seq]),
            )

    manifest = {
        "version": 2,
        "generated_by": "compile.aot",
        "families": em.families,
        "artifacts": em.artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"done: {len(em.artifacts)} artifacts in {time.time() - t0:.0f}s -> {args.out_dir}",
        flush=True,
    )


if __name__ == "__main__":
    main()
