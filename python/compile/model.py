"""L2 model: transformer language model with a pluggable attention variant.

Matches the paper's experimental architectures:
  * dense (Table 1): RMSNorm pre-norm blocks, SQA-family attention, SwiGLU
    MLP, RoPE positions, tied embedding/LM head.
  * MoE  (Table 2): same skeleton with the MLP swapped for a top-1 routed
    mixture of experts (see `moe.py`).

Everything is pure-functional: parameters are a nested dict pytree whose
flattening order (sorted keys, `jax.tree_util`) is the contract with the
Rust runtime — `aot.py` records the order in `manifest.json`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .attention import (
    AttentionSpec,
    attention_layer,
    init_attention_params,
    rope_tables,
)
from . import moe as moe_mod


@dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters for one model (one row of Table 1/2)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    h_total: int  # H of the MHA baseline; d_head = d_model / H
    spec: AttentionSpec
    d_ff: int = 0  # defaults to ~8/3 * d_model rounded to 32
    causal: bool = True
    attn_impl: str = "xla"  # "xla" | "pallas"
    # MoE (Table 2): n_experts == 0 means dense SwiGLU MLP.
    n_experts: int = 0
    moe_top_k: int = 1

    @property
    def d_head(self) -> int:
        assert self.d_model % self.h_total == 0
        return self.d_model // self.h_total

    def ff_dim(self) -> int:
        if self.d_ff:
            return self.d_ff
        return ((8 * self.d_model // 3) + 31) // 32 * 32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_linear(key, fan_in, fan_out):
    std = (2.0 / (fan_in + fan_out)) ** 0.5
    return std * jax.random.normal(key, (fan_in, fan_out), jnp.float32)


def init_params(cfg: ModelConfig, key) -> dict:
    """Initialize the full parameter pytree from a PRNG key.

    Per-layer parameters are **stacked on a leading layer axis** so the
    forward pass can `lax.scan` over depth — one compiled block body
    instead of `n_layers` unrolled copies (≈8x faster XLA compiles for the
    dense_sm family; see EXPERIMENTS.md §Perf).
    """
    keys = jax.random.split(key, cfg.n_layers + 2)
    ff = cfg.ff_dim()

    def layer_init(k):
        lk = jax.random.split(k, 6)
        layer = {
            "attn": init_attention_params(lk[0], cfg.d_model, cfg.d_head, cfg.spec),
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if cfg.n_experts:
            layer["moe"] = moe_mod.init_moe_params(
                lk[1], cfg.d_model, ff, cfg.n_experts
            )
        else:
            layer["mlp"] = {
                "w_gate": _init_linear(lk[1], cfg.d_model, ff),
                "w_up": _init_linear(lk[2], cfg.d_model, ff),
                "w_down": _init_linear(lk[3], ff, cfg.d_model),
            }
        return layer

    # vmap over the layer keys: one compiled init body for all layers
    # (matches the scan-over-depth forward; EXPERIMENTS.md §Perf iter 3).
    blocks = jax.vmap(layer_init)(keys[: cfg.n_layers])
    return {
        "embed": 0.02 * jax.random.normal(keys[-2], (cfg.vocab, cfg.d_model), jnp.float32),
        "blocks": blocks,
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
    }


def param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def swiglu(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def forward_with_aux(params, cfg: ModelConfig, tokens: jnp.ndarray):
    """tokens: [batch, seq] int32 -> (logits [batch, seq, vocab], moe_aux).

    Depth is a `lax.scan` over the stacked block parameters: XLA compiles
    one block body regardless of `n_layers` (compile-time optimization;
    runtime is unchanged since every layer executes the same program).
    """
    _, s = tokens.shape
    x = params["embed"][tokens]
    rope = rope_tables(s, cfg.d_head)

    def body(x, blk):
        h = rms_norm(x, blk["norm1"])
        x = x + attention_layer(
            blk["attn"],
            h,
            cfg.spec,
            cfg.d_head,
            causal=cfg.causal,
            impl=cfg.attn_impl,
            rope=rope,
        )
        h = rms_norm(x, blk["norm2"])
        if cfg.n_experts:
            out, aux = moe_mod.moe_layer(blk["moe"], h, top_k=cfg.moe_top_k)
            return x + out, aux
        return x + swiglu(blk["mlp"], h), jnp.float32(0.0)

    x, auxs = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["norm_f"])
    logits = x @ params["embed"].T  # tied LM head
    aux = jnp.mean(auxs) if cfg.n_experts else jnp.float32(0.0)
    return logits, aux


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: [batch, seq] int32 -> logits [batch, seq, vocab]."""
    return forward_with_aux(params, cfg, tokens)[0]


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------

MOE_AUX_WEIGHT = 0.01


def loss_and_acc(params, cfg: ModelConfig, tokens, targets):
    """Mean next-token cross-entropy + token accuracy.

    tokens/targets: [batch, seq] int32; targets = tokens shifted by one
    (prepared by the Rust data pipeline).
    """
    logits, aux = forward_with_aux(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + MOE_AUX_WEIGHT * aux
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# AdamW training step (fused into one XLA module for the Rust runtime)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptConfig:
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01


def train_step(params, m, v, step, lr, cfg: ModelConfig, opt: OptConfig, tokens, targets):
    """One fused AdamW step.

    step: int32 scalar (1-based); lr: f32 scalar (schedule computed by Rust).
    Returns (params', m', v', loss, acc).
    """
    (loss, acc), grads = jax.value_and_grad(
        lambda p: loss_and_acc(p, cfg, tokens, targets), has_aux=True
    )(params)

    t = step.astype(jnp.float32)
    bc1 = 1.0 - opt.beta1**t
    bc2 = 1.0 - opt.beta2**t

    def upd(p, g, m_, v_):
        m2 = opt.beta1 * m_ + (1.0 - opt.beta1) * g
        v2 = opt.beta2 * v_ + (1.0 - opt.beta2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p)
        return p2, m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v, loss, acc
