"""L2 attention layers: the paper's variant zoo over the L1 kernel.

Two interchangeable implementations of the attention core:

* ``impl="pallas"`` — the L1 tiled kernel (`kernels.sqa_kernel`). Forward is
  the Pallas kernel; backward is a ``custom_vjp`` that differentiates the
  pure-jnp oracle (mathematically identical, XLA-fused). This mirrors how
  FlashAttention pairs a custom forward with an analytic backward.
* ``impl="xla"`` — the pure-jnp oracle end to end, letting XLA fuse the
  whole attention. On CPU this parallelizes across cores (the Pallas
  interpreter's grid is sequential), so compute-bound *benchmarks* default
  to it while the kernel path proves the TPU-shaped lowering composes.

Either way the SQA structure is identical: Hq query heads, Hkv key/value
heads, zero-copy head grouping, optional causal/sliding-window masks.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.ref import attention_ref
from .kernels.sqa_kernel import sqa_attention


# ---------------------------------------------------------------------------
# Variant definitions (paper §3.3 + Table 1/2 configurations)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionSpec:
    """One point in the (Hq, Hkv) design space of the paper."""

    name: str
    hq: int
    hkv: int
    window: int | None = None  # SWA / SW-SQA sliding window

    def __post_init__(self):
        if self.hq % self.hkv != 0:
            raise ValueError(f"{self.name}: Hq={self.hq} not a multiple of Hkv={self.hkv}")

    def flops_factor(self, h_total: int) -> float:
        """Attention-core FLOPs relative to the MHA baseline (= Hq / H)."""
        return self.hq / h_total

    def kv_cache_factor(self, h_total: int) -> float:
        """KV-cache bytes relative to the MHA baseline (= Hkv / H)."""
        return self.hkv / h_total


def variant_zoo(h_total: int, window: int = 128) -> dict[str, AttentionSpec]:
    """The named variants of the paper for a given MHA head budget H.

    Head counts follow Table 1 (H=16) / Table 2 (H=8) scaled by H:
    GQA uses H/4 kv heads (min 1), SQA = (H/2, H/4), sSQA = (H/2, H/2),
    xSQA = (H/4, H/4), xSMQA = (H/4, 1), SWA = MHA heads + window.
    """
    q = lambda f: max(h_total // f, 1)
    zoo = {
        "mha": AttentionSpec("mha", h_total, h_total),
        "gqa": AttentionSpec("gqa", h_total, q(4)),
        "mqa": AttentionSpec("mqa", h_total, 1),
        "sqa": AttentionSpec("sqa", q(2), q(4)),
        "ssqa": AttentionSpec("ssqa", q(2), q(2)),
        "xsqa": AttentionSpec("xsqa", q(4), q(4)),
        "xsmqa": AttentionSpec("xsmqa", q(4), 1),
        "swa": AttentionSpec("swa", h_total, h_total, window=window),
        "swsqa": AttentionSpec("swsqa", q(2), q(4), window=window),
    }
    # §6 future-work variants — analysis/extension points of the paper.
    # Light SQA: modest 25% query reduction (Hq = 3H/4), aiming for a new
    # sweet spot on the Pareto frontier. Requires 4 | H.
    if h_total % 4 == 0 and (3 * h_total // 4) % q(4) == 0:
        zoo["lsqa"] = AttentionSpec("lsqa", 3 * h_total // 4, q(4))
    return zoo


# ---------------------------------------------------------------------------
# Differentiable kernel wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _pallas_attention(q, k, v, causal, window):
    return sqa_attention(q, k, v, causal=causal, window=window)


def _pallas_attention_fwd(q, k, v, causal, window):
    return sqa_attention(q, k, v, causal=causal, window=window), (q, k, v)


def _pallas_attention_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal, window=window),
        q,
        k,
        v,
    )
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def grouped_attention(q, k, v, *, causal: bool = False):
    """Full attention without materializing repeated K/V heads.

    `repeat_kv` broadcasts K/V `G = Hq/Hkv` times before the einsum — on
    CPU that's a G-fold memory blow-up that made MQA *slower* than MHA
    (EXPERIMENTS.md §Perf iter 2). Grouping the query heads as
    `[b, Hkv, G, s, d]` expresses the same math with K/V read in place.
    """
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bkgqd,bkKd->bkgqK", qg, k) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(sk)[None, :]
        mask = (qi + (sk - s)) >= kj
        scores = jnp.where(mask[None, None, None], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqK,bkKd->bkgqd", probs, v)
    return out.reshape(b, hq, s, d)


def windowed_attention(q, k, v, *, window: int, causal: bool = True):
    """Block-local sliding-window attention in O(N·window) FLOPs.

    The oracle masks a dense N x N score matrix, which can never beat full
    attention in wall-clock — but the paper's SWA rows *do* win at long N
    because real implementations restrict computation to the band. This is
    the standard two-block trick: pad S to a multiple of `window`, let each
    query block attend to (its own + the previous) key block, and mask to
    the exact band `0 <= i - j < window`. Exactly equals the oracle's
    causal sliding window (SWA and SW-SQA, §2.5/§3.4).
    """
    b, hq, s, d = q.shape
    _, hkv, _, _ = k.shape
    g = hq // hkv
    w = window
    pad = (-s) % w
    sp = s + pad
    nb = sp // w
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qb = qp.reshape(b, hkv, g, nb, w, d)
    kb = kp.reshape(b, hkv, nb, w, d)
    vb = vp.reshape(b, hkv, nb, w, d)
    # Previous block (zeros before block 0), concat on the key axis: [.., 2w, d]
    prev = lambda x: jnp.concatenate([jnp.zeros_like(x[:, :, :1]), x[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([prev(kb), kb], axis=3)
    v2 = jnp.concatenate([prev(vb), vb], axis=3)

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bkgnad,bkncd->bkgnac", qb, k2) * scale  # [..,nb,w,2w]

    # Band mask in global coordinates: qpos = n*w + a, kpos = (n-1)*w + c.
    blk = jnp.arange(nb)[:, None, None]
    a = jnp.arange(w)[None, :, None]
    c = jnp.arange(2 * w)[None, None, :]
    qpos = blk * w + a
    kpos = (blk - 1) * w + c
    rel = qpos - kpos
    mask = (rel >= 0) & (rel < w) & (kpos >= 0) & (kpos < s) & (qpos < s)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask[None, None, None], scores, neg)
    # Stable softmax that tolerates fully-masked (padding) rows.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - jnp.maximum(m, neg / 2))
    p = jnp.where(mask[None, None, None], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgnac,bkncd->bkgnad", p, v2)
    out = out.reshape(b, hq, sp, d)[:, :, :s, :]
    _ = causal  # the band is inherently causal; flag kept for API symmetry
    return out


def attention_core(q, k, v, *, causal: bool, window: int | None, impl: str):
    """Dispatch to the selected attention-core implementation."""
    if impl == "pallas":
        return _pallas_attention(q, k, v, causal, window)
    if impl == "xla":
        if window is not None:
            return windowed_attention(q, k, v, window=window, causal=causal)
        return grouped_attention(q, k, v, causal=causal)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(seq: int, d_head: int, base: float = 10_000.0):
    """cos/sin tables, shape [seq, d_head//2] each."""
    half = d_head // 2
    inv_freq = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv_freq)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [batch, heads, seq, d_head]; rotate pairs (x_even, x_odd)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------


def init_attention_params(key, d_model: int, d_head: int, spec: AttentionSpec):
    """Xavier-ish init for the four projections of eqs. (4)-(6), (8)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    dq = spec.hq * d_head
    dkv = spec.hkv * d_head

    def init(k, fan_in, fan_out):
        std = (2.0 / (fan_in + fan_out)) ** 0.5
        return std * jax.random.normal(k, (fan_in, fan_out), jnp.float32)

    return {
        "wq": init(kq, d_model, dq),
        "wk": init(kk, d_model, dkv),
        "wv": init(kv, d_model, dkv),
        "wo": init(ko, dq, d_model),
    }


def attention_layer(
    params,
    x: jnp.ndarray,
    spec: AttentionSpec,
    d_head: int,
    *,
    causal: bool = True,
    impl: str = "xla",
    rope: tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """Apply one SQA-family layer to x: [batch, seq, d_model]."""
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, spec.hq, d_head).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(b, s, spec.hkv, d_head).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(b, s, spec.hkv, d_head).transpose(0, 2, 1, 3)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = attention_core(q, k, v, causal=causal, window=spec.window, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, spec.hq * d_head)
    return o @ params["wo"]
