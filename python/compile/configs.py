"""Model-zoo presets: the paper's experimental configurations, CPU-scaled.

Three families (see DESIGN.md §3 for the scaling substitutions):

* ``tiny``    — integration/e2e driver model (fast on CPU, both attn impls).
* ``dense_sm``— Table 1 stand-in: the paper's dense architecture
                (hidden 256, 8 layers, H=16) with CPU-scaled context.
* ``moe_sm``  — Table 2 stand-in: MoE architecture (hidden 128, 6 layers,
                H=8, 4 experts).
* ``bench``   — Table 3 stand-in: dense blocks used for the long-sequence
                forward-pass sweep.

Head counts per variant follow the paper exactly (Tables 1-3); only context
length / training-step budget are scaled for the XLA-CPU substrate.
"""

from __future__ import annotations

from dataclasses import replace

from .attention import AttentionSpec, variant_zoo
from .model import ModelConfig

SWA_WINDOW = 128

# Table 1 variant set (H = 16).
TABLE1_VARIANTS = ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa"]
# Table 2 variant set (H = 8).
TABLE2_VARIANTS = ["gqa", "mqa", "sqa", "ssqa", "xsqa"]
# Table 3 variant set (column order of the paper's table).
TABLE3_VARIANTS = ["xsqa", "sqa", "ssqa", "swa", "mqa", "gqa", "mha"]

# Sequence-length buckets for fwd artifacts (Table 3 sweep + serving).
BENCH_SEQS = [512, 1024, 2048, 4096, 8192]
TINY_SEQS = [64, 128, 256]


def _zoo(h_total: int) -> dict[str, AttentionSpec]:
    return variant_zoo(h_total, window=SWA_WINDOW)


def tiny(variant: str = "sqa", attn_impl: str = "xla") -> ModelConfig:
    """~1.5M params; the e2e driver + integration-test model."""
    return ModelConfig(
        name="tiny",
        vocab=2048,
        d_model=128,
        n_layers=2,
        h_total=8,
        spec=_zoo(8)[variant],
        attn_impl=attn_impl,
    )


def dense_sm(variant: str = "sqa", attn_impl: str = "xla") -> ModelConfig:
    """Table 1 architecture: hidden 256, 8 layers, H=16 (~7M params tied)."""
    return ModelConfig(
        name="dense_sm",
        vocab=4096,
        d_model=256,
        n_layers=8,
        h_total=16,
        spec=_zoo(16)[variant],
        attn_impl=attn_impl,
    )


def moe_sm(variant: str = "gqa", attn_impl: str = "xla") -> ModelConfig:
    """Table 2 architecture: hidden 128, 6 layers, H=8, 4 experts, top-1."""
    return ModelConfig(
        name="moe_sm",
        vocab=2048,
        d_model=128,
        n_layers=6,
        h_total=8,
        spec=_zoo(8)[variant],
        attn_impl=attn_impl,
        n_experts=4,
        moe_top_k=1,
    )


def bench(variant: str = "mha", attn_impl: str = "xla") -> ModelConfig:
    """Table 3 forward-sweep model: dense blocks, H=16, CPU-scaled depth."""
    return ModelConfig(
        name="bench",
        vocab=1024,
        d_model=256,
        n_layers=4,
        h_total=16,
        spec=_zoo(16)[variant],
        attn_impl=attn_impl,
    )


FAMILIES = {
    "tiny": tiny,
    "dense_sm": dense_sm,
    "moe_sm": moe_sm,
    "bench": bench,
}


def get(family: str, variant: str, attn_impl: str = "xla") -> ModelConfig:
    cfg = FAMILIES[family](variant=variant, attn_impl=attn_impl)
    return cfg


def with_impl(cfg: ModelConfig, attn_impl: str) -> ModelConfig:
    return replace(cfg, attn_impl=attn_impl)
