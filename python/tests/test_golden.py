"""Generate cross-language golden files: JAX reference attention outputs.

The Rust native oracle (`rust/src/attention/`) reads these in
`rust/tests/golden.rs` and must match bit-for-bit-ish (<= 2e-5). This pins
the *semantics* of the SQA family across the two independent
implementations (jnp oracle that the Pallas kernel is tested against, and
the pure-Rust oracle the coordinator properties are tested against).

Golden files are regenerated on every pytest run (deterministic inputs) —
they live under artifacts/golden/ and are gitignored like all artifacts.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "../../artifacts/golden")

CASES = [
    # name, hq, hkv, seq, d, causal, window
    ("mha", 4, 4, 24, 8, False, None),
    ("gqa", 4, 2, 24, 8, False, None),
    ("mqa", 4, 1, 16, 4, False, None),
    ("sqa_causal", 4, 2, 32, 8, True, None),
    ("xsqa", 2, 2, 16, 8, True, None),
    ("swa", 2, 2, 40, 4, False, 8),
    ("sw_sqa", 4, 2, 40, 4, True, 8),
]


def lcg(seed: int, n: int) -> np.ndarray:
    """Tiny deterministic generator both languages can replay if needed."""
    out = np.empty(n, dtype=np.float64)
    state = np.uint64(seed * 2654435761 % (2**31) or 1)
    a, c, m = np.uint64(1664525), np.uint64(1013904223), np.uint64(2**32)
    for i in range(n):
        state = (a * state + c) % m
        out[i] = (int(state) / 2**32) * 2.0 - 1.0
    return out.astype(np.float32)


def test_write_goldens():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, hq, hkv, s, d, causal, window in CASES:
        b = 1
        q = lcg(1, b * hq * s * d).reshape(b, hq, s, d)
        k = lcg(2, b * hkv * s * d).reshape(b, hkv, s, d)
        v = lcg(3, b * hkv * s * d).reshape(b, hkv, s, d)
        out = attention_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal, window=window
        )
        out = np.asarray(out)
        assert np.isfinite(out).all()
        blob = {
            "name": name,
            "hq": hq,
            "hkv": hkv,
            "seq": s,
            "d": d,
            "causal": causal,
            "window": window,
            "q": q.reshape(-1).tolist(),
            "k": k.reshape(-1).tolist(),
            "v": v.reshape(-1).tolist(),
            "out": out.reshape(-1).tolist(),
        }
        with open(os.path.join(GOLDEN_DIR, f"{name}.json"), "w") as f:
            json.dump(blob, f)
    assert len(os.listdir(GOLDEN_DIR)) >= len(CASES)
