"""AOT contract tests: packer round-trip, manifest schema, HLO emission."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs
from compile.aot import Packer, to_hlo_text
from compile.model import forward, init_params, loss_and_acc

jax.config.update("jax_platform_name", "cpu")


def test_packer_roundtrip():
    cfg = configs.tiny("sqa")
    params = init_params(cfg, jax.random.PRNGKey(0))
    packer = Packer(cfg)
    vec = packer.pack(params)
    assert vec.shape == (packer.total,)
    back = packer.unpack(vec)
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packer_offsets_are_disjoint_and_total():
    packer = Packer(configs.tiny("xsqa"))
    end = 0
    for spec in packer.specs:
        assert spec["offset"] == end
        end += int(np.prod(spec["shape"])) if spec["shape"] else 1
    assert end == packer.total


def test_packed_forward_equals_unpacked():
    cfg = configs.tiny("ssqa")
    params = init_params(cfg, jax.random.PRNGKey(1))
    packer = Packer(cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab, jnp.int32)
    a = forward(params, cfg, tokens)
    b = forward(packer.unpack(packer.pack(params)), cfg, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_hlo_text_emission_parses():
    """The HLO text must start with a module header the Rust side can load."""
    cfg = configs.tiny("sqa")
    packer = Packer(cfg)

    def fwd(fp, tokens):
        return (forward(packer.unpack(fp), cfg, tokens),)

    lowered = jax.jit(fwd).lower(
        jax.ShapeDtypeStruct((packer.total,), jnp.float32),
        jax.ShapeDtypeStruct((1, 16), jnp.int32),
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "parameter(0)" in text


def test_manifest_schema():
    """Validate the manifest the Rust runtime consumes (if generated)."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    assert m["version"] == 2
    assert "tiny" in m["families"]
    fam = m["families"]["tiny"]
    for key in ["vocab", "d_model", "n_layers", "h_total", "d_head", "variants"]:
        assert key in fam
    for vname, v in fam["variants"].items():
        assert v["hq"] % v["hkv"] == 0, vname
        assert v["n_params"] == sum(
            int(np.prod(p["shape"])) if p["shape"] else 1 for p in v["params"]
        )
    kinds = {(a["family"], a["variant"], a["kind"]) for a in m["artifacts"]}
    assert ("tiny", "sqa", "train") in kinds
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(os.path.dirname(path), a["path"])), a["path"]


def test_eval_loss_matches_direct_computation():
    """The lowered eval graph output == direct python computation."""
    cfg = configs.tiny("sqa")
    packer = Packer(cfg)
    params = init_params(cfg, jax.random.PRNGKey(5))
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 32), 0, cfg.vocab, jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)

    def eval_fn(fp, t, g):
        return loss_and_acc(packer.unpack(fp), cfg, t, g)

    direct = loss_and_acc(params, cfg, tokens, targets)
    via = jax.jit(eval_fn)(packer.pack(params), tokens, targets)
    assert abs(float(direct[0]) - float(via[0])) < 1e-5
    assert abs(float(direct[1]) - float(via[1])) < 1e-6
