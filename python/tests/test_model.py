"""L2 correctness: model shapes, training dynamics, variant zoo, MoE."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs
from compile.attention import AttentionSpec, variant_zoo
from compile.model import (
    OptConfig,
    forward,
    init_params,
    loss_and_acc,
    param_count,
    train_step,
)
from compile.moe import init_moe_params, moe_layer

jax.config.update("jax_platform_name", "cpu")


def data(cfg, batch=2, seq=64, seed=0):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (batch, seq), 0, cfg.vocab, jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


@pytest.mark.parametrize("variant", ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa", "swa", "swsqa"])
def test_forward_shapes_all_variants(variant):
    cfg = configs.tiny(variant)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, _ = data(cfg)
    logits = forward(params, cfg, tokens)
    assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_count_scales_with_hq():
    """Wq/Wo shrink with Hq (paper §3.2): fewer params for SQA variants."""
    counts = {
        v: param_count(init_params(configs.tiny(v), jax.random.PRNGKey(0)))
        for v in ["mha", "sqa", "xsqa"]
    }
    assert counts["mha"] > counts["sqa"] > counts["xsqa"]


def test_dense_sm_matches_paper_scale():
    """Table 1 models are ~10-12M params; ours (tied embeddings) ~7-9M."""
    cfg = configs.dense_sm("mha")
    n = param_count(init_params(cfg, jax.random.PRNGKey(0)))
    assert 5_000_000 < n < 13_000_000


def test_moe_sm_scale_and_forward():
    cfg = configs.moe_sm("gqa")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = param_count(params)
    assert 2_000_000 < n < 10_000_000
    tokens, targets = data(cfg, batch=2, seq=32)
    loss, acc = loss_and_acc(params, cfg, tokens, targets)
    assert np.isfinite(float(loss)) and 0.0 <= float(acc) <= 1.0


def test_initial_loss_near_uniform():
    """Fresh model ≈ uniform predictor: loss ≈ ln(vocab)."""
    cfg = configs.tiny("sqa")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, targets = data(cfg)
    loss, _ = loss_and_acc(params, cfg, tokens, targets)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


@pytest.mark.parametrize("variant", ["sqa", "mha"])
def test_train_step_reduces_loss(variant):
    """A few AdamW steps on a fixed batch must fit it (loss strictly down)."""
    cfg = configs.tiny(variant)
    params = init_params(cfg, jax.random.PRNGKey(0))
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    tokens, targets = data(cfg, batch=4, seq=64)
    opt = OptConfig()
    losses = []
    step_fn = jax.jit(
        lambda p, m_, v_, s: train_step(
            p, m_, v_, s, jnp.float32(1e-3), cfg, opt, tokens, targets
        )
    )
    for i in range(8):
        params, m, v, loss, acc = step_fn(params, m, v, jnp.int32(i + 1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_pallas_impl_composes():
    """fwd+bwd through the Pallas kernel (custom_vjp) must train too."""
    cfg = configs.tiny("sqa", attn_impl="pallas")
    params = init_params(cfg, jax.random.PRNGKey(0))
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    tokens, targets = data(cfg, batch=2, seq=64)
    opt = OptConfig()
    p1, m1, v1, loss0, _ = train_step(
        params, m, v, jnp.int32(1), jnp.float32(1e-3), cfg, opt, tokens, targets
    )
    _, _, _, loss1, _ = train_step(
        p1, m1, v1, jnp.int32(2), jnp.float32(1e-3), cfg, opt, tokens, targets
    )
    assert float(loss1) < float(loss0)


def test_pallas_and_xla_impls_agree():
    """Same params, same batch: the two attention impls give the same loss."""
    cfg_x = configs.tiny("sqa", attn_impl="xla")
    cfg_p = configs.tiny("sqa", attn_impl="pallas")
    params = init_params(cfg_x, jax.random.PRNGKey(3))
    tokens, targets = data(cfg_x)
    lx, _ = loss_and_acc(params, cfg_x, tokens, targets)
    lp, _ = loss_and_acc(params, cfg_p, tokens, targets)
    assert abs(float(lx) - float(lp)) < 1e-4


def test_grads_match_between_impls():
    cfg_x = configs.tiny("sqa", attn_impl="xla")
    cfg_p = configs.tiny("sqa", attn_impl="pallas")
    params = init_params(cfg_x, jax.random.PRNGKey(4))
    tokens, targets = data(cfg_x, batch=1, seq=32)
    gx = jax.grad(lambda p: loss_and_acc(p, cfg_x, tokens, targets)[0])(params)
    gp = jax.grad(lambda p: loss_and_acc(p, cfg_p, tokens, targets)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(gx), jax.tree_util.tree_leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_causal_no_future_leakage():
    """Changing token t must not change logits before t."""
    cfg = configs.tiny("sqa")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, _ = data(cfg, batch=1, seq=32)
    l0 = forward(params, cfg, tokens)
    tokens2 = tokens.at[0, 20].set((tokens[0, 20] + 1) % cfg.vocab)
    l1 = forward(params, cfg, tokens2)
    np.testing.assert_allclose(
        np.asarray(l0[0, :20]), np.asarray(l1[0, :20]), atol=1e-5
    )
    assert np.abs(np.asarray(l0[0, 20:]) - np.asarray(l1[0, 20:])).max() > 1e-4


def test_variant_zoo_head_counts_table1():
    zoo = variant_zoo(16)
    expect = {
        "mha": (16, 16),
        "gqa": (16, 4),
        "mqa": (16, 1),
        "sqa": (8, 4),
        "ssqa": (8, 8),
        "xsqa": (4, 4),
        "xsmqa": (4, 1),
    }
    for name, (hq, hkv) in expect.items():
        assert (zoo[name].hq, zoo[name].hkv) == (hq, hkv), name


def test_variant_zoo_head_counts_table2():
    zoo = variant_zoo(8)
    expect = {"gqa": (8, 2), "mqa": (8, 1), "sqa": (4, 2), "ssqa": (4, 4), "xsqa": (2, 2)}
    for name, (hq, hkv) in expect.items():
        assert (zoo[name].hq, zoo[name].hkv) == (hq, hkv), name


def test_attention_spec_validation():
    with pytest.raises(ValueError):
        AttentionSpec("bad", 3, 2)


def test_moe_outputs_finite_and_balanced_aux():
    p = init_moe_params(jax.random.PRNGKey(0), 32, 64, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_layer(p, x, top_k=1)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # aux is ~1 near balance, and bounded by n_experts.
    assert 0.0 < float(aux) <= 4.0


def test_moe_topk_all_experts_is_dense_mixture():
    """top_k = E keeps the full softmax mixture (weights sum to 1)."""
    p = init_moe_params(jax.random.PRNGKey(0), 16, 32, 3)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    out_k3, _ = moe_layer(p, x, top_k=3)
    out_k99, _ = moe_layer(p, x, top_k=99)
    np.testing.assert_allclose(np.asarray(out_k3), np.asarray(out_k99), atol=1e-6)
