"""L1 correctness: Pallas SQA kernel vs the pure-jnp oracle.

This is the core correctness signal for the compiled artifacts: everything
Rust executes lowers through these kernels. Coverage:
  * every named paper variant (MHA/GQA/MQA/SQA/sSQA/xSQA/xSMQA) as (Hq,Hkv)
  * causal, sliding-window (SWA) and combined SW-SQA masking
  * hypothesis sweep over shapes, head ratios, block sizes, seeds
  * analytic invariants (convex-combination bound, mask zeroing)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import attention_ref, attention_flops, repeat_kv
from compile.kernels.sqa_kernel import (
    mxu_tile_matmuls,
    sqa_attention,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def qkv(b, hq, hkv, s, d, seed=0):
    return (
        rand(seed, (b, hq, s, d)),
        rand(seed + 1, (b, hkv, s, d)),
        rand(seed + 2, (b, hkv, s, d)),
    )


# The paper's variant zoo with a 16-head MHA baseline (Table 1).
VARIANTS_H16 = {
    "mha": (16, 16),
    "gqa": (16, 4),
    "mqa": (16, 1),
    "sqa": (8, 4),
    "ssqa": (8, 8),
    "xsqa": (4, 4),
    "xsmqa": (4, 1),
}


@pytest.mark.parametrize("name,heads", VARIANTS_H16.items(), ids=VARIANTS_H16.keys())
@pytest.mark.parametrize("causal", [False, True])
def test_variants_match_ref(name, heads, causal):
    hq, hkv = heads
    q, k, v = qkv(2, hq, hkv, 128, 16)
    out = sqa_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


@pytest.mark.parametrize("window", [1, 16, 37, 128, 1000])
def test_sliding_window(window):
    q, k, v = qkv(1, 4, 2, 128, 8)
    out = sqa_attention(q, k, v, window=window, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_sw_sqa_combined():
    """SW-SQA hybrid (§3.4): reduced query heads + windowed scope."""
    q, k, v = qkv(2, 4, 4, 256, 16)  # xSQA heads of an H=16 baseline
    out = sqa_attention(q, k, v, causal=True, window=64)
    ref = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 64), (64, 16), (128, 128), (256, 256)])
def test_block_shape_independence(bq, bk):
    """Output must not depend on the HBM<->VMEM schedule."""
    q, k, v = qkv(1, 2, 1, 256, 8)
    base = sqa_attention(q, k, v, causal=True, block_q=64, block_k=64)
    out = sqa_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=ATOL)


def test_non_pow2_seq_falls_back_to_divisor_blocks():
    q, k, v = qkv(1, 2, 2, 96, 8)  # 96 = 3 * 32
    out = sqa_attention(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_hq_equals_h_is_mha():
    """SQA with Hq = H = Hkv degenerates to exact MHA (paper §3.3)."""
    q, k, v = qkv(1, 8, 8, 64, 16)
    out = sqa_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_repeat_kv_semantics():
    """Output head h must read kv head h // group (repeat_interleave)."""
    b, hkv, s, d = 1, 2, 4, 2
    k = jnp.arange(b * hkv * s * d, dtype=jnp.float32).reshape(b, hkv, s, d)
    r = repeat_kv(k, 3)
    assert r.shape == (b, 6, s, d)
    for h in range(6):
        np.testing.assert_array_equal(np.asarray(r[0, h]), np.asarray(k[0, h // 3]))


def test_kernel_uses_grouped_kv_not_first_head():
    """Distinct K/V per group: zeroing kv head 1 must change only heads 2,3."""
    q, k, v = qkv(1, 4, 2, 64, 8)
    out0 = sqa_attention(q, k, v)
    v2 = v.at[:, 1].set(0.0)
    out1 = sqa_attention(q, k, v2)
    same = np.asarray(out0[:, :2]) - np.asarray(out1[:, :2])
    diff = np.asarray(out0[:, 2:]) - np.asarray(out1[:, 2:])
    assert np.abs(same).max() < 1e-6
    assert np.abs(diff).max() > 1e-3


def test_causal_first_token_attends_only_itself():
    q, k, v = qkv(1, 2, 2, 32, 8)
    out = sqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out[:, :, 0, :]), np.asarray(v[:, :, 0, :]), atol=ATOL
    )


def test_window_one_is_identity_on_values():
    """window=1 with causal geometry: each token sees only itself."""
    q, k, v = qkv(1, 2, 1, 64, 8)
    out = sqa_attention(q, k, v, window=1)
    vr = repeat_kv(v, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vr), atol=ATOL)


def test_output_within_value_hull():
    """Softmax output is a convex combination of values (row-wise bound)."""
    q, k, v = qkv(2, 4, 2, 128, 16, seed=7)
    out = np.asarray(sqa_attention(q, k, v))
    vr = np.asarray(repeat_kv(v, 2))
    vmax = vr.max(axis=2, keepdims=True)
    vmin = vr.min(axis=2, keepdims=True)
    assert (out <= vmax + 1e-5).all() and (out >= vmin - 1e-5).all()


def test_uniform_scores_average_values():
    """Constant q,k -> uniform attention -> output == mean of values."""
    b, hq, hkv, s, d = 1, 2, 2, 64, 8
    q = jnp.ones((b, hq, s, d))
    k = jnp.ones((b, hkv, s, d))
    v = rand(3, (b, hkv, s, d))
    out = sqa_attention(q, k, v)
    ref = jnp.broadcast_to(v.mean(axis=2, keepdims=True), v.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_rejects_bad_head_ratio():
    q, k, v = qkv(1, 3, 2, 32, 8)
    with pytest.raises(ValueError):
        sqa_attention(q, k, v)


def test_rejects_bad_window():
    q, k, v = qkv(1, 2, 2, 32, 8)
    with pytest.raises(ValueError):
        sqa_attention(q, k, v, window=0)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 2),
    group=st.integers(1, 4),
    hkv=st.integers(1, 4),
    logs=st.integers(4, 8),
    d=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_hypothesis_matches_ref(b, group, hkv, logs, d, causal, seed):
    hq = group * hkv
    s = 2**logs
    q, k, v = qkv(b, hq, hkv, s, d, seed=seed)
    out = sqa_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    window=st.integers(1, 300),
    logs=st.integers(5, 8),
    seed=st.integers(0, 1000),
)
def test_hypothesis_windows(window, logs, seed):
    s = 2**logs
    q, k, v = qkv(1, 2, 1, s, 8, seed=seed)
    out = sqa_attention(q, k, v, window=window, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


# ---------------------------------------------------------------------------
# Structural perf model (the quantities DESIGN.md §7 tracks)
# ---------------------------------------------------------------------------


def test_flops_reduction_matches_paper():
    """Paper eq. (9): speed-up = H / Hq, independent of N and d."""
    h, n, d = 16, 4096, 64
    full = attention_flops(1, h, n, n, d)
    for hq in (8, 4, 2):
        assert full / attention_flops(1, hq, n, n, d) == h / hq


def test_mxu_tile_count_scales_with_hq():
    base = mxu_tile_matmuls(1, 16, 4096, 128, 128)
    half = mxu_tile_matmuls(1, 8, 4096, 128, 128)
    assert base == 2 * half


def test_vmem_footprint_independent_of_seq():
    f = vmem_footprint_bytes(128, 128, 64)
    assert f == vmem_footprint_bytes(128, 128, 64)
    assert f < 16 * 1024 * 1024  # fits TPU VMEM with ample headroom
