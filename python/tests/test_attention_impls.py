"""The optimized L2 attention paths must equal the oracle exactly.

`grouped_attention` (no K/V repeat) and `windowed_attention` (block-local
O(N·w)) are wall-clock optimizations — these tests pin them to
`attention_ref` across head ratios, window sizes, and awkward sequence
lengths (padding edge cases).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.attention import attention_core, grouped_attention, windowed_attention
from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def qkv(b, hq, hkv, s, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (b, hq, s, d), jnp.float32),
        jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32),
        jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32),
    )


@pytest.mark.parametrize("hq,hkv", [(16, 16), (16, 4), (16, 1), (8, 4), (4, 4), (4, 1)])
@pytest.mark.parametrize("causal", [False, True])
def test_grouped_matches_ref(hq, hkv, causal):
    q, k, v = qkv(2, hq, hkv, 48, 8)
    out = grouped_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


@pytest.mark.parametrize("window", [1, 3, 16, 64])
@pytest.mark.parametrize("s", [16, 37, 64, 100, 129])
def test_windowed_matches_ref(window, s):
    q, k, v = qkv(1, 4, 2, s, 8, seed=3)
    out = windowed_attention(q, k, v, window=window)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_windowed_larger_than_seq():
    q, k, v = qkv(1, 2, 1, 24, 4, seed=5)
    out = windowed_attention(q, k, v, window=64)
    ref = attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_dispatch_selects_windowed_for_swa():
    q, k, v = qkv(1, 4, 4, 40, 8, seed=7)
    out = attention_core(q, k, v, causal=True, window=8, impl="xla")
    ref = attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_windowed_flops_scale_linearly():
    """Structural check: compiled HLO of windowed attention at 2N should be
    ~2x the FLOPs of N (not 4x as dense attention would be)."""
    def cost(s):
        q, k, v = qkv(1, 2, 2, s, 8, seed=1)
        fn = lambda q_, k_, v_: windowed_attention(q_, k_, v_, window=16)
        c = jax.jit(fn).lower(q, k, v).compile().cost_analysis()
        return c.get("flops", 0.0)

    f1, f2 = cost(256), cost(512)
    assert f2 < 2.6 * f1, f"windowed attention not linear: {f1} -> {f2}"


@settings(max_examples=20, deadline=None)
@given(
    group=st.integers(1, 4),
    hkv=st.integers(1, 3),
    s=st.integers(2, 100),
    window=st.integers(1, 40),
    seed=st.integers(0, 99),
)
def test_hypothesis_windowed(group, hkv, s, window, seed):
    q, k, v = qkv(1, group * hkv, hkv, s, 4, seed=seed)
    out = windowed_attention(q, k, v, window=window)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)
