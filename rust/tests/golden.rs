//! Cross-language differential test: the pure-Rust attention oracle vs the
//! JAX reference (`python/compile/kernels/ref.py`), via golden files
//! written by `python/tests/test_golden.py` (run `make test` or pytest
//! first — missing goldens skip with a message, they are build artifacts).

use sqa::attention::{attention, tensor::Tensor, Spec};
use sqa::util::json::Json;

fn load_case(path: &std::path::Path) -> (Spec, Tensor, Tensor, Tensor, Tensor) {
    let text = std::fs::read_to_string(path).unwrap();
    let j = Json::parse(&text).unwrap();
    let get = |k: &str| j.req(k).unwrap().as_usize().unwrap();
    let (hq, hkv, s, d) = (get("hq"), get("hkv"), get("seq"), get("d"));
    let spec = Spec {
        causal: j.req("causal").unwrap().as_bool().unwrap(),
        window: j.get("window").and_then(|w| w.as_usize()),
        ..Spec::full(hq, hkv)
    };
    let arr = |k: &str, shape: &[usize]| {
        let data: Vec<f32> = j
            .req(k)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        Tensor::from_vec(shape, data).unwrap()
    };
    (
        spec,
        arr("q", &[1, hq, s, d]),
        arr("k", &[1, hkv, s, d]),
        arr("v", &[1, hkv, s, d]),
        arr("out", &[1, hq, s, d]),
    )
}

#[test]
fn native_oracle_matches_jax_reference() {
    let dir = std::path::Path::new("artifacts/golden");
    if !dir.exists() {
        // Goldens are optional build artifacts: without a Python/JAX
        // toolchain there is nothing to compare against, so skip — the
        // oracle is still covered by its unit/property/equivalence tests.
        eprintln!(
            "skipping: no {} — generate goldens with \
             `cd python && python -m pytest tests/test_golden.py`",
            dir.display()
        );
        return;
    }
    let mut n = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let (spec, q, k, v, expected) = load_case(&path);
        let out = attention(&q, &k, &v, spec).unwrap();
        let diff = out.max_abs_diff(&expected);
        assert!(
            diff <= 2e-5,
            "{}: max |rust - jax| = {diff}",
            path.display()
        );
        n += 1;
    }
    assert!(n >= 7, "only {n} golden cases found");
}
