//! Loom interleaving models for the runtime's concurrency protocols.
//!
//! Compiled ONLY under `--cfg loom` — tier-1 builds see an empty crate and
//! never resolve the `loom` dependency (the offline image has no crates;
//! the CI `loom` job `cargo add`s it before running). Locally:
//!
//! ```sh
//! cargo add loom@0.7 --dev              # network required, not committed
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!   cargo test --release --test loom_models
//! git checkout Cargo.toml               # drop the dev-dep again
//! ```
//!
//! Every primitive here reaches loom through the `sqa::util::sync` seam:
//! under `cfg(loom)` the pool's mutexes/condvars, the `run_borrowed`
//! latch, and the session table's lock are loom types, so loom explores
//! every interleaving (bounded by `LOOM_MAX_PREEMPTIONS`) of the exact
//! production code paths — not of a test-only model.
//!
//! Models are kept to ≤2 spawned threads + main: loom's state space grows
//! exponentially in threads and context switches.

#![cfg(loom)]

use loom::thread;
use sqa::runtime::session::{SessionTable, TakeError};
use sqa::util::sync::{Arc, AtomicUsize, Latch, Ordering};
use sqa::util::threadpool::ThreadPool;

/// The submit → worker-pop → `wait_idle` idle-condvar handshake: wait_idle
/// must not return while a popped job is still running (the queue is
/// already empty then — `active` is what holds it back).
#[test]
fn pool_submit_wait_idle_handshake() {
    loom::model(|| {
        let pool = ThreadPool::new(1, 2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        drop(pool); // drains + joins: every branch must terminate cleanly
    });
}

/// Bounded-queue backpressure: with capacity 1 the second submit must
/// block on `not_full` until the worker pops — no job may be lost or
/// duplicated in any interleaving of submitter vs worker.
#[test]
fn pool_bounded_queue_backpressure() {
    loom::model(|| {
        let pool = ThreadPool::new(1, 1);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        drop(pool);
    });
}

/// `run_borrowed`'s SAFETY argument, model-checked: the erased-lifetime
/// jobs write through borrows of main's stack, and in every interleaving
/// the writes are complete (and the borrows dead) before `run_borrowed`
/// returns to the assert.
#[test]
fn run_borrowed_latch_joins_every_interleaving() {
    loom::model(|| {
        let pool = ThreadPool::new(1, 2);
        let mut data = [0usize; 2];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for slot in data.iter_mut() {
                jobs.push(Box::new(move || {
                    *slot += 1;
                }));
            }
            pool.run_borrowed(jobs);
        }
        assert_eq!(data, [1, 1]);
        drop(pool);
    });
}

/// The latch's terminated-vs-completed split — the path behind the
/// job-panic and pool-drops-jobs-unrun cases (loom cannot unwind, so the
/// "panic" is modeled as what unwinding does to the guard: a drop without
/// `complete()`). The waiter must unblock in every schedule and must
/// count exactly the completions.
#[test]
fn latch_counts_drops_as_terminated_not_completed() {
    loom::model(|| {
        let latch = Arc::new(Latch::new(2));
        let g_ok = latch.guard();
        let g_drop = latch.guard();
        let t1 = thread::spawn(move || g_ok.complete());
        let t2 = thread::spawn(move || drop(g_drop));
        let completed = latch.wait();
        assert_eq!(completed, 1, "one completed, one merely terminated");
        t1.join().unwrap();
        t2.join().unwrap();
    });
}

/// Session-table step vs close race: in every interleaving the close
/// succeeds exactly once, the state is never resurrected after close, and
/// the step either runs to a successful put-back or observes the session
/// gone — never a hang, never a double-free of the boxed state.
#[test]
fn session_table_step_vs_close() {
    loom::model(|| {
        let tab = Arc::new(SessionTable::new());
        let id = tab.insert(0u64);
        let stepper = {
            let tab = Arc::clone(&tab);
            thread::spawn(move || match tab.take(id) {
                Ok(mut s) => {
                    *s += 1;
                    tab.put_back(id, s)
                }
                Err(TakeError::Unknown) => false, // close won the race
                Err(TakeError::Busy) => unreachable!("no concurrent stepper"),
            })
        };
        let closed = tab.close(id);
        let _stepped = stepper.join().unwrap();
        assert!(closed, "the entry (ready or busy) is removable exactly once");
        assert!(tab.is_empty(), "closed session must not be resurrected");
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Unknown);
    });
}

/// Two concurrent steps on one session: mutual exclusion through the Busy
/// marker — at least one step wins, a loser sees `Busy` (not a hang, not
/// a second handle to the same boxed state), and the final state reflects
/// exactly the steps that reported success.
#[test]
fn session_table_concurrent_steps_exclude() {
    loom::model(|| {
        let tab = Arc::new(SessionTable::new());
        let id = tab.insert(0u64);
        let other = {
            let tab = Arc::clone(&tab);
            thread::spawn(move || match tab.take(id) {
                Ok(mut s) => {
                    *s += 1;
                    assert!(tab.put_back(id, s), "nobody closes in this model");
                    true
                }
                Err(TakeError::Busy) => false,
                Err(TakeError::Unknown) => unreachable!("never closed"),
            })
        };
        let mine = match tab.take(id) {
            Ok(mut s) => {
                *s += 1;
                assert!(tab.put_back(id, s));
                true
            }
            Err(TakeError::Busy) => false,
            Err(TakeError::Unknown) => unreachable!("never closed"),
        };
        let theirs = other.join().unwrap();
        assert!(mine || theirs, "at least one step must win the slot");
        let expected = (mine as u64) + (theirs as u64);
        assert_eq!(tab.with(id, |s| *s), Ok(expected));
        assert!(tab.close(id));
    });
}
