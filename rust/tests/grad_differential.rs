//! Gradient differential suite: the flash-style streaming attention
//! backward vs the scalar row-loop oracle, from raw slabs up through a
//! whole fused train step, plus finite-difference checks of the analytic
//! gradients against the loss itself.
//!
//! Mirrors `tiled_differential.rs` / `linalg_differential.rs` structure:
//! the slab grid covers every head geometry of the paper's variant zoo,
//! both mask kinds, sequence lengths straddling the tile boundaries
//! (S = 1, T−1, T, T+1, 3·T+5) and both linalg lowerings, at 1e-4 — the
//! two backwards share the math (dV = Pᵀ dO, dS = P∘(dP − Δ)·scale,
//! dQ = dS K, dK = dSᵀ Q) but not the association (streamed tile blocks
//! with LSE-based probability recompute vs per-row two-pass softmax), so
//! agreement pins the logsumexp export, the block recompute, the
//! mask-aware tile skipping and the KV-head gradient folding all at once.

use sqa::attention::backward::{backward_naive_slabs, backward_tiled_slabs, forward_slabs_lse};
use sqa::attention::tiled::TileConfig;
use sqa::attention::{Kernel, Spec};
use sqa::linalg::Impl;
use sqa::runtime::{Backend, NativeBackend};
use sqa::util::rng::Pcg64;
use sqa::util::threadpool::ThreadPool;

const TILE: usize = 8;
const TOL: f32 = 1e-4;

fn randn(len: usize, seed: u64, std: f32) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    (0..len).map(|_| rng.normal_f32(0.0, std)).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// (label, Hq, Hkv) — the head-geometry grid from the paper:
/// MHA, GQA grouping, MQA, SQA (Hq halved), extreme SQA.
const GEOMETRIES: &[(&str, usize, usize)] = &[
    ("mha", 8, 8),
    ("gqa", 8, 2),
    ("mqa", 4, 1),
    ("sqa", 4, 2),
    ("xsqa", 2, 2),
];

/// (causal, window) mask grid.
const MASKS: &[(bool, Option<usize>)] = &[
    (false, None),          // full bidirectional
    (true, None),           // causal
    (false, Some(3)),       // symmetric sliding window
    (true, Some(3)),        // causal sliding window
    (true, Some(TILE + 3)), // window wider than a tile
];

/// Sequence lengths straddling the tile size: 1, T−1, T, T+1, 3·T+5.
const SEQS: &[usize] = &[1, TILE - 1, TILE, TILE + 1, 3 * TILE + 5];

/// Run forward (with LSE) + both backwards on one random slab set; return
/// (tiled grads, naive grads) as (dq, dk, dv) triples.
type Grads = (Vec<f32>, Vec<f32>, Vec<f32>);

fn both_backwards(
    hq: usize,
    hkv: usize,
    s: usize,
    d: usize,
    spec: Spec,
    imp: Impl,
    seed: u64,
) -> (Grads, Grads) {
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    let q = randn(s * dq_cols, seed, 0.7);
    let k = randn(s * dkv_cols, seed + 1, 0.7);
    let v = randn(s * dkv_cols, seed + 2, 0.7);
    let dout = randn(s * dq_cols, seed + 3, 0.7);
    let scale = 1.0 / (d as f32).sqrt();
    let cfg = TileConfig::new(TILE, TILE).unwrap().with_linalg(imp);
    let mut o = vec![0.0f32; s * dq_cols];
    let mut lse = vec![0.0f32; hq * s];
    forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, scale, None);

    let mut tiled = (
        vec![0.0f32; s * dq_cols],
        vec![0.0f32; s * dkv_cols],
        vec![0.0f32; s * dkv_cols],
    );
    backward_tiled_slabs(
        &q, &k, &v, &o, &lse, &dout, &mut tiled.0, &mut tiled.1, &mut tiled.2, s, d, spec,
        cfg, scale, None,
    );
    let mut naive = (
        vec![0.0f32; s * dq_cols],
        vec![0.0f32; s * dkv_cols],
        vec![0.0f32; s * dkv_cols],
    );
    backward_naive_slabs(
        &q, &k, &v, &dout, &mut naive.0, &mut naive.1, &mut naive.2, s, d, spec, scale,
    );
    (tiled, naive)
}

#[test]
fn tiled_backward_matches_oracle_across_spec_grid() {
    let mut seed = 500;
    for &(geom, hq, hkv) in GEOMETRIES {
        for &(causal, window) in MASKS {
            for &s in SEQS {
                for imp in [Impl::Scalar, Impl::Blocked] {
                    seed += 10;
                    let spec = Spec {
                        causal,
                        window,
                        ..Spec::full(hq, hkv)
                    };
                    let ((dq_t, dk_t, dv_t), (dq_n, dk_n, dv_n)) =
                        both_backwards(hq, hkv, s, 4, spec, imp, seed);
                    for (name, t, n) in [
                        ("dq", &dq_t, &dq_n),
                        ("dk", &dk_t, &dk_n),
                        ("dv", &dv_t, &dv_n),
                    ] {
                        let diff = max_diff(t, n);
                        assert!(
                            diff < TOL,
                            "{geom} (Hq={hq} Hkv={hkv}) causal={causal} window={window:?} \
                             s={s} {imp:?}: {name} diff {diff}"
                        );
                        assert!(t.iter().all(|x| x.is_finite()));
                    }
                }
            }
        }
    }
}

#[test]
fn tiled_backward_matches_oracle_under_sparse_patterns() {
    // The pattern axis of the gradient grid: the streaming backward's
    // tile skipping and LSE recompute must reproduce the per-element
    // oracle's gradients under every sparse built-in, both lowerings.
    use sqa::attention::MaskPattern;
    let patterns = [
        MaskPattern::Window { window: 5 },
        MaskPattern::Strided { stride: 3 },
        MaskPattern::Dilated { window: 2, stride: 3 },
        MaskPattern::SinkLocal { sinks: 2, window: 4 },
    ];
    let mut seed = 4000;
    for &pattern in &patterns {
        for &(geom, hq, hkv) in GEOMETRIES {
            for &causal in &[false, true] {
                for &s in SEQS {
                    for imp in [Impl::Scalar, Impl::Blocked] {
                        seed += 10;
                        let spec = Spec {
                            causal,
                            ..Spec::full(hq, hkv)
                        }
                        .with_pattern(pattern);
                        let ((dq_t, dk_t, dv_t), (dq_n, dk_n, dv_n)) =
                            both_backwards(hq, hkv, s, 4, spec, imp, seed);
                        for (name, t, n) in [
                            ("dq", &dq_t, &dq_n),
                            ("dk", &dk_t, &dk_n),
                            ("dv", &dv_t, &dv_n),
                        ] {
                            let diff = max_diff(t, n);
                            assert!(
                                diff < TOL,
                                "{geom} (Hq={hq} Hkv={hkv}) {pattern:?} causal={causal} \
                                 s={s} {imp:?}: {name} diff {diff}"
                            );
                            assert!(t.iter().all(|x| x.is_finite()));
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn simd_backward_matches_oracle_on_representative_slice() {
    // Representative slice of the gradient grid under Impl::Simd: dense
    // causal/bidirectional masks engage the vectorized probs+dscores fused
    // pass, the windowed mask its segment clipping (the full mask×pattern
    // sweep runs on the blocked and scalar axes above). Hosts without
    // AVX2+FMA/NEON resolve to the portable micro-kernel at runtime.
    let mut seed = 77000;
    for &(geom, hq, hkv) in &[("sqa", 4usize, 2usize), ("mha", 8, 8)] {
        for &(causal, window) in &[(true, None), (false, None), (true, Some(TILE + 3))] {
            for &s in SEQS {
                seed += 10;
                let spec = Spec {
                    causal,
                    window,
                    ..Spec::full(hq, hkv)
                };
                let ((dq_t, dk_t, dv_t), (dq_n, dk_n, dv_n)) =
                    both_backwards(hq, hkv, s, 4, spec, Impl::Simd, seed);
                for (name, t, n) in [
                    ("dq", &dq_t, &dq_n),
                    ("dk", &dk_t, &dk_n),
                    ("dv", &dv_t, &dv_n),
                ] {
                    let diff = max_diff(t, n);
                    assert!(
                        diff < TOL,
                        "{geom} (Hq={hq} Hkv={hkv}) causal={causal} window={window:?} \
                         s={s} simd: {name} diff {diff}"
                    );
                    assert!(t.iter().all(|x| x.is_finite()));
                }
            }
        }
    }
}

#[test]
fn pattern_masked_slices_get_exactly_zero_gradients() {
    // A bitmap with a fully masked query block (rows [8, 16)) and a key
    // block nobody can see (keys [8, 16)): both backwards must emit
    // exactly-zero gradients for those slices — never NaN — while the
    // live slices still carry gradient and agree between kernels.
    use sqa::attention::{pattern, BlockBitmap, MaskPattern};
    let id = pattern::register_bitmap(
        BlockBitmap::new(
            TILE,
            3,
            3,
            vec![
                true, false, false, //
                false, false, false, // query rows [8, 16): fully masked
                true, false, true, //  key column [8, 16): never visible
            ],
        )
        .unwrap(),
    );
    let (hq, hkv, s, d) = (4usize, 2usize, 3 * TILE, 4usize);
    let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Bitmap(id));
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    for imp in [Impl::Scalar, Impl::Blocked, Impl::Simd] {
        let ((dq_t, dk_t, dv_t), (dq_n, dk_n, dv_n)) =
            both_backwards(hq, hkv, s, d, spec, imp, 8800);
        for (name, t, n) in [
            ("dq", &dq_t, &dq_n),
            ("dk", &dk_t, &dk_n),
            ("dv", &dv_t, &dv_n),
        ] {
            assert!(max_diff(t, n) < TOL, "{name} {imp:?}");
            assert!(t.iter().all(|x| x.is_finite()), "{name} {imp:?}");
        }
        let masked_q = TILE * dq_cols..2 * TILE * dq_cols;
        let masked_kv = TILE * dkv_cols..2 * TILE * dkv_cols;
        assert!(dq_t[masked_q].iter().all(|&x| x == 0.0), "{imp:?}: dq");
        assert!(dk_t[masked_kv.clone()].iter().all(|&x| x == 0.0), "{imp:?}: dk");
        assert!(dv_t[masked_kv].iter().all(|&x| x == 0.0), "{imp:?}: dv");
        assert!(dq_t[..TILE * dq_cols].iter().any(|&x| x != 0.0), "{imp:?}");
        assert!(dk_t[..TILE * dkv_cols].iter().any(|&x| x != 0.0), "{imp:?}");
    }
}

#[test]
fn parallel_backward_matches_serial_bitwise_on_grid_sample() {
    // The exhaustive determinism property lives in properties.rs; here one
    // spec-grid sample pins serial == pooled through the public API.
    let pool = ThreadPool::new(4, 128);
    let (hq, hkv, s, d) = (4usize, 2usize, 3 * TILE + 5, 4usize);
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    let q = randn(s * dq_cols, 900, 0.7);
    let k = randn(s * dkv_cols, 901, 0.7);
    let v = randn(s * dkv_cols, 902, 0.7);
    let dout = randn(s * dq_cols, 903, 0.7);
    let spec = Spec::causal(hq, hkv);
    let scale = 1.0 / (d as f32).sqrt();
    let cfg = TileConfig::new(TILE, TILE).unwrap();
    let mut o = vec![0.0f32; s * dq_cols];
    let mut lse = vec![0.0f32; hq * s];
    forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, scale, None);
    let run = |pool: Option<&ThreadPool>| {
        let mut dq = vec![0.0f32; s * dq_cols];
        let mut dk = vec![0.0f32; s * dkv_cols];
        let mut dv = vec![0.0f32; s * dkv_cols];
        backward_tiled_slabs(
            &q, &k, &v, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, s, d, spec, cfg, scale,
            pool,
        );
        (dq, dk, dv)
    };
    assert_eq!(run(None), run(Some(&pool)));
}

#[test]
fn poisoned_rows_emit_zero_gradients_not_nan() {
    // A +inf score poisons its row: the forward emits zeros and lse = -inf,
    // and the streaming backward must emit exactly zero attention grads
    // for that row — never NaN. (The scalar oracle NaNs here, which is why
    // this case is pinned against the forward contract instead.)
    let (hq, hkv, s, d) = (1usize, 1usize, 6usize, 4usize);
    let q = vec![f32::MAX; s * d];
    let k = vec![f32::MAX; s * d];
    let v = randn(s * d, 77, 0.5);
    let dout = randn(s * d, 78, 0.5);
    let spec = Spec::causal(hq, hkv);
    let cfg = TileConfig::new(4, 4).unwrap();
    let mut o = vec![f32::NAN; s * d];
    let mut lse = vec![f32::NAN; s];
    forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, 1.0, None);
    assert!(o.iter().all(|&x| x == 0.0));
    assert!(lse.iter().all(|&x| x == f32::NEG_INFINITY));
    let (mut dq, mut dk, mut dv) =
        (vec![0.0f32; s * d], vec![0.0f32; s * d], vec![0.0f32; s * d]);
    backward_tiled_slabs(
        &q, &k, &v, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, s, d, spec, cfg, 1.0, None,
    );
    assert!(dq.iter().all(|&x| x == 0.0), "{dq:?}");
    assert!(dk.iter().all(|&x| x == 0.0));
    assert!(dv.iter().all(|&x| x == 0.0));
}

#[test]
fn train_state_equivalent_between_linalg_impls_on_tiled_backward() {
    // One fused step through the streaming backward, blocked vs scalar
    // GEMMs end to end: losses and the updated state must agree to 1e-4
    // (the linalg analogue of linalg_differential.rs's train-state test,
    // now exercising the new backward path).
    let b = NativeBackend::new();
    for variant in ["sqa", "xsqa"] {
        let params = b.init_params("tiny", variant, 51).unwrap();
        let p = params.len();
        let (bs, s) = b.train_shape("tiny", variant).unwrap();
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 37 + 3) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t * 5 + 11) % 2048).collect();
        let run = |impl_: &str| -> (f32, Vec<f32>) {
            let mut state = vec![0.0f32; 3 * p + 2];
            state[..p].copy_from_slice(&params);
            let (loss, _) = b
                .train_step_impl(
                    impl_, "tiny", variant, &mut state, 1, 1e-2, &tokens, &targets, bs, s,
                )
                .unwrap();
            (loss, state)
        };
        let (loss_b, state_b) = run("tiled+blocked");
        let (loss_s, state_s) = run("tiled+scalar");
        assert!(
            (loss_b - loss_s).abs() < 1e-4,
            "tiny/{variant}: loss {loss_b} vs {loss_s}"
        );
        let diff = max_diff(&state_b, &state_s);
        assert!(diff < TOL, "tiny/{variant}: train state diverges by {diff}");
    }
}

#[test]
fn model_gradients_match_between_kernels() {
    // Full-model gradients (loss_and_grad), streaming backward vs the
    // scalar oracle, across variants: the end-to-end composition of the
    // slab-level agreement above with the shared GEMM reductions.
    let b = NativeBackend::new();
    let (bs, s) = (1usize, 12usize);
    let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 89 + 5) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t * 3 + 7) % 2048).collect();
    for variant in ["mha", "gqa", "sqa", "xsqa", "swsqa"] {
        let params = b.init_params("tiny", variant, 61).unwrap();
        let (loss_t, grad_t) = b
            .loss_and_grad("tiled", "tiny", variant, &params, &tokens, &targets, bs, s)
            .unwrap();
        let (loss_n, grad_n) = b
            .loss_and_grad("naive", "tiny", variant, &params, &tokens, &targets, bs, s)
            .unwrap();
        assert!(
            (loss_t - loss_n).abs() < 1e-3,
            "tiny/{variant}: loss {loss_t} vs {loss_n}"
        );
        let diff = max_diff(&grad_t, &grad_n);
        assert!(diff < 2e-4, "tiny/{variant}: grads diverge by {diff}");
        assert!(grad_t.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn train_step_loss_still_matches_eval_through_both_kernels() {
    // The fused step's recorded (pre-update) loss must agree with eval on
    // the same params for both lowerings — the train forward and the
    // serving forward stay the same function under the refactored
    // checkpointing.
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "sqa", 13).unwrap();
    let p = params.len();
    let (bs, s) = (2usize, 12usize);
    let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 13 + 7) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t + 1) % 2048).collect();
    let (eval_loss, _) = b.eval("tiny", "sqa", &params, &tokens, &targets, bs, s).unwrap();
    for impl_ in ["tiled", "naive", "tiled+scalar"] {
        let mut state = vec![0.0f32; 3 * p + 2];
        state[..p].copy_from_slice(&params);
        let (train_loss, _) = b
            .train_step_impl(impl_, "tiny", "sqa", &mut state, 1, 1e-3, &tokens, &targets, bs, s)
            .unwrap();
        assert!(
            (train_loss - eval_loss).abs() < 2e-3,
            "{impl_}: train {train_loss} vs eval {eval_loss}"
        );
        assert_ne!(&state[..p], &params[..], "{impl_}: step did not move params");
    }
}

// ---- finite differences -------------------------------------------------

/// Central-difference check of the analytic gradient, parameter block by
/// parameter block (embed, every layer's Wq/Wk/Wv/Wo, lm_head, lm_bias).
/// Probes the top-|g| indices of each block: f32 loss noise (~1e-6) over
/// the 2h step bounds the FD error near 5e-4, so only gradients comfortably
/// above that are meaningfully checkable at 1e-2 relative.
fn finite_difference_check(variant: &str, impl_: &str) {
    let b = NativeBackend::new();
    let (bs, s) = (1usize, 6usize);
    let tokens: Vec<i32> = (0..s).map(|i| ((i * 389 + 41) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t * 11 + 29) % 2048).collect();
    let params = b.init_params("tiny", variant, 71).unwrap();
    let (_, grad) = b
        .loss_and_grad(impl_, "tiny", variant, &params, &tokens, &targets, bs, s)
        .unwrap();
    let loss_at = |params: &[f32]| -> f32 {
        b.loss_and_grad(impl_, "tiny", variant, params, &tokens, &targets, bs, s)
            .unwrap()
            .0
    };
    let h = 1e-3f32;
    let entry = b.variant("tiny", variant).unwrap();
    for block in &entry.params {
        let len: usize = block.shape.iter().product();
        // Top-6 gradient magnitudes of this block.
        let mut idx: Vec<usize> = (0..len).collect();
        idx.sort_by(|&a, &b2| {
            grad[block.offset + b2]
                .abs()
                .partial_cmp(&grad[block.offset + a].abs())
                .unwrap()
        });
        let mut checked = 0;
        for &i in idx.iter().take(6) {
            let gi = grad[block.offset + i];
            let mut p = params.clone();
            p[block.offset + i] = params[block.offset + i] + h;
            let up = loss_at(&p);
            p[block.offset + i] = params[block.offset + i] - h;
            let down = loss_at(&p);
            let fd = (up - down) / (2.0 * h);
            let err = (fd - gi).abs();
            // 1e-2 relative, with an absolute floor absorbing the f32 loss
            // rounding (~1e-6) amplified by the 2h divisor (~5e-4).
            assert!(
                err <= 1e-2 * fd.abs().max(gi.abs()) + 3e-3,
                "{variant}/{impl_} {}[{i}]: analytic {gi} vs fd {fd} (err {err})",
                block.name
            );
            checked += 1;
        }
        assert!(checked > 0, "{}: nothing checked", block.name);
    }
}

#[test]
fn finite_differences_confirm_tiled_gradients_mha() {
    finite_difference_check("mha", "tiled");
}

#[test]
fn finite_differences_confirm_tiled_gradients_xsqa() {
    finite_difference_check("xsqa", "tiled");
}

#[test]
fn finite_differences_confirm_oracle_gradients_xsqa() {
    finite_difference_check("xsqa", "naive");
}

#[test]
fn train_step_impl_rejects_unknown_lowerings() {
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "sqa", 1).unwrap();
    let p = params.len();
    let mut state = vec![0.0f32; 3 * p + 2];
    state[..p].copy_from_slice(&params);
    let err = b
        .train_step_impl("pallas", "tiny", "sqa", &mut state, 1, 1e-3, &[1, 2], &[2, 3], 1, 2)
        .unwrap_err();
    assert!(err.to_string().contains("pallas"), "{err:#}");
    assert!(b
        .loss_and_grad("pallas", "tiny", "sqa", &params, &[1, 2], &[2, 3], 1, 2)
        .is_err());
    // The kernel enum itself still parses both names (sanity anchor).
    assert_eq!(Kernel::parse("tiled").unwrap(), Kernel::Tiled);
}
