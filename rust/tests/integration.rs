//! Integration tests over the real artifacts: runtime + training + data.
//!
//! These require `make artifacts` to have run (skipped with a clear panic
//! otherwise). They exercise the full L1→L2→L3 composition: HLO text load,
//! PJRT compile, device-resident state, fused train steps, eval, and the
//! differential check of XLA logits vs the pure-Rust attention oracle.

use sqa::attention::{attention, tensor::Tensor, Spec};
use sqa::config::TrainConfig;
use sqa::runtime::{Kind, ModelState, Runtime};
use sqa::train::Trainer;
use std::sync::OnceLock;

fn rt() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::new("artifacts").expect("artifacts missing — run `make artifacts` first")
    })
}

#[test]
fn manifest_has_all_families_and_variants() {
    let m = rt().manifest();
    for fam in ["tiny", "dense_sm", "moe_sm", "bench"] {
        assert!(m.families.contains_key(fam), "{fam} missing");
    }
    for v in ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa"] {
        assert!(m.variant("dense_sm", v).is_ok(), "dense_sm/{v}");
    }
    for v in ["gqa", "mqa", "sqa", "ssqa", "xsqa"] {
        assert!(m.variant("moe_sm", v).is_ok(), "moe_sm/{v}");
    }
    // Table 3 needs fwd buckets for all 7 variants.
    for v in ["xsqa", "sqa", "ssqa", "swa", "mqa", "gqa", "mha"] {
        assert!(
            !m.fwd_seqs("bench", v, "xla").is_empty(),
            "bench/{v} has no fwd buckets"
        );
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let a = ModelState::init(rt(), "tiny", "sqa", 5).unwrap();
    let b = ModelState::init(rt(), "tiny", "sqa", 5).unwrap();
    let c = ModelState::init(rt(), "tiny", "sqa", 6).unwrap();
    let (va, vb, vc) = (
        a.to_host(rt()).unwrap(),
        b.to_host(rt()).unwrap(),
        c.to_host(rt()).unwrap(),
    );
    assert_eq!(va, vb);
    assert_ne!(va, vc);
    // Healthy init: finite, non-degenerate spread.
    assert!(va.iter().all(|x| x.is_finite()));
    let nonzero = va.iter().filter(|x| **x != 0.0).count();
    assert!(nonzero > va.len() / 2);
}

#[test]
fn fwd_artifact_runs_and_is_deterministic() {
    let state = ModelState::init(rt(), "tiny", "sqa", 1).unwrap();
    let a = rt()
        .manifest()
        .find("tiny", "sqa", Kind::Fwd, Some(64), None)
        .unwrap();
    let exe = rt().compile_artifact(a).unwrap();
    let (b, s) = (a.batch.unwrap(), a.seq.unwrap());
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 2000) as i32).collect();
    let tbuf = rt().buf_i32(&tokens, &[b, s]).unwrap();
    let o1 = rt().to_vec_f32(&rt().execute1(&exe, &[&state.params, &tbuf]).unwrap()).unwrap();
    let o2 = rt().to_vec_f32(&rt().execute1(&exe, &[&state.params, &tbuf]).unwrap()).unwrap();
    assert_eq!(o1, o2);
    assert!(o1.iter().all(|x| x.is_finite()));
    let vocab = rt().manifest().family("tiny").unwrap().dims.vocab;
    assert_eq!(o1.len(), b * s * vocab);
}

#[test]
fn training_reduces_loss_tiny_sqa() {
    let mut cfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 60,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        seed: 3,
        ..TrainConfig::default()
    };
    cfg.schedule.base_lr = 1e-3;
    cfg.schedule.total_steps = 60;
    cfg.schedule.warmup_steps = 6;
    let mut t = Trainer::new(rt(), cfg).unwrap();
    let first = t.step_once().unwrap().loss;
    for _ in 0..59 {
        t.step_once().unwrap();
    }
    let last = t.history.last().unwrap().loss;
    assert!(
        last < first - 0.5,
        "loss did not drop: {first} -> {last}"
    );
    // ln(vocab) sanity at start.
    assert!((first - (2048f32).ln()).abs() < 1.0, "{first}");
}

#[test]
fn train_state_stays_consistent_with_eval() {
    // eval(params) after N steps must match the train-step's own loss scale.
    let cfg = TrainConfig {
        family: "tiny".into(),
        variant: "xsqa".into(),
        steps: 10,
        eval_every: 0,
        log_every: 0,
        seed: 11,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(rt(), cfg).unwrap();
    for _ in 0..10 {
        t.step_once().unwrap();
    }
    let (val_loss, val_acc) = t.evaluate(4).unwrap();
    let train_loss = t.history.last().unwrap().loss;
    assert!(val_loss.is_finite() && val_acc >= 0.0);
    assert!((val_loss - train_loss).abs() < 2.0, "{val_loss} vs {train_loss}");
}

#[test]
fn checkpoint_roundtrip() {
    let dir = std::env::temp_dir().join(format!("sqa_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 3,
        eval_every: 0,
        log_every: 0,
        seed: 9,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(rt(), cfg).unwrap();
    for _ in 0..3 {
        t.step_once().unwrap();
    }
    let path = t.save_checkpoint(dir.to_str().unwrap()).unwrap();
    let before = t.params_to_host().unwrap();
    let (state, step) = ModelState::load(rt(), "tiny", "sqa", &path).unwrap();
    assert_eq!(step, 3);
    assert_eq!(state.to_host(rt()).unwrap(), before);
    // Wrong variant must be rejected.
    assert!(ModelState::load(rt(), "tiny", "mha", &path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pallas_impl_train_artifact_composes() {
    // The tiny/sqa pallas-impl train artifact must execute and reduce loss:
    // proves the Pallas kernel (fwd) + custom-vjp (bwd) lowering round-trips
    // through HLO text into the PJRT runtime.
    let m = rt().manifest();
    let a = m
        .find("tiny", "sqa", Kind::Train, None, Some("pallas"))
        .expect("pallas train artifact");
    let exe = rt().compile_artifact(a).unwrap();
    let entry = m.variant("tiny", "sqa").unwrap();
    let p = entry.n_params;
    let init = ModelState::init(rt(), "tiny", "sqa", 2).unwrap();
    let params = init.to_host(rt()).unwrap();
    let mut state_host = vec![0.0f32; 3 * p + 2];
    state_host[..p].copy_from_slice(&params);
    let mut state = rt().buf_f32(&state_host, &[3 * p + 2]).unwrap();

    let (b, s) = (a.batch.unwrap(), a.seq.unwrap());
    let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 31 + 7) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t + 1) % 2048).collect();
    let tbuf = rt().buf_i32(&tokens, &[b, s]).unwrap();
    let gbuf = rt().buf_i32(&targets, &[b, s]).unwrap();

    let mut losses = Vec::new();
    for step in 1..=3 {
        let sb = rt().buf_scalar_i32(step).unwrap();
        let lb = rt().buf_scalar_f32(1e-3).unwrap();
        state = rt().execute1(&exe, &[&state, &sb, &lb, &tbuf, &gbuf]).unwrap();
        let metrics = rt().slice_f32(&state, 3 * p + 2, 3 * p, 3 * p + 2).unwrap();
        losses.push(rt().to_vec_f32(&metrics).unwrap()[0]);
    }
    assert!(
        losses[2] < losses[0],
        "pallas train losses did not decrease: {losses:?}"
    );
}

#[test]
fn xla_logits_match_native_attention_oracle() {
    // Differential test: run the attention core natively (pure Rust) and
    // through an equivalent dot-product computation of the same geometry.
    // We validate the *shared semantics* via a synthetic case: uniform
    // queries/keys make attention an average of values; both the oracle and
    // a device computation must agree with the analytic result.
    let (b, hq, hkv, s, d) = (1usize, 4usize, 2usize, 16usize, 8usize);
    let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; b * hq * s * d]).unwrap();
    let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; b * hkv * s * d]).unwrap();
    let mut vals = vec![0.0f32; b * hkv * s * d];
    for (i, v) in vals.iter_mut().enumerate() {
        *v = (i % 7) as f32 - 3.0;
    }
    let v = Tensor::from_vec(&[b, hkv, s, d], vals).unwrap();
    let out = attention(&q, &k, &v, Spec::full(hq, hkv)).unwrap();
    for h in 0..hq {
        for dd in 0..d {
            let mean: f32 = (0..s).map(|j| v.get4(0, h / 2, j, dd)).sum::<f32>() / s as f32;
            for i in 0..s {
                assert!((out.get4(0, h, i, dd) - mean).abs() < 1e-5);
            }
        }
    }
}

#[test]
fn eval_artifact_matches_train_metrics_tail() {
    // After one train step, the loss in the state tail must equal the loss
    // the eval artifact computes on the same batch with the *pre-step*
    // params (train records the loss at the step's forward pass).
    let m = rt().manifest();
    let a_train = m.find("tiny", "ssqa", Kind::Train, None, None).unwrap();
    let a_eval = m.find("tiny", "ssqa", Kind::Eval, None, None).unwrap();
    let train_exe = rt().compile_artifact(a_train).unwrap();
    let eval_exe = rt().compile_artifact(a_eval).unwrap();
    let entry = m.variant("tiny", "ssqa").unwrap();
    let p = entry.n_params;

    let init = ModelState::init(rt(), "tiny", "ssqa", 21).unwrap();
    let params_host = init.to_host(rt()).unwrap();
    let mut state_host = vec![0.0f32; 3 * p + 2];
    state_host[..p].copy_from_slice(&params_host);
    let state = rt().buf_f32(&state_host, &[3 * p + 2]).unwrap();

    let (b, s) = (a_train.batch.unwrap(), a_train.seq.unwrap());
    let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 13 + 5) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t * 7 + 1) % 2048).collect();
    let tbuf = rt().buf_i32(&tokens, &[b, s]).unwrap();
    let gbuf = rt().buf_i32(&targets, &[b, s]).unwrap();

    // Train-step loss (computed on pre-update params).
    let sb = rt().buf_scalar_i32(1).unwrap();
    let lb = rt().buf_scalar_f32(1e-3).unwrap();
    let new_state = rt()
        .execute1(&train_exe, &[&state, &sb, &lb, &tbuf, &gbuf])
        .unwrap();
    let tail = rt()
        .slice_f32(&new_state, 3 * p + 2, 3 * p, 3 * p + 2)
        .unwrap();
    let train_loss = rt().to_vec_f32(&tail).unwrap()[0];

    // Eval loss with the original params on the same batch.
    let out = rt()
        .execute1(&eval_exe, &[&init.params, &tbuf, &gbuf])
        .unwrap();
    let eval_loss = rt().to_vec_f32(&out).unwrap()[0];
    assert!(
        (train_loss - eval_loss).abs() < 1e-4,
        "train tail {train_loss} vs eval {eval_loss}"
    );
}

#[test]
fn slicer_extracts_correct_ranges() {
    let data: Vec<f32> = (0..100).map(|x| x as f32).collect();
    let buf = rt().buf_f32(&data, &[100]).unwrap();
    let s = rt().slice_f32(&buf, 100, 10, 15).unwrap();
    assert_eq!(rt().to_vec_f32(&s).unwrap(), vec![10.0, 11.0, 12.0, 13.0, 14.0]);
    assert!(rt().slice_f32(&buf, 100, 90, 101).is_err());
}
