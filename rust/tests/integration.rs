//! Integration tests over the default (native) backend: catalog, runtime,
//! training, data — no Python, no XLA, no artifacts required.
//!
//! The equivalence suite at the bottom differentially tests the backend's
//! forward pass against a from-scratch reference implementation written in
//! this file (independent loops, independent softmax), across the MHA
//! (Hq = Hkv), GQA-style grouped, and MQA (Hkv = 1) head geometries.

use sqa::config::TrainConfig;
use sqa::runtime::{checkpoint, Backend, FamilyEntry, NativeBackend, VariantEntry};
use sqa::train::Trainer;
use std::sync::{Arc, OnceLock};

fn backend() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| Arc::new(NativeBackend::new()))
}

#[test]
fn catalog_has_all_families_and_variants() {
    let b = backend();
    for fam in ["tiny", "dense_sm", "moe_sm", "bench"] {
        assert!(b.families().contains_key(fam), "{fam} missing");
    }
    for v in ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa"] {
        assert!(b.variant("dense_sm", v).is_ok(), "dense_sm/{v}");
    }
    for v in ["gqa", "mqa", "sqa", "ssqa", "xsqa"] {
        assert!(b.variant("moe_sm", v).is_ok(), "moe_sm/{v}");
    }
    // Table 3 needs fwd buckets for all 7 variants.
    for v in ["xsqa", "sqa", "ssqa", "swa", "mqa", "gqa", "mha"] {
        assert!(
            !b.fwd_buckets("bench", v).is_empty(),
            "bench/{v} has no fwd buckets"
        );
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let b = backend();
    let va = b.init_params("tiny", "sqa", 5).unwrap();
    let vb = b.init_params("tiny", "sqa", 5).unwrap();
    let vc = b.init_params("tiny", "sqa", 6).unwrap();
    assert_eq!(va, vb);
    assert_ne!(va, vc);
    // Healthy init: finite, non-degenerate spread.
    assert!(va.iter().all(|x| x.is_finite()));
    let nonzero = va.iter().filter(|x| **x != 0.0).count();
    assert!(nonzero > va.len() / 2);
    assert_eq!(va.len(), b.variant("tiny", "sqa").unwrap().n_params);
}

#[test]
fn forward_runs_and_is_deterministic() {
    let b = backend();
    let params = b.init_params("tiny", "sqa", 1).unwrap();
    let (batch, seq) = (b.fwd_batch("tiny", "sqa", 64).unwrap(), 64usize);
    let tokens: Vec<i32> = (0..batch * seq).map(|i| (i % 2000) as i32).collect();
    let o1 = b.forward("tiny", "sqa", &params, &tokens, batch, seq).unwrap();
    let o2 = b.forward("tiny", "sqa", &params, &tokens, batch, seq).unwrap();
    assert_eq!(o1, o2);
    assert!(o1.iter().all(|x| x.is_finite()));
    let vocab = b.family("tiny").unwrap().dims.vocab;
    assert_eq!(o1.len(), batch * seq * vocab);
}

#[test]
fn training_reduces_loss_tiny_sqa() {
    let mut cfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 60,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        seed: 3,
        ..TrainConfig::default()
    };
    cfg.schedule.base_lr = 1e-2;
    cfg.schedule.total_steps = 60;
    cfg.schedule.warmup_steps = 6;
    let mut t = Trainer::new(backend(), cfg).unwrap();
    let first = t.step_once().unwrap().loss;
    for _ in 0..59 {
        t.step_once().unwrap();
    }
    let best_late = t.history[50..]
        .iter()
        .map(|h| h.loss)
        .fold(f32::MAX, f32::min);
    assert!(
        best_late < first - 1.0,
        "loss did not drop: {first} -> best of last 10 {best_late}"
    );
    // ln(vocab) sanity at start.
    assert!((first - (2048f32).ln()).abs() < 1.0, "{first}");
}

#[test]
fn train_state_stays_consistent_with_eval() {
    // eval(params) after N steps must match the train-step's own loss scale.
    let cfg = TrainConfig {
        family: "tiny".into(),
        variant: "xsqa".into(),
        steps: 10,
        eval_every: 0,
        log_every: 0,
        seed: 11,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(backend(), cfg).unwrap();
    for _ in 0..10 {
        t.step_once().unwrap();
    }
    let (val_loss, val_acc) = t.evaluate(4).unwrap();
    let train_loss = t.history.last().unwrap().loss;
    assert!(val_loss.is_finite() && val_acc >= 0.0);
    assert!((val_loss - train_loss).abs() < 2.0, "{val_loss} vs {train_loss}");
}

#[test]
fn checkpoint_roundtrip() {
    let dir = std::env::temp_dir().join(format!("sqa_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 3,
        eval_every: 0,
        log_every: 0,
        seed: 9,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(backend(), cfg).unwrap();
    for _ in 0..3 {
        t.step_once().unwrap();
    }
    let path = t.save_checkpoint(dir.to_str().unwrap()).unwrap();
    let before = t.params_to_host().unwrap();
    let (params, step) = checkpoint::load(backend().as_ref(), "tiny", "sqa", &path).unwrap();
    assert_eq!(step, 3);
    assert_eq!(params, before);
    // Wrong variant must be rejected.
    assert!(checkpoint::load(backend().as_ref(), "tiny", "mha", &path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_loss_tail_matches_eval_on_same_batch() {
    // After one train step, the loss in the state tail must equal the loss
    // eval computes on the same batch with the *pre-step* params (the step
    // records the loss at its forward pass). This pins the fused
    // forward+backward implementation to the forward-only path.
    let b = backend();
    let entry = b.variant("tiny", "ssqa").unwrap();
    let p = entry.n_params;
    let params = b.init_params("tiny", "ssqa", 21).unwrap();
    let mut state = vec![0.0f32; 3 * p + 2];
    state[..p].copy_from_slice(&params);

    let (bs, s) = b.train_shape("tiny", "ssqa").unwrap();
    let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 13 + 5) % 2048) as i32).collect();
    let targets: Vec<i32> = tokens.iter().map(|t| (t * 7 + 1) % 2048).collect();

    let (train_loss, _) = b
        .train_step("tiny", "ssqa", &mut state, 1, 1e-3, &tokens, &targets, bs, s)
        .unwrap();
    assert_eq!(state[3 * p], train_loss);

    let (eval_loss, _) = b
        .eval("tiny", "ssqa", &params, &tokens, &targets, bs, s)
        .unwrap();
    assert!(
        (train_loss - eval_loss).abs() < 2e-3,
        "train tail {train_loss} vs eval {eval_loss}"
    );
}

// ---------------------------------------------------------------------------
// Native-backend equivalence vs an independent reference implementation
// ---------------------------------------------------------------------------

fn named_param<'a>(entry: &VariantEntry, params: &'a [f32], name: &str) -> &'a [f32] {
    let spec = entry
        .params
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("no param {name}"));
    &params[spec.offset..spec.offset + spec.size()]
}

/// From-scratch forward pass of the catalog's reference model: embedding,
/// residual causal-attention blocks with Hq/Hkv head grouping, LM head.
/// Shares *no* code with the backend (its own projections, masking and
/// softmax), so agreement is a real differential check.
fn ref_logits(
    fam: &FamilyEntry,
    entry: &VariantEntry,
    params: &[f32],
    tokens: &[i32],
) -> Vec<f32> {
    let (d, dh) = (fam.dims.d_model, fam.dims.d_head);
    let (hq, hkv) = (entry.cfg.hq, entry.cfg.hkv);
    let group = hq / hkv;
    let s = tokens.len();
    let vocab = fam.dims.vocab;
    let scale = 1.0 / (dh as f32).sqrt();
    assert!(fam.causal && entry.cfg.window.is_none(), "ref covers causal full");

    let embed = named_param(entry, params, "embed");
    let mut x = vec![0.0f32; s * d];
    for (i, &t) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(&embed[t as usize * d..(t as usize + 1) * d]);
    }

    for l in 0..fam.dims.n_layers {
        let wq = named_param(entry, params, &format!("l{l}.wq"));
        let wk = named_param(entry, params, &format!("l{l}.wk"));
        let wv = named_param(entry, params, &format!("l{l}.wv"));
        let wo = named_param(entry, params, &format!("l{l}.wo"));
        let proj = |w: &[f32], heads: usize| -> Vec<f32> {
            let cols = heads * dh;
            let mut out = vec![0.0f32; s * cols];
            for i in 0..s {
                for c in 0..cols {
                    let mut acc = 0.0f32;
                    for p in 0..d {
                        acc += x[i * d + p] * w[p * cols + c];
                    }
                    out[i * cols + c] = acc;
                }
            }
            out
        };
        let q = proj(wq, hq);
        let k = proj(wk, hkv);
        let v = proj(wv, hkv);
        let mut o = vec![0.0f32; s * hq * dh];
        for h in 0..hq {
            let kvh = h / group; // head grouping under test
            for i in 0..s {
                // Causal scores 0..=i, plain two-pass softmax.
                let mut scores = Vec::with_capacity(i + 1);
                let mut maxv = f32::NEG_INFINITY;
                for j in 0..=i {
                    let mut acc = 0.0f32;
                    for dd in 0..dh {
                        acc += q[i * hq * dh + h * dh + dd] * k[j * hkv * dh + kvh * dh + dd];
                    }
                    let sc = acc * scale;
                    scores.push(sc);
                    maxv = maxv.max(sc);
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                for (j, sc) in scores.iter().enumerate() {
                    let w = sc / denom;
                    for dd in 0..dh {
                        o[i * hq * dh + h * dh + dd] += w * v[j * hkv * dh + kvh * dh + dd];
                    }
                }
            }
        }
        // Residual: x += o @ wo.
        for i in 0..s {
            for c in 0..d {
                let mut acc = 0.0f32;
                for p in 0..hq * dh {
                    acc += o[i * hq * dh + p] * wo[p * d + c];
                }
                x[i * d + c] += acc;
            }
        }
    }

    let lm_head = named_param(entry, params, "lm_head");
    let lm_bias = named_param(entry, params, "lm_bias");
    let mut logits = vec![0.0f32; s * vocab];
    for i in 0..s {
        for c in 0..vocab {
            let mut acc = lm_bias[c];
            for p in 0..d {
                acc += x[i * d + p] * lm_head[p * vocab + c];
            }
            logits[i * vocab + c] = acc;
        }
    }
    logits
}

fn assert_matches_reference(variant: &str) {
    let b = backend();
    let fam = b.family("tiny").unwrap().clone();
    let entry = b.variant("tiny", variant).unwrap().clone();
    let params = b.init_params("tiny", variant, 17).unwrap();
    let tokens: Vec<i32> = (0..8).map(|i| ((i * 523 + 91) % 2048) as i32).collect();
    let got = b
        .forward("tiny", variant, &params, &tokens, 1, tokens.len())
        .unwrap();
    let want = ref_logits(&fam, &entry, &params, &tokens);
    assert_eq!(got.len(), want.len());
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        worst = worst.max((g - w).abs());
    }
    assert!(
        worst < 1e-3,
        "tiny/{variant}: backend diverges from reference by {worst}"
    );
}

#[test]
fn native_matches_reference_mha() {
    // Hq == Hkv: every query head owns its kv head.
    assert_matches_reference("mha");
}

#[test]
fn native_matches_reference_gqa_grouping() {
    // tiny/sqa is (Hq=4, Hkv=2): two query heads share each kv head.
    assert_matches_reference("sqa");
}

#[test]
fn native_matches_reference_mqa() {
    // Hkv = 1: all query heads read the single kv head.
    assert_matches_reference("mqa");
}
