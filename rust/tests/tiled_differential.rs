//! Differential suite: the tiled streaming kernel vs the naive S×S oracle
//! across the full spec grid — every head geometry of the paper's variant
//! zoo, both mask kinds, and sequence lengths chosen to straddle the tile
//! boundaries (S = 1, T−1, T, T+1, 3·T+5 for tile size T).
//!
//! Tolerance is 1e-4: the two kernels share the math but not the summation
//! order (online rescaling vs two-pass softmax), so agreement here pins the
//! streaming algebra, the mask-aware block skipping, and the SQA head
//! sharing all at once.

use sqa::attention::tiled::{attention_tiled_cfg, attention_tiled_parallel, TileConfig};
use sqa::attention::{attention, attention_with, tensor::Tensor, Kernel, Spec};
use sqa::util::rng::Pcg64;
use sqa::util::threadpool::ThreadPool;

const TILE: usize = 8;
const TOL: f32 = 1e-4;

fn randn(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
}

/// (label, Hq, Hkv) — the head-geometry grid from the paper:
/// MHA (Hq = Hkv = H), GQA grouping, MQA (Hkv = 1), SQA (Hq halved), and
/// extreme SQA (Hq = Hkv = 2 vs an 8-head baseline).
const GEOMETRIES: &[(&str, usize, usize)] = &[
    ("mha", 8, 8),
    ("gqa", 8, 2),
    ("mqa", 4, 1),
    ("sqa", 4, 2),
    ("xsqa", 2, 2),
];

/// (causal, window) mask grid.
const MASKS: &[(bool, Option<usize>)] = &[
    (false, None),          // full bidirectional
    (true, None),           // causal
    (false, Some(3)),       // symmetric sliding window
    (true, Some(3)),        // causal sliding window
    (true, Some(TILE + 3)), // window wider than a tile
];

/// Sequence lengths straddling the tile size: 1, T−1, T, T+1, 3·T+5.
const SEQS: &[usize] = &[1, TILE - 1, TILE, TILE + 1, 3 * TILE + 5];

fn check_grid(run: impl Fn(&Tensor, &Tensor, &Tensor, Spec) -> Tensor, label: &str) {
    let mut seed = 100;
    for &(geom, hq, hkv) in GEOMETRIES {
        for &(causal, window) in MASKS {
            for &s in SEQS {
                seed += 1;
                let mut rng = Pcg64::new(seed);
                let d = 4;
                let q = randn(&[2, hq, s, d], &mut rng);
                let k = randn(&[2, hkv, s, d], &mut rng);
                let v = randn(&[2, hkv, s, d], &mut rng);
                let spec = Spec {
                    causal,
                    window,
                    ..Spec::full(hq, hkv)
                };
                let want = attention(&q, &k, &v, spec).unwrap();
                let got = run(&q, &k, &v, spec);
                let diff = want.max_abs_diff(&got);
                assert!(
                    diff < TOL,
                    "{label}: {geom} (Hq={hq} Hkv={hkv}) causal={causal} \
                     window={window:?} s={s}: diff {diff}"
                );
                assert!(got.data.iter().all(|x| x.is_finite()));
            }
        }
    }
}

#[test]
fn tiled_matches_oracle_across_spec_grid() {
    let cfg = TileConfig::new(TILE, TILE).unwrap();
    check_grid(
        |q, k, v, spec| attention_tiled_cfg(q, k, v, spec, cfg).unwrap(),
        "serial",
    );
}

#[test]
fn tiled_matches_oracle_with_rectangular_tiles() {
    // q_tile != k_tile, and deliberately awkward sizes.
    let cfg = TileConfig::new(5, 3).unwrap();
    check_grid(
        |q, k, v, spec| attention_tiled_cfg(q, k, v, spec, cfg).unwrap(),
        "rect",
    );
}

#[test]
fn parallel_tiled_matches_oracle_across_spec_grid() {
    let pool = ThreadPool::new(4, 128);
    let cfg = TileConfig::new(TILE, TILE).unwrap();
    check_grid(
        |q, k, v, spec| attention_tiled_parallel(q, k, v, spec, cfg, &pool).unwrap(),
        "parallel",
    );
}

#[test]
fn tiled_matches_oracle_under_sparse_patterns_across_grid() {
    // The pattern axis of the differential grid: every sparse built-in ×
    // every head geometry × tile-straddling S × both linalg lowerings,
    // causal and bidirectional. The naive oracle applies patterns
    // per-element; the tiled kernel must agree through its tile skipping.
    use sqa::attention::MaskPattern;
    use sqa::linalg;
    let patterns = [
        MaskPattern::Window { window: 5 },
        MaskPattern::Strided { stride: 3 },
        MaskPattern::Dilated { window: 2, stride: 3 },
        MaskPattern::SinkLocal { sinks: 2, window: 4 },
    ];
    let mut seed = 9000;
    for &pattern in &patterns {
        for &(geom, hq, hkv) in GEOMETRIES {
            for &causal in &[false, true] {
                for &s in SEQS {
                    seed += 1;
                    let mut rng = Pcg64::new(seed);
                    let d = 4;
                    let q = randn(&[2, hq, s, d], &mut rng);
                    let k = randn(&[2, hkv, s, d], &mut rng);
                    let v = randn(&[2, hkv, s, d], &mut rng);
                    let spec = Spec {
                        causal,
                        ..Spec::full(hq, hkv)
                    }
                    .with_pattern(pattern);
                    let want = attention(&q, &k, &v, spec).unwrap();
                    for imp in [linalg::Impl::Blocked, linalg::Impl::Scalar] {
                        let cfg = TileConfig::new(TILE, TILE).unwrap().with_linalg(imp);
                        let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
                        let diff = want.max_abs_diff(&got);
                        assert!(
                            diff < TOL,
                            "{geom} (Hq={hq} Hkv={hkv}) {pattern:?} causal={causal} \
                             s={s} {imp:?}: diff {diff}"
                        );
                        assert!(got.data.iter().all(|x| x.is_finite()));
                    }
                }
            }
        }
    }
}

#[test]
fn simd_lowering_matches_oracle_on_representative_slice() {
    // Representative slice of the grid under Impl::Simd: the dense causal
    // and windowed masks engage the vectorized online-softmax fast path;
    // the strided pattern exercises the masked scalar fallback under the
    // same lowering (the full pattern×geometry sweep runs on the blocked
    // and scalar axes above). Hosts without AVX2+FMA/NEON degrade to the
    // portable micro-kernel at runtime, so this stays a valid check there.
    use sqa::attention::MaskPattern;
    use sqa::linalg;
    let pool = ThreadPool::new(4, 128);
    let mut seed = 31000;
    for &(geom, hq, hkv) in &[("sqa", 4usize, 2usize), ("mha", 8, 8)] {
        for &s in SEQS {
            for &(causal, window, pattern) in &[
                (true, None, None),
                (false, None, None),
                (true, Some(TILE + 3), None),
                (true, None, Some(MaskPattern::Strided { stride: 3 })),
            ] {
                seed += 1;
                let mut rng = Pcg64::new(seed);
                let d = 4;
                let q = randn(&[2, hq, s, d], &mut rng);
                let k = randn(&[2, hkv, s, d], &mut rng);
                let v = randn(&[2, hkv, s, d], &mut rng);
                let mut spec = Spec {
                    causal,
                    window,
                    ..Spec::full(hq, hkv)
                };
                if let Some(p) = pattern {
                    spec = spec.with_pattern(p);
                }
                let want = attention(&q, &k, &v, spec).unwrap();
                let cfg = TileConfig::new(TILE, TILE)
                    .unwrap()
                    .with_linalg(linalg::Impl::Simd);
                let serial = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
                let diff = want.max_abs_diff(&serial);
                assert!(
                    diff < TOL,
                    "{geom} s={s} causal={causal} window={window:?} {pattern:?}: diff {diff}"
                );
                assert!(serial.data.iter().all(|x| x.is_finite()));
                // Pool-size independence must stay *bitwise* under the
                // vectorized softmax: its lane-then-tail reduction order
                // depends only on the visible segment, never the pool.
                let parallel = attention_tiled_parallel(&q, &k, &v, spec, cfg, &pool).unwrap();
                assert_eq!(serial.data, parallel.data, "parallel simd diverges bitwise");
            }
        }
    }
}

#[test]
fn fully_masked_rows_stream_to_exact_zeros_across_kernels() {
    // A bitmap row with no visible key blocks must produce exactly-zero
    // output rows — not NaN from a 0/0 softmax — in the oracle, the serial
    // tiled kernel, and the pooled tiled kernel alike.
    use sqa::attention::{pattern, BlockBitmap, MaskPattern};
    let id = pattern::register_bitmap(
        BlockBitmap::new(
            TILE,
            3,
            3,
            vec![
                true, false, false, //
                false, false, false, // query rows [8, 16): fully masked
                true, false, true,
            ],
        )
        .unwrap(),
    );
    let (hq, hkv, s, d) = (4usize, 2usize, 3 * TILE, 4usize);
    let mut rng = Pcg64::new(77);
    let q = randn(&[1, hq, s, d], &mut rng);
    let k = randn(&[1, hkv, s, d], &mut rng);
    let v = randn(&[1, hkv, s, d], &mut rng);
    let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Bitmap(id));
    let pool = ThreadPool::new(2, 64);
    let cfg = TileConfig::new(TILE, TILE).unwrap();
    let want = attention(&q, &k, &v, spec).unwrap();
    for (label, got) in [
        ("oracle", want.clone()),
        ("serial", attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap()),
        (
            "parallel",
            attention_tiled_parallel(&q, &k, &v, spec, cfg, &pool).unwrap(),
        ),
    ] {
        assert!(want.max_abs_diff(&got) < TOL, "{label}");
        for h in 0..hq {
            for i in TILE..2 * TILE {
                for dd in 0..d {
                    assert_eq!(
                        got.get4(0, h, i, dd),
                        0.0,
                        "{label}: masked row {i} h{h} d{dd} must be exactly zero"
                    );
                }
            }
            // Unmasked rows stay live (row 0 sees key block 0).
            assert!((0..d).any(|dd| got.get4(0, h, 0, dd) != 0.0), "{label}");
        }
        assert!(got.data.iter().all(|x| x.is_finite()), "{label}");
    }
}

#[test]
fn default_kernel_dispatch_is_tiled_and_matches_oracle() {
    // attention_with(Tiled) on default 64-tiles, at sizes around that tile.
    let mut rng = Pcg64::new(9);
    for s in [1usize, 63, 64, 65, 197] {
        let (hq, hkv, d) = (4, 2, 8);
        let q = randn(&[1, hq, s, d], &mut rng);
        let k = randn(&[1, hkv, s, d], &mut rng);
        let v = randn(&[1, hkv, s, d], &mut rng);
        let spec = Spec::causal(hq, hkv);
        let want = attention_with(&q, &k, &v, spec, Kernel::Naive).unwrap();
        let got = attention_with(&q, &k, &v, spec, Kernel::Tiled).unwrap();
        assert!(
            want.max_abs_diff(&got) < TOL,
            "s={s}: {}",
            want.max_abs_diff(&got)
        );
    }
}

#[test]
fn kernel_parsing_round_trips() {
    assert_eq!(Kernel::parse("naive").unwrap(), Kernel::Naive);
    assert_eq!(Kernel::parse("tiled").unwrap(), Kernel::Tiled);
    assert_eq!(Kernel::default(), Kernel::Tiled);
    assert_eq!(Kernel::Tiled.name(), "tiled");
    assert!(Kernel::parse("pallas").is_err());
}

#[test]
fn tiled_rejects_bad_shapes_like_the_oracle() {
    let mut rng = Pcg64::new(5);
    let q = randn(&[1, 3, 4, 2], &mut rng);
    let k = randn(&[1, 2, 4, 2], &mut rng);
    // Hq=3 not a multiple of Hkv=2: both kernels must refuse.
    assert!(attention(&q, &k, &k, Spec::full(3, 2)).is_err());
    assert!(attention_with(&q, &k, &k, Spec::full(3, 2), Kernel::Tiled).is_err());
}
