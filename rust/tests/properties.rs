//! Property-based tests (custom harness, `sqa::util::prop`) over the
//! coordinator invariants, the native attention oracle, the tiled
//! streaming kernel's online-softmax invariants, the streaming attention
//! backward's masking/determinism guarantees, and the blocked-vs-scalar
//! GEMM equivalence of `sqa::linalg`.

use sqa::attention::backward::{backward_tiled_slabs, forward_slabs_lse};
use sqa::attention::tiled::{
    attention_tiled_cfg, attention_tiled_parallel, visited_key_tiles, TileConfig,
};
use sqa::attention::{attention, tensor::Tensor, MaskPattern, Spec};
use sqa::util::threadpool::ThreadPool;
use sqa::linalg::{self, Impl};
use sqa::coordinator::batcher::DynamicBatcher;
use sqa::coordinator::request::EncodeRequest;
use sqa::coordinator::router::Router;
use sqa::data::{pad_to, Batcher, Split};
use sqa::util::prop::{check, Choice, Gen, Pair, UsizeRange};
use sqa::util::rng::Pcg64;
use std::time::{Duration, Instant};

fn randn_tensor(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
}

/// Attention rows are convex combinations: outputs stay inside the per-head
/// value hull for every (Hq, Hkv, S, window) drawn.
#[test]
fn prop_attention_output_in_value_hull() {
    let geom = Pair(
        Pair(UsizeRange { lo: 1, hi: 3 }, UsizeRange { lo: 1, hi: 2 }), // (group, hkv)
        Pair(UsizeRange { lo: 2, hi: 24 }, Choice(vec![None, Some(1usize), Some(4), Some(9)])),
    );
    let mut rng_seed = 0u64;
    check(42, 40, &geom, |((group, hkv), (s, window))| {
        rng_seed += 1;
        let hq = group * hkv;
        let mut rng = Pcg64::new(rng_seed);
        let q = randn_tensor(&[1, hq, *s, 4], &mut rng);
        let k = randn_tensor(&[1, *hkv, *s, 4], &mut rng);
        let v = randn_tensor(&[1, *hkv, *s, 4], &mut rng);
        let spec = Spec {
            causal: window.is_none(), // exercise both mask kinds
            window: *window,
            ..Spec::full(hq, *hkv)
        };
        let out = attention(&q, &k, &v, spec).map_err(|e| e.to_string())?;
        for h in 0..hq {
            let kvh = h / group;
            for dd in 0..4 {
                let (mut lo, mut hi) = (f32::MAX, f32::MIN);
                for j in 0..*s {
                    let x = v.get4(0, kvh, j, dd);
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                for i in 0..*s {
                    let o = out.get4(0, h, i, dd);
                    if o < lo - 1e-4 || o > hi + 1e-4 {
                        return Err(format!("out {o} outside hull [{lo}, {hi}]"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Permuting value rows under uniform attention leaves the output unchanged
/// (softmax over constant scores is permutation-invariant).
#[test]
fn prop_uniform_attention_permutation_invariant() {
    check(7, 30, &UsizeRange { lo: 2, hi: 32 }, |&s| {
        let mut rng = Pcg64::new(s as u64);
        let q = Tensor::from_vec(&[1, 2, s, 4], vec![1.0; 2 * s * 4]).unwrap();
        let k = Tensor::from_vec(&[1, 1, s, 4], vec![1.0; s * 4]).unwrap();
        let v = randn_tensor(&[1, 1, s, 4], &mut rng);
        let out1 = attention(&q, &k, &v, Spec::full(2, 1)).map_err(|e| e.to_string())?;
        // Rotate value rows by one.
        let mut v2 = Tensor::zeros(&[1, 1, s, 4]);
        for j in 0..s {
            for dd in 0..4 {
                v2.set4(0, 0, (j + 1) % s, dd, v.get4(0, 0, j, dd));
            }
        }
        let out2 = attention(&q, &k, &v2, Spec::full(2, 1)).map_err(|e| e.to_string())?;
        if out1.max_abs_diff(&out2) > 1e-5 {
            return Err("uniform attention not permutation invariant".into());
        }
        Ok(())
    });
}

/// Tiled online softmax normalizes: with all-ones values, every output
/// coordinate is exactly the row's probability mass, so it must be 1 for
/// every (Hq, Hkv, S, tile, mask) drawn — rows always see at least
/// themselves, hence no degenerate zero rows here.
#[test]
fn prop_tiled_softmax_rows_sum_to_one() {
    let geom = Pair(
        Pair(UsizeRange { lo: 1, hi: 3 }, UsizeRange { lo: 1, hi: 2 }), // (group, hkv)
        Pair(
            Pair(UsizeRange { lo: 1, hi: 25 }, UsizeRange { lo: 1, hi: 9 }), // (s, tile)
            Choice(vec![None, Some(1usize), Some(3), Some(8)]),
        ),
    );
    let mut rng_seed = 1000u64;
    check(21, 50, &geom, |((group, hkv), ((s, tile), window))| {
        rng_seed += 1;
        let hq = group * hkv;
        let d = 4;
        let mut rng = Pcg64::new(rng_seed);
        let q = randn_tensor(&[1, hq, *s, d], &mut rng);
        let k = randn_tensor(&[1, *hkv, *s, d], &mut rng);
        let v = Tensor::from_vec(&[1, *hkv, *s, d], vec![1.0; *hkv * *s * d]).unwrap();
        let spec = Spec {
            causal: window.is_none(),
            window: *window,
            ..Spec::full(hq, *hkv)
        };
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        let out = attention_tiled_cfg(&q, &k, &v, spec, cfg).map_err(|e| e.to_string())?;
        for (idx, &x) in out.data.iter().enumerate() {
            if (x - 1.0).abs() > 1e-5 {
                return Err(format!("row mass {x} != 1 at flat index {idx}"));
            }
        }
        Ok(())
    });
}

/// Keys/values outside a row's visible window must not influence that row:
/// shuffling (K, V) jointly at the invisible positions leaves the tiled
/// output of the probed row unchanged.
#[test]
fn prop_tiled_invariant_to_kv_outside_window() {
    let gen = Pair(
        Pair(UsizeRange { lo: 4, hi: 24 }, UsizeRange { lo: 1, hi: 4 }), // (s, window)
        UsizeRange { lo: 1, hi: 6 },                                     // tile
    );
    let mut rng_seed = 2000u64;
    check(23, 40, &gen, |((s, window), tile)| {
        rng_seed += 1;
        let (hq, hkv, d) = (2usize, 1usize, 4usize);
        let mut rng = Pcg64::new(rng_seed);
        let q = randn_tensor(&[1, hq, *s, d], &mut rng);
        let k = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let v = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let spec = Spec {
            causal: rng.bool(0.5),
            window: Some(*window),
            ..Spec::full(hq, hkv)
        };
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        let out1 = attention_tiled_cfg(&q, &k, &v, spec, cfg).map_err(|e| e.to_string())?;
        // Probe a random row; rotate K/V rows jointly outside its window.
        let i = rng.range_usize(0, *s);
        let (lo, hi) = sqa::attention::visible_range(i, *s, spec);
        let outside: Vec<usize> = (0..*s).filter(|j| *j < lo || *j >= hi).collect();
        if outside.is_empty() {
            return Ok(()); // whole sequence visible, nothing to scramble
        }
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for (a, b) in outside.iter().zip(outside.iter().cycle().skip(1)) {
            for dd in 0..d {
                k2.set4(0, 0, *b, dd, k.get4(0, 0, *a, dd));
                v2.set4(0, 0, *b, dd, v.get4(0, 0, *a, dd));
            }
        }
        let out2 = attention_tiled_cfg(&q, &k2, &v2, spec, cfg).map_err(|e| e.to_string())?;
        for h in 0..hq {
            for dd in 0..d {
                let (a, b) = (out1.get4(0, h, i, dd), out2.get4(0, h, i, dd));
                if (a - b).abs() > 1e-5 {
                    return Err(format!(
                        "row {i} (visible [{lo},{hi})) changed: {a} vs {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The key tiles the kernel visits are exactly the tiles intersecting some
/// row's `visible_range` — no tile is skipped that holds a visible key, and
/// no fully-masked tile is touched.
#[test]
fn prop_visited_key_tiles_agree_with_visible_range() {
    let gen = Pair(
        Pair(UsizeRange { lo: 1, hi: 40 }, UsizeRange { lo: 1, hi: 7 }), // (s, k_tile)
        Pair(
            Choice(vec![None, Some(1usize), Some(2), Some(5)]),
            Choice(vec![false, true]),
        ),
    );
    check(29, 150, &gen, |((s, k_tile), (window, causal))| {
        let spec = Spec {
            causal: *causal,
            window: *window,
            ..Spec::full(1, 1)
        };
        let q_tile = 4usize;
        let mut i0 = 0;
        while i0 < *s {
            let i1 = (i0 + q_tile).min(*s);
            let visited: std::collections::BTreeSet<usize> =
                visited_key_tiles(i0, i1, *s, spec, *k_tile).into_iter().collect();
            let mut expect = std::collections::BTreeSet::new();
            for i in i0..i1 {
                let (lo, hi) = sqa::attention::visible_range(i, *s, spec);
                for t in lo / *k_tile..hi.div_ceil(*k_tile) {
                    if (t * *k_tile).max(lo) < ((t + 1) * *k_tile).min(hi) {
                        expect.insert(t);
                    }
                }
            }
            if visited != expect {
                return Err(format!(
                    "qtile [{i0},{i1}): visited {visited:?} != visible {expect:?}"
                ));
            }
            i0 = i1;
        }
        Ok(())
    });
}

/// Mask-aware backward: a gradient injected at one query row produces
/// exactly zero dK/dV outside that row's visible window (the mask-skipped
/// key tiles are provably untouched, not just approximately zero) and
/// exactly zero dQ at every other row.
#[test]
fn prop_backward_grads_outside_visible_window_are_exactly_zero() {
    let gen = Pair(
        Pair(UsizeRange { lo: 2, hi: 24 }, UsizeRange { lo: 1, hi: 4 }), // (s, window)
        Pair(UsizeRange { lo: 1, hi: 6 }, Choice(vec![false, true])),    // (tile, causal)
    );
    let mut rng_seed = 4000u64;
    check(31, 40, &gen, |((s, window), (tile, causal))| {
        rng_seed += 1;
        let (hq, hkv, d) = (2usize, 1usize, 4usize);
        let (dq_cols, dkv_cols) = (hq * d, hkv * d);
        let mut rng = Pcg64::new(rng_seed);
        let mut fill = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
        };
        let q = fill(*s * dq_cols);
        let k = fill(*s * dkv_cols);
        let v = fill(*s * dkv_cols);
        let spec = Spec {
            causal: *causal,
            window: Some(*window),
            ..Spec::full(hq, hkv)
        };
        let scale = 1.0 / (d as f32).sqrt();
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        let mut o = vec![0.0f32; *s * dq_cols];
        let mut lse = vec![0.0f32; hq * *s];
        forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, *s, d, spec, cfg, scale, None);
        // dout nonzero only at one (row, head).
        let i = rng.range_usize(0, *s);
        let h = rng.range_usize(0, hq);
        let mut dout = vec![0.0f32; *s * dq_cols];
        for dd in 0..d {
            dout[i * dq_cols + h * d + dd] = rng.normal_f32(0.0, 1.0);
        }
        let mut dq = vec![0.0f32; *s * dq_cols];
        let mut dk = vec![0.0f32; *s * dkv_cols];
        let mut dv = vec![0.0f32; *s * dkv_cols];
        backward_tiled_slabs(
            &q, &k, &v, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, *s, d, spec, cfg, scale,
            None,
        );
        let (lo, hi) = sqa::attention::visible_range(i, *s, spec);
        for j in 0..*s {
            if (lo..hi).contains(&j) {
                continue;
            }
            for dd in 0..d {
                let (gk, gv) = (dk[j * dkv_cols + dd], dv[j * dkv_cols + dd]);
                if gk != 0.0 || gv != 0.0 {
                    return Err(format!(
                        "key {j} outside visible [{lo},{hi}) of row {i}: dk {gk} dv {gv}"
                    ));
                }
            }
        }
        for r in 0..*s {
            if r == i {
                continue;
            }
            for c in 0..dq_cols {
                if dq[r * dq_cols + c] != 0.0 {
                    return Err(format!("dq row {r} nonzero with dout only at row {i}"));
                }
            }
        }
        Ok(())
    });
}

/// Gradient reduction order is deterministic: the wave-merged backward is
/// bitwise identical across thread-pool sizes (and to the serial path).
#[test]
fn prop_backward_bitwise_deterministic_across_pool_sizes() {
    let pool2 = ThreadPool::new(2, 128);
    let pool5 = ThreadPool::new(5, 128);
    let gen = Pair(
        Pair(UsizeRange { lo: 1, hi: 40 }, UsizeRange { lo: 1, hi: 5 }), // (s, tile)
        Pair(UsizeRange { lo: 1, hi: 2 }, Choice(vec![None, Some(2usize), Some(7)])),
    );
    let mut rng_seed = 5000u64;
    check(33, 25, &gen, |((s, tile), (group, window))| {
        rng_seed += 1;
        let (hkv, d) = (2usize, 4usize);
        let hq = group * hkv;
        let (dq_cols, dkv_cols) = (hq * d, hkv * d);
        let mut rng = Pcg64::new(rng_seed);
        let mut fill = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
        };
        let q = fill(*s * dq_cols);
        let k = fill(*s * dkv_cols);
        let v = fill(*s * dkv_cols);
        let dout = fill(*s * dq_cols);
        let spec = Spec {
            causal: window.is_none(),
            window: *window,
            ..Spec::full(hq, hkv)
        };
        let scale = 1.0 / (d as f32).sqrt();
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        let mut o = vec![0.0f32; *s * dq_cols];
        let mut lse = vec![0.0f32; hq * *s];
        forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, *s, d, spec, cfg, scale, None);
        let run = |pool: Option<&ThreadPool>| {
            let mut dq = vec![0.0f32; *s * dq_cols];
            let mut dk = vec![0.0f32; *s * dkv_cols];
            let mut dv = vec![0.0f32; *s * dkv_cols];
            backward_tiled_slabs(
                &q, &k, &v, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, *s, d, spec, cfg,
                scale, pool,
            );
            (dq, dk, dv)
        };
        let serial = run(None);
        if serial != run(Some(&pool2)) {
            return Err("2-worker pool diverged from serial".into());
        }
        if serial != run(Some(&pool5)) {
            return Err("5-worker pool diverged from serial".into());
        }
        Ok(())
    });
}

/// Sparse patterns keep the visited-tile seam honest: for every pattern the
/// tiles the kernel visits are exactly the tiles holding at least one
/// effectively-visible (i, j) pair — per-element brute force as the oracle.
#[test]
fn prop_visited_key_tiles_match_elementwise_visibility_under_patterns() {
    let gen = Pair(
        Pair(UsizeRange { lo: 1, hi: 40 }, UsizeRange { lo: 1, hi: 7 }), // (s, k_tile)
        Pair(
            Choice(vec![
                MaskPattern::Dense,
                MaskPattern::Window { window: 5 },
                MaskPattern::Strided { stride: 3 },
                MaskPattern::Dilated { window: 2, stride: 3 },
                MaskPattern::SinkLocal { sinks: 2, window: 4 },
            ]),
            Choice(vec![false, true]),
        ),
    );
    check(47, 150, &gen, |((s, k_tile), (pattern, causal))| {
        let spec = Spec {
            causal: *causal,
            ..Spec::full(1, 1)
        }
        .with_pattern(*pattern);
        let rm = spec.resolved();
        let q_tile = 4usize;
        let mut i0 = 0;
        while i0 < *s {
            let i1 = (i0 + q_tile).min(*s);
            let visited: std::collections::BTreeSet<usize> =
                visited_key_tiles(i0, i1, *s, spec, *k_tile).into_iter().collect();
            let mut expect = std::collections::BTreeSet::new();
            for i in i0..i1 {
                for j in 0..*s {
                    if rm.visible(i, j) {
                        expect.insert(j / *k_tile);
                    }
                }
            }
            if visited != expect {
                return Err(format!(
                    "{pattern:?} causal={causal} qtile [{i0},{i1}): \
                     visited {visited:?} != visible {expect:?}"
                ));
            }
            i0 = i1;
        }
        Ok(())
    });
}

/// The paper-scale sparsity claim, pinned analytically: at S = 4096 with
/// 64×64 tiles under the causal mask, every sparse built-in visits a
/// sub-dense — for strided/dilated o(S²/T²) — number of key tiles. The
/// exact integers double as the bench baseline (`pattern_tiles` in
/// BENCH_attention.json); if the visibility seam drifts, both fail together.
#[test]
fn sparse_patterns_visit_sub_dense_tile_counts_at_scale() {
    let (s, tile) = (4096usize, 64usize);
    let count = |pattern: MaskPattern| -> usize {
        let spec = Spec::causal(1, 1).with_pattern(pattern);
        let mut total = 0;
        let mut i0 = 0;
        while i0 < s {
            let i1 = (i0 + tile).min(s);
            total += visited_key_tiles(i0, i1, s, spec, tile).len();
            i0 = i1;
        }
        total
    };
    let dense = count(MaskPattern::Dense);
    assert_eq!(dense, 64 * 65 / 2, "causal dense is the triangle count");
    // window: ≤ 17 diagonal tile bands (⌈(1024+63)/64⌉) per query tile.
    assert_eq!(count(MaskPattern::Window { window: 1024 }), 952);
    // strided: one band every stride/T = 16 tiles — Θ(S²/(T·stride)).
    assert_eq!(count(MaskPattern::Strided { stride: 1024 }), 160);
    // dilated: 8 reachable offsets, one band each.
    assert_eq!(count(MaskPattern::Dilated { window: 8, stride: 512 }), 288);
    // sink+local: the window bands plus one pinned sink tile column.
    assert_eq!(count(MaskPattern::SinkLocal { sinks: 64, window: 1024 }), 999);
}

/// K/V rows outside a query row's *effective* visible set (causal ∧ window
/// ∧ pattern) must not influence that row's tiled output, for every sparse
/// pattern — the pattern analogue of the window-invariance property.
#[test]
fn prop_tiled_invariant_to_kv_outside_pattern_visible_set() {
    let gen = Pair(
        Pair(UsizeRange { lo: 4, hi: 24 }, UsizeRange { lo: 1, hi: 6 }), // (s, tile)
        Choice(vec![
            MaskPattern::Window { window: 3 },
            MaskPattern::Strided { stride: 3 },
            MaskPattern::Dilated { window: 2, stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 3 },
        ]),
    );
    let mut rng_seed = 6000u64;
    check(53, 60, &gen, |((s, tile), pattern)| {
        rng_seed += 1;
        let (hq, hkv, d) = (2usize, 1usize, 4usize);
        let mut rng = Pcg64::new(rng_seed);
        let q = randn_tensor(&[1, hq, *s, d], &mut rng);
        let k = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let v = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let spec = Spec {
            causal: rng.bool(0.5),
            ..Spec::full(hq, hkv)
        }
        .with_pattern(*pattern);
        let rm = spec.resolved();
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        let out1 = attention_tiled_cfg(&q, &k, &v, spec, cfg).map_err(|e| e.to_string())?;
        // Probe a random row; rotate K/V rows jointly across the positions
        // it cannot see.
        let i = rng.range_usize(0, *s);
        let outside: Vec<usize> = (0..*s).filter(|&j| !rm.visible(i, j)).collect();
        if outside.is_empty() {
            return Ok(());
        }
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for (a, b) in outside.iter().zip(outside.iter().cycle().skip(1)) {
            for dd in 0..d {
                k2.set4(0, 0, *b, dd, k.get4(0, 0, *a, dd));
                v2.set4(0, 0, *b, dd, v.get4(0, 0, *a, dd));
            }
        }
        let out2 = attention_tiled_cfg(&q, &k2, &v2, spec, cfg).map_err(|e| e.to_string())?;
        for h in 0..hq {
            for dd in 0..d {
                let (a, b) = (out1.get4(0, h, i, dd), out2.get4(0, h, i, dd));
                if (a - b).abs() > 1e-5 {
                    return Err(format!("{pattern:?} row {i}: {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

/// Patterned kernels stay deterministic across scheduling: for every sparse
/// pattern the pooled forward is bitwise identical to the serial forward,
/// and the wave-merged backward is bitwise identical across pool sizes.
#[test]
fn prop_pattern_kernels_bitwise_deterministic_across_pools() {
    let pool2 = ThreadPool::new(2, 128);
    let pool5 = ThreadPool::new(5, 128);
    let gen = Pair(
        Pair(UsizeRange { lo: 1, hi: 30 }, UsizeRange { lo: 1, hi: 5 }), // (s, tile)
        Choice(vec![
            MaskPattern::Window { window: 4 },
            MaskPattern::Strided { stride: 3 },
            MaskPattern::Dilated { window: 2, stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 4 },
        ]),
    );
    let mut rng_seed = 7000u64;
    check(59, 30, &gen, |((s, tile), pattern)| {
        rng_seed += 1;
        let (hq, hkv, d) = (4usize, 2usize, 4usize);
        let (dq_cols, dkv_cols) = (hq * d, hkv * d);
        let mut rng = Pcg64::new(rng_seed);
        let spec = Spec {
            causal: rng.bool(0.5),
            ..Spec::full(hq, hkv)
        }
        .with_pattern(*pattern);
        let cfg = TileConfig::new(*tile, *tile).map_err(|e| e.to_string())?;
        // Forward: serial vs pooled, bitwise.
        let q = randn_tensor(&[1, hq, *s, d], &mut rng);
        let kt = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let vt = randn_tensor(&[1, hkv, *s, d], &mut rng);
        let serial = attention_tiled_cfg(&q, &kt, &vt, spec, cfg).map_err(|e| e.to_string())?;
        let pooled =
            attention_tiled_parallel(&q, &kt, &vt, spec, cfg, &pool2).map_err(|e| e.to_string())?;
        if serial.data != pooled.data {
            return Err(format!("{pattern:?}: pooled forward diverged from serial"));
        }
        // Backward: serial vs two pool sizes, bitwise.
        let mut fill = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
        };
        let qs = fill(*s * dq_cols);
        let ks = fill(*s * dkv_cols);
        let vs = fill(*s * dkv_cols);
        let dout = fill(*s * dq_cols);
        let scale = 1.0 / (d as f32).sqrt();
        let mut o = vec![0.0f32; *s * dq_cols];
        let mut lse = vec![0.0f32; hq * *s];
        forward_slabs_lse(&qs, &ks, &vs, &mut o, &mut lse, *s, d, spec, cfg, scale, None);
        let run = |pool: Option<&ThreadPool>| {
            let mut dq = vec![0.0f32; *s * dq_cols];
            let mut dk = vec![0.0f32; *s * dkv_cols];
            let mut dv = vec![0.0f32; *s * dkv_cols];
            backward_tiled_slabs(
                &qs, &ks, &vs, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, *s, d, spec, cfg,
                scale, pool,
            );
            (dq, dk, dv)
        };
        let serial_grads = run(None);
        if serial_grads != run(Some(&pool2)) || serial_grads != run(Some(&pool5)) {
            return Err(format!("{pattern:?}: pooled backward diverged from serial"));
        }
        Ok(())
    });
}

/// Blocked GEMM equivalence: for any (s, m, n) the blocked micro-kernels
/// compute the same product as the scalar oracle loops (within f32
/// reassociation tolerance), including shapes that are not multiples of
/// the MR/NR micro-tile or leave partial edge panels.
#[test]
fn prop_blocked_gemm_matches_scalar() {
    let gen = Pair(
        Pair(UsizeRange { lo: 1, hi: 40 }, UsizeRange { lo: 1, hi: 40 }),
        UsizeRange { lo: 1, hi: 40 },
    );
    let mut rng_seed = 9000u64;
    check(37, 60, &gen, |((s, m), n)| {
        rng_seed += 1;
        let mut rng = Pcg64::new(rng_seed);
        let x: Vec<f32> = (0..s * m).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let want = linalg::matmul(Impl::Scalar, &x, &w, *s, *m, *n, None);
        let got = linalg::matmul(Impl::Blocked, &x, &w, *s, *m, *n, None);
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!("({s},{m},{n}) elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Router invariants: routed bucket fits, is minimal, and waste < 1.
#[test]
fn prop_router_minimal_fitting_bucket() {
    let gen = Pair(UsizeRange { lo: 1, hi: 4 }, UsizeRange { lo: 1, hi: 600 });
    check(3, 200, &gen, |(n_buckets, len)| {
        let buckets: Vec<usize> = (1..=*n_buckets).map(|i| i * 128).collect();
        let router = Router::new(buckets.clone());
        match router.route(*len) {
            Ok(b) => {
                if b < *len {
                    return Err(format!("bucket {b} < len {len}"));
                }
                if let Some(&smaller) = buckets.iter().filter(|&&x| x >= *len).min() {
                    if b != smaller {
                        return Err(format!("bucket {b} not minimal ({smaller})"));
                    }
                }
                let w = router.padding_waste(*len);
                if !(0.0..1.0).contains(&w) {
                    return Err(format!("waste {w} out of range"));
                }
            }
            Err(_) => {
                if *len <= *buckets.last().unwrap() {
                    return Err("rejected a routable length".into());
                }
            }
        }
        Ok(())
    });
}

/// Dynamic batcher conservation: every pushed request comes out exactly
/// once, in FIFO order per bucket, and no batch exceeds max_batch.
#[test]
fn prop_batcher_conserves_requests() {
    let gen = Pair(UsizeRange { lo: 1, hi: 8 }, UsizeRange { lo: 1, hi: 50 });
    check(11, 100, &gen, |(max_batch, n_reqs)| {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(&[64, 128], *max_batch, Duration::ZERO);
        let mut rng = Pcg64::new((*n_reqs * 31 + *max_batch) as u64);
        let mut pushed = Vec::new();
        for id in 0..*n_reqs as u64 {
            let bucket = if rng.bool(0.5) { 64 } else { 128 };
            b.push(
                bucket,
                EncodeRequest {
                    id,
                    tokens: vec![1],
                    submitted: now,
                },
            );
            pushed.push((bucket, id));
        }
        let batches = b.ready(now, true);
        let mut seen: Vec<(usize, u64)> = Vec::new();
        for batch in &batches {
            if batch.requests.len() > *max_batch {
                return Err(format!("batch of {} > max {max_batch}", batch.requests.len()));
            }
            for r in &batch.requests {
                seen.push((batch.bucket, r.id));
            }
        }
        // Exactly once, FIFO per bucket.
        for bucket in [64usize, 128] {
            let sent: Vec<u64> = pushed.iter().filter(|(b2, _)| *b2 == bucket).map(|(_, id)| *id).collect();
            let got: Vec<u64> = seen.iter().filter(|(b2, _)| *b2 == bucket).map(|(_, id)| *id).collect();
            if sent != got {
                return Err(format!("bucket {bucket}: sent {sent:?} got {got:?}"));
            }
        }
        if b.queued() != 0 {
            return Err("requests left in queue after drain".into());
        }
        Ok(())
    });
}

/// pad_to: length preserved, padding id correct, truncation exact.
#[test]
fn prop_pad_to() {
    let gen = Pair(UsizeRange { lo: 0, hi: 300 }, UsizeRange { lo: 1, hi: 256 });
    check(5, 200, &gen, |(len, bucket)| {
        let tokens: Vec<u32> = (0..*len as u32).map(|i| i + 10).collect();
        let (padded, n) = pad_to(&tokens, *bucket, 0);
        if padded.len() != *bucket {
            return Err(format!("padded len {} != bucket {bucket}", padded.len()));
        }
        if n != (*len).min(*bucket) {
            return Err(format!("real len {n} wrong"));
        }
        for (i, &t) in padded.iter().enumerate() {
            let want = if i < n { (i + 10) as i32 } else { 0 };
            if t != want {
                return Err(format!("padded[{i}] = {t}, want {want}"));
            }
        }
        Ok(())
    });
}

/// Batcher (data pipeline): targets always equal next tokens; train and val
/// windows never overlap for any (seq, batch) geometry.
#[test]
fn prop_data_batcher_shift_and_split() {
    let gen = Pair(UsizeRange { lo: 2, hi: 32 }, UsizeRange { lo: 1, hi: 4 });
    check(13, 60, &gen, |(seq, batch)| {
        let data: Vec<u32> = (0..((*seq + 1) * *batch * 25) as u32).collect();
        let mut tr = Batcher::new(data.clone(), *batch, *seq, Split::Train);
        let mut va = Batcher::new(data, *batch, *seq, Split::Val);
        let mut train_starts = std::collections::HashSet::new();
        for _ in 0..10 {
            let b = tr.next_batch();
            for row in 0..*batch {
                train_starts.insert(b.tokens[row * *seq]);
                for i in 0..*seq - 1 {
                    if b.targets[row * *seq + i] != b.tokens[row * *seq + i + 1] {
                        return Err("targets are not shifted tokens".into());
                    }
                }
            }
        }
        for _ in 0..4 {
            let b = va.next_batch();
            for row in 0..*batch {
                if train_starts.contains(&b.tokens[row * *seq]) {
                    return Err("val window seen in train".into());
                }
            }
        }
        Ok(())
    });
}
