//! Property-based tests (custom harness, `sqa::util::prop`) over the
//! coordinator invariants and the native attention oracle.

use sqa::attention::{attention, tensor::Tensor, Spec};
use sqa::coordinator::batcher::DynamicBatcher;
use sqa::coordinator::request::EncodeRequest;
use sqa::coordinator::router::Router;
use sqa::data::{pad_to, Batcher, Split};
use sqa::util::prop::{check, Choice, Gen, Pair, UsizeRange};
use sqa::util::rng::Pcg64;
use std::time::{Duration, Instant};

fn randn_tensor(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
}

/// Attention rows are convex combinations: outputs stay inside the per-head
/// value hull for every (Hq, Hkv, S, window) drawn.
#[test]
fn prop_attention_output_in_value_hull() {
    let geom = Pair(
        Pair(UsizeRange { lo: 1, hi: 3 }, UsizeRange { lo: 1, hi: 2 }), // (group, hkv)
        Pair(UsizeRange { lo: 2, hi: 24 }, Choice(vec![None, Some(1usize), Some(4), Some(9)])),
    );
    let mut rng_seed = 0u64;
    check(42, 40, &geom, |((group, hkv), (s, window))| {
        rng_seed += 1;
        let hq = group * hkv;
        let mut rng = Pcg64::new(rng_seed);
        let q = randn_tensor(&[1, hq, *s, 4], &mut rng);
        let k = randn_tensor(&[1, *hkv, *s, 4], &mut rng);
        let v = randn_tensor(&[1, *hkv, *s, 4], &mut rng);
        let spec = Spec {
            hq,
            hkv: *hkv,
            causal: window.is_none(), // exercise both mask kinds
            window: *window,
        };
        let out = attention(&q, &k, &v, spec).map_err(|e| e.to_string())?;
        for h in 0..hq {
            let kvh = h / group;
            for dd in 0..4 {
                let (mut lo, mut hi) = (f32::MAX, f32::MIN);
                for j in 0..*s {
                    let x = v.get4(0, kvh, j, dd);
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                for i in 0..*s {
                    let o = out.get4(0, h, i, dd);
                    if o < lo - 1e-4 || o > hi + 1e-4 {
                        return Err(format!("out {o} outside hull [{lo}, {hi}]"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Permuting value rows under uniform attention leaves the output unchanged
/// (softmax over constant scores is permutation-invariant).
#[test]
fn prop_uniform_attention_permutation_invariant() {
    check(7, 30, &UsizeRange { lo: 2, hi: 32 }, |&s| {
        let mut rng = Pcg64::new(s as u64);
        let q = Tensor::from_vec(&[1, 2, s, 4], vec![1.0; 2 * s * 4]).unwrap();
        let k = Tensor::from_vec(&[1, 1, s, 4], vec![1.0; s * 4]).unwrap();
        let v = randn_tensor(&[1, 1, s, 4], &mut rng);
        let out1 = attention(&q, &k, &v, Spec::full(2, 1)).map_err(|e| e.to_string())?;
        // Rotate value rows by one.
        let mut v2 = Tensor::zeros(&[1, 1, s, 4]);
        for j in 0..s {
            for dd in 0..4 {
                v2.set4(0, 0, (j + 1) % s, dd, v.get4(0, 0, j, dd));
            }
        }
        let out2 = attention(&q, &k, &v2, Spec::full(2, 1)).map_err(|e| e.to_string())?;
        if out1.max_abs_diff(&out2) > 1e-5 {
            return Err("uniform attention not permutation invariant".into());
        }
        Ok(())
    });
}

/// Router invariants: routed bucket fits, is minimal, and waste < 1.
#[test]
fn prop_router_minimal_fitting_bucket() {
    let gen = Pair(UsizeRange { lo: 1, hi: 4 }, UsizeRange { lo: 1, hi: 600 });
    check(3, 200, &gen, |(n_buckets, len)| {
        let buckets: Vec<usize> = (1..=*n_buckets).map(|i| i * 128).collect();
        let router = Router::new(buckets.clone());
        match router.route(*len) {
            Ok(b) => {
                if b < *len {
                    return Err(format!("bucket {b} < len {len}"));
                }
                if let Some(&smaller) = buckets.iter().filter(|&&x| x >= *len).min() {
                    if b != smaller {
                        return Err(format!("bucket {b} not minimal ({smaller})"));
                    }
                }
                let w = router.padding_waste(*len);
                if !(0.0..1.0).contains(&w) {
                    return Err(format!("waste {w} out of range"));
                }
            }
            Err(_) => {
                if *len <= *buckets.last().unwrap() {
                    return Err("rejected a routable length".into());
                }
            }
        }
        Ok(())
    });
}

/// Dynamic batcher conservation: every pushed request comes out exactly
/// once, in FIFO order per bucket, and no batch exceeds max_batch.
#[test]
fn prop_batcher_conserves_requests() {
    let gen = Pair(UsizeRange { lo: 1, hi: 8 }, UsizeRange { lo: 1, hi: 50 });
    check(11, 100, &gen, |(max_batch, n_reqs)| {
        let now = Instant::now();
        let mut b = DynamicBatcher::new(&[64, 128], *max_batch, Duration::ZERO);
        let mut rng = Pcg64::new((*n_reqs * 31 + *max_batch) as u64);
        let mut pushed = Vec::new();
        for id in 0..*n_reqs as u64 {
            let bucket = if rng.bool(0.5) { 64 } else { 128 };
            b.push(
                bucket,
                EncodeRequest {
                    id,
                    tokens: vec![1],
                    submitted: now,
                },
            );
            pushed.push((bucket, id));
        }
        let batches = b.ready(now, true);
        let mut seen: Vec<(usize, u64)> = Vec::new();
        for batch in &batches {
            if batch.requests.len() > *max_batch {
                return Err(format!("batch of {} > max {max_batch}", batch.requests.len()));
            }
            for r in &batch.requests {
                seen.push((batch.bucket, r.id));
            }
        }
        // Exactly once, FIFO per bucket.
        for bucket in [64usize, 128] {
            let sent: Vec<u64> = pushed.iter().filter(|(b2, _)| *b2 == bucket).map(|(_, id)| *id).collect();
            let got: Vec<u64> = seen.iter().filter(|(b2, _)| *b2 == bucket).map(|(_, id)| *id).collect();
            if sent != got {
                return Err(format!("bucket {bucket}: sent {sent:?} got {got:?}"));
            }
        }
        if b.queued() != 0 {
            return Err("requests left in queue after drain".into());
        }
        Ok(())
    });
}

/// pad_to: length preserved, padding id correct, truncation exact.
#[test]
fn prop_pad_to() {
    let gen = Pair(UsizeRange { lo: 0, hi: 300 }, UsizeRange { lo: 1, hi: 256 });
    check(5, 200, &gen, |(len, bucket)| {
        let tokens: Vec<u32> = (0..*len as u32).map(|i| i + 10).collect();
        let (padded, n) = pad_to(&tokens, *bucket, 0);
        if padded.len() != *bucket {
            return Err(format!("padded len {} != bucket {bucket}", padded.len()));
        }
        if n != (*len).min(*bucket) {
            return Err(format!("real len {n} wrong"));
        }
        for (i, &t) in padded.iter().enumerate() {
            let want = if i < n { (i + 10) as i32 } else { 0 };
            if t != want {
                return Err(format!("padded[{i}] = {t}, want {want}"));
            }
        }
        Ok(())
    });
}

/// Batcher (data pipeline): targets always equal next tokens; train and val
/// windows never overlap for any (seq, batch) geometry.
#[test]
fn prop_data_batcher_shift_and_split() {
    let gen = Pair(UsizeRange { lo: 2, hi: 32 }, UsizeRange { lo: 1, hi: 4 });
    check(13, 60, &gen, |(seq, batch)| {
        let data: Vec<u32> = (0..((*seq + 1) * *batch * 25) as u32).collect();
        let mut tr = Batcher::new(data.clone(), *batch, *seq, Split::Train);
        let mut va = Batcher::new(data, *batch, *seq, Split::Val);
        let mut train_starts = std::collections::HashSet::new();
        for _ in 0..10 {
            let b = tr.next_batch();
            for row in 0..*batch {
                train_starts.insert(b.tokens[row * *seq]);
                for i in 0..*seq - 1 {
                    if b.targets[row * *seq + i] != b.tokens[row * *seq + i + 1] {
                        return Err("targets are not shifted tokens".into());
                    }
                }
            }
        }
        for _ in 0..4 {
            let b = va.next_batch();
            for row in 0..*batch {
                if train_starts.contains(&b.tokens[row * *seq]) {
                    return Err("val window seen in train".into());
                }
            }
        }
        Ok(())
    });
}
