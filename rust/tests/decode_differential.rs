//! Differential suite for the autoregressive decode path: an N-step
//! incremental decode (prefill + per-token [`Backend::decode_step`]) must
//! reproduce a full stateless re-forward of the same token sequence at
//! every position, to 1e-4 — across the variant zoo, both attention
//! kernels (prefill lowering) and all three linalg impls (which the incremental
//! decode kernel also runs on).
//!
//! Plus KV-cache bookkeeping edge cases at the backend boundary: prompt
//! longer than the cache, session at capacity, eviction (close)
//! mid-generation, single-token prompts, and the §5.2 cache-size ordering
//! (xSQA == GQA < sSQA) as observable `session_stats` bytes — at f32 and
//! again at half-precision cache storage, where every byte halves but the
//! Hkv ratios (and hence the ordering) are untouched.
//!
//! The paged-allocator legs pin the storage refactor against the same
//! oracles: a paged session must be *bitwise* identical to its contiguous
//! twin at every dtype (the allocator changes layout, never values), a
//! prefix-trie hit must reproduce the stateless re-forward to 1e-4, sparse
//! patterns must survive paging bit-for-bit, and an evicted session must
//! restore from its spill file and keep decoding exactly as if it had
//! never left the pool.

use sqa::attention::Kernel;
use sqa::linalg;
use sqa::runtime::{Backend, KvDtype, NativeBackend, PagedConfig};

const VOCAB: usize = 2048; // tiny family

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

fn prompt_tokens(n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 37 + 11) % VOCAB) as i32).collect()
}

/// Incremental decode logits vs the full forward's rows, for one backend
/// configuration and variant. `split` is the prefill length.
fn check_decode_matches_forward(
    b: &NativeBackend,
    variant: &str,
    tokens: &[i32],
    split: usize,
    label: &str,
) {
    let t_len = tokens.len();
    let params = b.init_params("tiny", variant, 5).unwrap();
    let full = b.forward("tiny", variant, &params, tokens, 1, t_len).unwrap();
    let (sid, logits) = b
        .prefill("tiny", variant, &params, &tokens[..split], t_len)
        .unwrap();
    let d = max_diff(&logits, &full[(split - 1) * VOCAB..split * VOCAB]);
    assert!(d < 1e-4, "{label}/{variant} prefill logits diverge by {d}");
    for i in split..t_len {
        let l = b.decode_step(sid, &params, tokens[i]).unwrap();
        let d = max_diff(&l, &full[i * VOCAB..(i + 1) * VOCAB]);
        assert!(d < 1e-4, "{label}/{variant} step at position {i} diverges by {d}");
    }
    assert!(b.close_session(sid));
}

#[test]
fn incremental_decode_matches_full_forward_across_variants_and_impls() {
    let tokens = prompt_tokens(20);
    for kernel in [Kernel::Tiled, Kernel::Naive] {
        for imp in [linalg::Impl::Blocked, linalg::Impl::Scalar, linalg::Impl::Simd] {
            let b = NativeBackend::with_impls(kernel, imp);
            let label = format!("{}+{}", kernel.name(), imp.name());
            for variant in ["mha", "gqa", "mqa", "sqa", "xsqa"] {
                check_decode_matches_forward(&b, variant, &tokens, 7, &label);
            }
        }
    }
}

#[test]
fn incremental_decode_matches_forward_for_ssqa_and_window_variants() {
    // sSQA (the deliberately-larger-cache variant) on the default impls,
    // and the sliding-window variants with the context pushed *past* the
    // window (tiny's SWA window is 128) so decode masking actually trims.
    let b = NativeBackend::new();
    check_decode_matches_forward(&b, "ssqa", &prompt_tokens(20), 7, "default");
    let long = prompt_tokens(140);
    for variant in ["swa", "swsqa"] {
        check_decode_matches_forward(&b, variant, &long, 120, "default");
    }
}

#[test]
fn pattern_sessions_decode_like_their_pattern_forward() {
    // Sparse masks must not drift between prefill and decode: a session
    // opened through `tiled@<pattern>` has to reproduce the stateless
    // `forward_impl` rows of the *same* pattern at every position — and
    // the naive lowering of the same pattern must agree too.
    let b = NativeBackend::new();
    let tokens = prompt_tokens(20);
    let (split, t_len) = (7usize, 20usize);
    for variant in ["sqa", "gqa"] {
        let params = b.init_params("tiny", variant, 5).unwrap();
        for pat in ["window:5", "strided:3", "dilated:2:3", "sink:2:4"] {
            let tiled = format!("tiled@{pat}");
            let naive = format!("naive@{pat}");
            let full = b
                .forward_impl(&tiled, "tiny", variant, &params, &tokens, 1, t_len)
                .unwrap();
            let full_n = b
                .forward_impl(&naive, "tiny", variant, &params, &tokens, 1, t_len)
                .unwrap();
            assert!(max_diff(&full, &full_n) < 1e-4, "{variant}@{pat}: kernels");
            let (sid, logits) = b
                .prefill_impl(&tiled, "tiny", variant, &params, &tokens[..split], t_len)
                .unwrap();
            let d = max_diff(&logits, &full[(split - 1) * VOCAB..split * VOCAB]);
            assert!(d < 1e-4, "{variant}@{pat}: prefill logits diverge by {d}");
            for i in split..t_len {
                let l = b.decode_step(sid, &params, tokens[i]).unwrap();
                let d = max_diff(&l, &full[i * VOCAB..(i + 1) * VOCAB]);
                assert!(d < 1e-4, "{variant}@{pat}: step {i} diverges by {d}");
            }
            assert!(b.close_session(sid));
            // The pattern is load-bearing: it must differ from the dense run
            // once the context outgrows the local window.
            let dense = b
                .forward_impl("tiled", "tiny", variant, &params, &tokens, 1, t_len)
                .unwrap();
            assert!(
                max_diff(&full, &dense) > 1e-3,
                "{variant}@{pat}: pattern masked nothing"
            );
        }
    }
}

#[test]
fn single_token_prompt_decodes_correctly() {
    // The smallest possible prefill: one token, then decode from there.
    let b = NativeBackend::new();
    let tokens = prompt_tokens(6);
    check_decode_matches_forward(&b, "sqa", &tokens, 1, "single-token");
}

#[test]
fn prompt_longer_than_cache_is_rejected() {
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "sqa", 1).unwrap();
    let tokens = prompt_tokens(12);
    let err = b.prefill("tiny", "sqa", &params, &tokens, 8).unwrap_err();
    assert!(err.to_string().contains("exceeds"), "{err:#}");
    // Exactly filling the cache is allowed (prefill-only session).
    let (sid, _) = b.prefill("tiny", "sqa", &params, &tokens, 12).unwrap();
    let stats = b.session_stats(sid).unwrap();
    assert_eq!((stats.len, stats.capacity), (12, 12));
    // ...but the next step must fail with the session kept alive.
    assert!(b.decode_step(sid, &params, 1).is_err());
    assert_eq!(b.session_stats(sid).unwrap().len, 12);
    assert!(b.close_session(sid));
}

#[test]
fn closing_a_session_mid_generation_stops_it() {
    // Eviction at the backend boundary: the coordinator closes sessions
    // whose budget expired; subsequent steps must fail cleanly and the
    // cache must be gone (close is the only reclamation path).
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "gqa", 9).unwrap();
    let (sid, _) = b.prefill("tiny", "gqa", &params, &prompt_tokens(4), 32).unwrap();
    b.decode_step(sid, &params, 42).unwrap();
    assert!(b.close_session(sid), "first close reclaims");
    assert!(!b.close_session(sid), "second close is a no-op");
    let err = b.decode_step(sid, &params, 43).unwrap_err();
    assert!(err.to_string().contains("unknown"), "{err:#}");
    assert!(b.session_stats(sid).is_err());
}

#[test]
fn cache_bytes_follow_hkv_ordering() {
    // The paper's §5.2 decode axis as *observable* buffer sizes: at the
    // same context, bytes/step scale with Hkv alone. tiny (H=8):
    // GQA(8,2) == xSQA(2,2), sSQA(4,4) = 2x, MHA(8,8) = 4x, MQA(8,1) = ½x.
    let b = NativeBackend::new();
    let tokens = prompt_tokens(16);
    let bytes = |variant: &str| -> u64 {
        let params = b.init_params("tiny", variant, 3).unwrap();
        let (sid, _) = b.prefill("tiny", variant, &params, &tokens, 16).unwrap();
        let st = b.session_stats(sid).unwrap();
        b.close_session(sid);
        st.kv_bytes
    };
    let (mha, gqa, mqa, ssqa, xsqa) =
        (bytes("mha"), bytes("gqa"), bytes("mqa"), bytes("ssqa"), bytes("xsqa"));
    assert_eq!(xsqa, gqa, "xSQA must match GQA's cache exactly (§5.2)");
    assert_eq!(ssqa, 2 * gqa, "sSQA carries 2x GQA's cache (§5.1)");
    assert_eq!(mha, 4 * gqa);
    assert_eq!(2 * mqa, gqa);
    // And the absolute value is the analytic model's cache term:
    // 2 bytes-dirs * 2 layers * 16 tokens * Hkv * 16 dh * 4 B.
    assert_eq!(gqa, 2 * 2 * 16 * 2 * 16 * 4);
}

#[test]
fn half_precision_kv_decode_tracks_f32_within_narrowing_error() {
    // An f16/bf16-cache session decodes the same tokens as the f32
    // session to within the narrowing's resolution. Tolerances are
    // deliberate, not tight: f16 keeps ~11 mantissa bits (rel ~2^-11) and
    // bf16 ~8 (rel ~2^-8) per cached element, and the error compounds
    // through 2 layers of attention + projections before the LM head, so
    // we allow roughly 40x the single-element error on the logits. The
    // *exactness* contract (cache reads == the narrow-then-widen mirror of
    // what was written) is pinned elementwise in runtime::session's unit
    // tests; end-to-end only closeness is meaningful.
    let f32_b = NativeBackend::new();
    let tokens = prompt_tokens(16);
    let (split, t_len) = (6usize, 16usize);
    for variant in ["sqa", "ssqa"] {
        let params = f32_b.init_params("tiny", variant, 5).unwrap();
        let full = f32_b.forward("tiny", variant, &params, &tokens, 1, t_len).unwrap();
        for (dtype, tol) in [(KvDtype::F16, 2e-2f32), (KvDtype::Bf16, 1.5e-1f32)] {
            let b = NativeBackend::new().with_kv_dtype(dtype);
            let (sid, logits) = b
                .prefill("tiny", variant, &params, &tokens[..split], t_len)
                .unwrap();
            let d = max_diff(&logits, &full[(split - 1) * VOCAB..split * VOCAB]);
            assert!(d < tol, "{variant}/{} prefill diverges by {d}", dtype.name());
            for i in split..t_len {
                let l = b.decode_step(sid, &params, tokens[i]).unwrap();
                let d = max_diff(&l, &full[i * VOCAB..(i + 1) * VOCAB]);
                assert!(d < tol, "{variant}/{} step {i} diverges by {d}", dtype.name());
            }
            let st = b.session_stats(sid).unwrap();
            assert_eq!(st.len, t_len);
            assert_eq!(st.kv_bytes % 2, 0);
            assert!(b.close_session(sid));
        }
        // Engagement check: a bf16 cache cannot reproduce the f32 session
        // bit-for-bit over a 10-step decode (it would imply the cache
        // never narrowed anything).
        let b = NativeBackend::new().with_kv_dtype(KvDtype::Bf16);
        let (sid, _) = b
            .prefill("tiny", variant, &params, &tokens[..split], t_len)
            .unwrap();
        let mut any_diff = false;
        for i in split..t_len {
            let l = b.decode_step(sid, &params, tokens[i]).unwrap();
            any_diff |= l != full[i * VOCAB..(i + 1) * VOCAB];
        }
        assert!(any_diff, "{variant}: bf16 cache produced bit-identical logits");
        assert!(b.close_session(sid));
    }
}

#[test]
fn cache_byte_ordering_survives_half_precision() {
    // The §5.2 ordering re-checked at 2 bytes/elem: the dtype scales every
    // variant's cache uniformly, so xSQA == GQA < sSQA < MHA must hold
    // under f16 exactly as under f32 — at literally half the bytes.
    let b = NativeBackend::new().with_kv_dtype(KvDtype::F16);
    let tokens = prompt_tokens(16);
    let bytes = |variant: &str| -> u64 {
        let params = b.init_params("tiny", variant, 3).unwrap();
        let (sid, _) = b.prefill("tiny", variant, &params, &tokens, 16).unwrap();
        let st = b.session_stats(sid).unwrap();
        b.close_session(sid);
        st.kv_bytes
    };
    let (mha, gqa, ssqa, xsqa) = (bytes("mha"), bytes("gqa"), bytes("ssqa"), bytes("xsqa"));
    assert_eq!(xsqa, gqa, "xSQA must still match GQA's cache exactly");
    assert_eq!(ssqa, 2 * gqa, "sSQA still carries 2x GQA's cache");
    assert_eq!(mha, 4 * gqa);
    // Absolute term: 2 dirs * 2 layers * 16 tokens * Hkv=2 * 16 dh * 2 B.
    assert_eq!(gqa, 2 * 2 * 16 * 2 * 16 * 2);
}

#[test]
fn windowed_sessions_report_window_capped_step_bytes() {
    // tiny/swsqa: Hq=4, Hkv=2, window 128. Past the window, a decode step
    // only streams the visible 128 rows (mask-aware tile skipping), and
    // session_stats must report that — matching flops::decode's eff_s —
    // while the allocation stays the full capacity.
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "swsqa", 4).unwrap();
    let tokens = prompt_tokens(140);
    let (sid, _) = b.prefill("tiny", "swsqa", &params, &tokens, 140).unwrap();
    let st = b.session_stats(sid).unwrap();
    assert_eq!(st.len, 140);
    assert_eq!(st.kv_bytes, 2 * 2 * 128 * 32 * 4);
    assert_eq!(st.alloc_bytes, 2 * 2 * 140 * 32 * 4);
    assert!(b.close_session(sid));
}

#[test]
fn sessions_are_isolated() {
    // Two interleaved sessions with different prompts must not bleed into
    // each other's caches: each must still match its own full forward.
    let b = NativeBackend::new();
    let params = b.init_params("tiny", "sqa", 21).unwrap();
    let ta = prompt_tokens(12);
    let tb: Vec<i32> = (0..12).map(|i| ((i * 71 + 5) % VOCAB) as i32).collect();
    let fa = b.forward("tiny", "sqa", &params, &ta, 1, 12).unwrap();
    let fb = b.forward("tiny", "sqa", &params, &tb, 1, 12).unwrap();
    let (sa, _) = b.prefill("tiny", "sqa", &params, &ta[..4], 16).unwrap();
    let (sb, _) = b.prefill("tiny", "sqa", &params, &tb[..4], 16).unwrap();
    for i in 4..12 {
        // Interleave the two sessions' steps.
        let la = b.decode_step(sa, &params, ta[i]).unwrap();
        let lb = b.decode_step(sb, &params, tb[i]).unwrap();
        assert!(max_diff(&la, &fa[i * VOCAB..(i + 1) * VOCAB]) < 1e-4, "A@{i}");
        assert!(max_diff(&lb, &fb[i * VOCAB..(i + 1) * VOCAB]) < 1e-4, "B@{i}");
    }
    assert!(b.close_session(sa));
    assert!(b.close_session(sb));
}

// ---- paged KV allocator differentials ------------------------------------

/// A small paging granule so a ~20-token prompt exercises several full
/// blocks plus a partial tail (the COW/publish boundary cases).
fn paged_cfg() -> PagedConfig {
    PagedConfig { block_len: 4, pool_blocks: 512, spill_dir: None }
}

#[test]
fn paged_decode_is_bitwise_identical_to_contiguous() {
    // The paged allocator is a storage-layout refactor, not a numeric one:
    // writes narrow and reads widen through the same dtype codecs the
    // contiguous slab uses, and `layer_upto` hands the kernel the same f32
    // rows in the same order. So at the same KvDtype every logit must be
    // *bitwise* identical — any tolerance here would hide a gather bug.
    let tokens = prompt_tokens(21);
    let (split, t_len) = (9usize, 21usize);
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Bf16] {
        let paged = NativeBackend::new().with_kv_dtype(dtype).with_paged(Some(paged_cfg()));
        let contig = NativeBackend::new().with_kv_dtype(dtype).with_paged(None);
        for variant in ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa"] {
            let label = format!("{variant}/{}", dtype.name());
            let params = paged.init_params("tiny", variant, 5).unwrap();
            let (sp, lp) =
                paged.prefill("tiny", variant, &params, &tokens[..split], t_len).unwrap();
            let (sc, lc) =
                contig.prefill("tiny", variant, &params, &tokens[..split], t_len).unwrap();
            assert_eq!(lp, lc, "{label}: prefill logits differ");
            // Identity accounting right after prefill: the visible step
            // bytes are a pure function of the cached length (identical),
            // while the paged backing is block-lazy — ceil(9/4) = 3 blocks
            // of 4 positions < the contiguous capacity-21 slab.
            let (stp, stc) =
                (paged.session_stats(sp).unwrap(), contig.session_stats(sc).unwrap());
            assert_eq!(stp.kv_bytes, stc.kv_bytes, "{label}: step bytes");
            assert!(
                stp.alloc_bytes < stc.alloc_bytes,
                "{label}: paged alloc {} not lazier than contiguous {}",
                stp.alloc_bytes,
                stc.alloc_bytes
            );
            for i in split..t_len {
                let a = paged.decode_step(sp, &params, tokens[i]).unwrap();
                let b = contig.decode_step(sc, &params, tokens[i]).unwrap();
                assert_eq!(a, b, "{label}: step {i} differs");
            }
            assert!(paged.close_session(sp));
            assert!(contig.close_session(sc));
        }
        // Closing every session must return all non-trie blocks; what stays
        // resident is exactly the reclaimable published-prefix set.
        let ps = paged.kv_pool_stats().unwrap();
        assert_eq!(ps.blocks_in_use(), ps.blocks_reclaimable, "leak past the trie");
    }
}

#[test]
fn prefix_hit_prefill_matches_stateless_reforward() {
    // Copy-on-write prefix sharing: a donor session publishes its full
    // blocks into the trie; re-prefilling the same prompt adopts the
    // shared span (skipping its compute) and only the tail runs. The
    // adopted cache must be indistinguishable from recomputing — logits
    // and every subsequent decode step match the stateless forward.
    let b = NativeBackend::new().with_paged(Some(paged_cfg()));
    let tokens = prompt_tokens(20);
    for variant in ["gqa", "sqa"] {
        let params = b.init_params("tiny", variant, 7).unwrap();
        let full = b.forward("tiny", variant, &params, &tokens, 1, 20).unwrap();
        let (donor, _) = b.prefill("tiny", variant, &params, &tokens[..12], 20).unwrap();
        // Trie refs outlive the session that published them.
        assert!(b.close_session(donor));
        let before = b.kv_pool_stats().unwrap();
        let (sid, logits) = b.prefill("tiny", variant, &params, &tokens[..12], 20).unwrap();
        let after = b.kv_pool_stats().unwrap();
        assert_eq!(after.prefix_queries, before.prefix_queries + 1, "{variant}");
        assert_eq!(after.prefix_hits, before.prefix_hits + 1, "{variant}: no trie hit");
        // 12 prompt tokens publish 3 full 4-token chunks. The lookup span
        // is capped at len-1 = 11, so the hit descends 2 full chunks and
        // then partially matches the third (m = 3) — 11 adopted tokens,
        // with position 11 recomputed (and COW'd into the shared tail).
        assert_eq!(after.prefix_hit_tokens, before.prefix_hit_tokens + 11, "{variant}");
        let d = max_diff(&logits, &full[11 * VOCAB..12 * VOCAB]);
        assert!(d < 1e-4, "{variant}: adopted prefill diverges by {d}");
        for i in 12..20 {
            let l = b.decode_step(sid, &params, tokens[i]).unwrap();
            let d = max_diff(&l, &full[i * VOCAB..(i + 1) * VOCAB]);
            assert!(d < 1e-4, "{variant}: step {i} after adoption diverges by {d}");
        }
        assert!(b.close_session(sid));
    }
}

#[test]
fn paged_pattern_sessions_match_contiguous_pattern_decode() {
    // Sparse masks compose with paging: a `tiled@<pattern>` session on the
    // block pool must stay bitwise identical to the contiguous session of
    // the same pattern at every step (masking happens in the kernel, after
    // the gather — the allocator must not perturb either side).
    let paged = NativeBackend::new().with_paged(Some(paged_cfg()));
    let contig = NativeBackend::new();
    let tokens = prompt_tokens(20);
    let params = paged.init_params("tiny", "sqa", 5).unwrap();
    for pat in ["window:5", "sink:2:4"] {
        let tiled = format!("tiled@{pat}");
        let (sp, lp) = paged
            .prefill_impl(&tiled, "tiny", "sqa", &params, &tokens[..7], 20)
            .unwrap();
        let (sc, lc) = contig
            .prefill_impl(&tiled, "tiny", "sqa", &params, &tokens[..7], 20)
            .unwrap();
        assert_eq!(lp, lc, "sqa@{pat}: prefill logits differ");
        for i in 7..20 {
            let a = paged.decode_step(sp, &params, tokens[i]).unwrap();
            let b = contig.decode_step(sc, &params, tokens[i]).unwrap();
            assert_eq!(a, b, "sqa@{pat}: step {i} differs");
        }
        assert!(paged.close_session(sp));
        assert!(contig.close_session(sc));
    }
}

#[test]
fn evict_restore_roundtrip_is_exact() {
    // LRU eviction round-trip: spill a session's exclusive blocks to disk,
    // then decode — the first step restores transparently and every logit
    // must be bitwise identical to a twin that never left the pool. Run at
    // f32 and f16 so the spill file's raw-byte codec is exercised at both
    // element widths.
    let dir = std::env::temp_dir()
        .join(format!("sqa-decode-diff-spill-{}", std::process::id()));
    for dtype in [KvDtype::F32, KvDtype::F16] {
        let cfg = PagedConfig { spill_dir: Some(dir.clone()), ..paged_cfg() };
        let b = NativeBackend::new().with_kv_dtype(dtype).with_paged(Some(cfg));
        let twin = NativeBackend::new().with_kv_dtype(dtype).with_paged(Some(paged_cfg()));
        let tokens = prompt_tokens(18);
        let params = b.init_params("tiny", "sqa", 3).unwrap();
        let (sa, la) = b.prefill("tiny", "sqa", &params, &tokens[..10], 18).unwrap();
        let (st, lt) = twin.prefill("tiny", "sqa", &params, &tokens[..10], 18).unwrap();
        assert_eq!(la, lt, "{}: prefill twins differ", dtype.name());
        // 10 tokens = 2 published (trie-shared, pinned resident) chunks +
        // one exclusive partial block — exactly that block spills.
        let spilled = b.spill_session(sa).unwrap();
        assert_eq!(spilled, 1, "{}: spill set", dtype.name());
        let ps = b.kv_pool_stats().unwrap();
        assert_eq!(ps.evictions, 1, "{}", dtype.name());
        assert_eq!(ps.blocks_spilled, 1, "{}", dtype.name());
        for i in 10..18 {
            let l = b.decode_step(sa, &params, tokens[i]).unwrap();
            let l2 = twin.decode_step(st, &params, tokens[i]).unwrap();
            assert_eq!(l, l2, "{}: step {i} after restore differs", dtype.name());
        }
        let ps = b.kv_pool_stats().unwrap();
        assert_eq!(ps.restores, ps.evictions, "{}: spill never restored", dtype.name());
        assert_eq!(ps.blocks_spilled, 0, "{}", dtype.name());
        assert!(b.close_session(sa));
        assert!(twin.close_session(st));
    }
    std::fs::remove_dir_all(&dir).ok();
}
