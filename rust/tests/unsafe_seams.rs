//! Miri-sized exercise of the crate's unsafe (and unsafe-adjacent) seams.
//!
//! Run under Miri (CI `miri` job, or locally
//! `cargo +nightly miri test --test unsafe_seams`) to check for UB; the
//! same tests run under plain `cargo test` as cheap functional coverage.
//! Three seams, per ISSUE 6:
//!
//! 1. `ThreadPool::run_borrowed` — the lifetime-erasing `transmute` that
//!    lets pool jobs borrow the caller's stack. Miri validates that no
//!    borrow outlives the latch wait (it tracks the erased lifetimes as
//!    raw provenance).
//! 2. `linalg` strided views — the `MatRef {data, off, rs, cs}` tiles the
//!    blocked GEMM walks. All indexing is safe Rust, but the stride
//!    arithmetic is exactly where an off-by-one turns into OOB; Miri (and
//!    the scalar-vs-blocked differential here) pins it.
//! 3. Tensor / KvCache slab indexing — flat `[a,b,c,d]` and per-layer
//!    `[capacity, dkv]` buffers addressed by hand-rolled index math.
//!
//! Shapes are deliberately tiny (Miri runs ~100x slower than native) and
//! nothing here touches wall clocks or sleeps, so the suite runs with
//! Miri's isolation on.

use sqa::attention::tensor::Tensor;
use sqa::linalg::{self, Impl};
use sqa::runtime::session::{KvCache, SessionTable, TakeError};
use sqa::util::threadpool::ThreadPool;

/// Deterministic, libm-free fill: small signed fractions.
fn fill(n: usize, salt: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(salt.wrapping_mul(97));
            ((h >> 7) % 17) as f32 / 8.0 - 1.0
        })
        .collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

// ---- seam 1: run_borrowed lifetime erasure ---------------------------------

#[test]
fn run_borrowed_borrows_stay_inside_the_latch() {
    let pool = ThreadPool::new(2, 8);
    let input: Vec<u64> = (0..24).collect();
    let mut out = vec![0u64; 24];
    {
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (i, chunk) in out.chunks_mut(6).enumerate() {
            let src = &input[i * 6..(i + 1) * 6];
            jobs.push(Box::new(move || {
                for (o, &s) in chunk.iter_mut().zip(src) {
                    *o = s * 3 + 1;
                }
            }));
        }
        pool.run_borrowed(jobs);
    }
    assert!(out.iter().enumerate().all(|(i, &x)| x == 3 * i as u64 + 1));
}

#[test]
fn run_borrowed_batches_do_not_leak_state_across_calls() {
    // Two consecutive batches on one pool: the second batch's borrows are
    // fresh — any guard/latch state bleeding over would show up as a
    // count mismatch or, under Miri, a stale-provenance access.
    let pool = ThreadPool::new(2, 4);
    for round in 0u64..3 {
        let mut acc = vec![0u64; 4];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for slot in acc.iter_mut() {
                jobs.push(Box::new(move || *slot = round + 1));
            }
            pool.run_borrowed(jobs);
        }
        assert_eq!(acc, vec![round + 1; 4]);
    }
    pool.run_borrowed(Vec::new()); // empty batch: wait() on n = 0
}

#[test]
fn pool_drop_after_borrowed_batches_is_clean() {
    // Worker teardown after erased-lifetime jobs ran: Miri checks the
    // joined threads left no dangling references behind.
    let pool = ThreadPool::new(2, 4);
    let data = [1u8, 2, 3, 4];
    let mut sums = [0u32; 2];
    {
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (i, s) in sums.iter_mut().enumerate() {
            let half = &data[i * 2..i * 2 + 2];
            jobs.push(Box::new(move || *s = half.iter().map(|&b| b as u32).sum()));
        }
        pool.run_borrowed(jobs);
    }
    assert_eq!(sums, [3, 7]);
    drop(pool);
}

// ---- seam 2: linalg strided views ------------------------------------------

#[test]
fn score_block_strided_scalar_vs_blocked() {
    // Head-interleaved slab geometry: row r of the view lives at
    // slab[r * stride + off ..][..d]. Offsets chosen so a stride slip
    // lands outside the buffer (Miri aborts) or off the differential.
    let (d, tq, tk, i0, j0) = (4usize, 3usize, 5usize, 1usize, 2usize);
    let (q_stride, q_off, kv_stride, kv_off, s_stride) = (11usize, 2usize, 9usize, 1usize, 6usize);
    let q = fill((i0 + tq - 1) * q_stride + q_off + d, 1);
    let k = fill((j0 + tk - 1) * kv_stride + kv_off + d, 2);
    let mut s_scalar = vec![f32::NAN; (tq - 1) * s_stride + tk + 1];
    let mut s_blocked = s_scalar.clone();
    for (imp, out) in [(Impl::Scalar, &mut s_scalar), (Impl::Blocked, &mut s_blocked)] {
        linalg::score_block(
            imp, &q, q_stride, q_off, i0, tq, &k, kv_stride, kv_off, j0, tk, d, 0.25, out,
            s_stride,
        );
    }
    for i in 0..tq {
        assert_close(
            &s_scalar[i * s_stride..i * s_stride + tk],
            &s_blocked[i * s_stride..i * s_stride + tk],
            "score row",
        );
    }
}

#[test]
fn pv_and_ptx_blocks_strided_scalar_vs_blocked() {
    let (d, tq, tk, j0, row0) = (4usize, 3usize, 5usize, 2usize, 1usize);
    let (p_stride, kv_stride, kv_off) = (6usize, 9usize, 1usize);
    let probs = fill((tq - 1) * p_stride + tk + 1, 3)
        .iter()
        .map(|x| x.abs()) // probabilities: non-negative
        .collect::<Vec<_>>();
    let v = fill((j0 + tk - 1) * kv_stride + kv_off + d, 4);

    let (o_stride, o_off) = (7usize, 2usize);
    let base = fill((tq - 1) * o_stride + o_off + d, 5);
    let mut o_scalar = base.clone();
    let mut o_blocked = base;
    for (imp, out) in [(Impl::Scalar, &mut o_scalar), (Impl::Blocked, &mut o_blocked)] {
        linalg::pv_block(
            imp, &probs, p_stride, tq, tk, &v, kv_stride, kv_off, j0, d, out, o_stride, o_off,
        );
    }
    assert_close(&o_scalar, &o_blocked, "pv_block out slab");

    // dK/dV shape: out rows indexed j0 + jj, input rows row0 + ti.
    let (x_stride, x_off) = (10usize, 3usize);
    let x = fill((row0 + tq - 1) * x_stride + x_off + d, 6);
    let base = fill((j0 + tk - 1) * o_stride + o_off + d, 7);
    let mut t_scalar = base.clone();
    let mut t_blocked = base;
    for (imp, out) in [(Impl::Scalar, &mut t_scalar), (Impl::Blocked, &mut t_blocked)] {
        linalg::ptx_block(
            imp, &probs, p_stride, tq, tk, &x, x_stride, x_off, row0, d, out, o_stride, o_off, j0,
        );
    }
    assert_close(&t_scalar, &t_blocked, "ptx_block out slab");
}

#[test]
fn gemm_entrypoints_scalar_vs_blocked() {
    let (s, m, n) = (4usize, 3usize, 5usize);
    let x = fill(s * m, 8);
    let w = fill(m * n, 9);
    let bias = fill(n, 10);

    let a = linalg::matmul(Impl::Scalar, &x, &w, s, m, n, None);
    let b = linalg::matmul(Impl::Blocked, &x, &w, s, m, n, None);
    assert_close(&a, &b, "matmul");

    let mut ya = vec![0.0; s * n];
    let mut yb = vec![0.0; s * n];
    linalg::matmul_bias_into(Impl::Scalar, &x, &w, &bias, &mut ya, s, m, n, None);
    linalg::matmul_bias_into(Impl::Blocked, &x, &w, &bias, &mut yb, s, m, n, None);
    assert_close(&ya, &yb, "matmul_bias_into");

    let dy = fill(s * n, 11);
    let mut ga = fill(m * n, 12);
    let mut gb = ga.clone();
    linalg::accum_xt_dy(Impl::Scalar, &mut ga, &x, &dy, s, m, n);
    linalg::accum_xt_dy(Impl::Blocked, &mut gb, &x, &dy, s, m, n);
    assert_close(&ga, &gb, "accum_xt_dy");

    let mut dxa = fill(s * m, 13);
    let mut dxb = dxa.clone();
    linalg::accum_dy_wt(Impl::Scalar, &mut dxa, &dy, &w, s, m, n);
    linalg::accum_dy_wt(Impl::Blocked, &mut dxb, &dy, &w, s, m, n);
    assert_close(&dxa, &dxb, "accum_dy_wt");
}

// ---- seam 3: tensor / KV slab indexing -------------------------------------

#[test]
fn tensor_slab_indexing_round_trips() {
    let (a, b, c, d) = (2usize, 3usize, 4usize, 5usize);
    let mut t = Tensor::zeros(&[a, b, c, d]);
    for ia in 0..a {
        for ib in 0..b {
            for ic in 0..c {
                for id in 0..d {
                    let v = (((ia * b + ib) * c + ic) * d + id) as f32;
                    t.set4(ia, ib, ic, id, v);
                }
            }
        }
    }
    // idx4 is exactly the row-major flattening...
    let (ia, ib, ic, id) = (1usize, 2usize, 3usize, 4usize);
    assert_eq!(t.idx4(ia, ib, ic, id), ((ia * b + ib) * c + ic) * d + id);
    // ...and get4/row4 read back what set4 wrote, at the slab edges too.
    assert_eq!(t.get4(a - 1, b - 1, c - 1, d - 1), (a * b * c * d - 1) as f32);
    let row = t.row4(1, 2, 3);
    assert_eq!(row.len(), d);
    assert_eq!(row[0], t.get4(1, 2, 3, 0));
    assert_eq!(row[d - 1], t.get4(1, 2, 3, d - 1));
}

#[test]
fn kv_cache_slab_writes_and_reads() {
    let (layers, cap, dkv) = (2usize, 3usize, 4usize);
    let mut kv = KvCache::new(layers, cap, dkv);
    for step in 0..cap {
        for l in 0..layers {
            let k: Vec<f32> = (0..dkv).map(|i| (step * 10 + l + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            kv.write(l, &k, &v).unwrap();
        }
        kv.advance(1).unwrap();
    }
    assert_eq!(kv.len(), cap);
    let (k0, v0) = kv.layer_upto(0, cap);
    assert_eq!(k0.len(), cap * dkv);
    assert_eq!(k0[(cap - 1) * dkv], ((cap - 1) * 10) as f32);
    assert_eq!(v0[(cap - 1) * dkv], -(((cap - 1) * 10) as f32));
    assert_eq!(kv.live_bytes(), 2 * layers * cap * dkv * 4);
}

#[test]
fn session_table_protocol_under_miri() {
    // The Busy-marker protocol with a real thread interleaving (Miri
    // explores a few schedules and checks the Box<S> ownership handoff).
    let tab = std::sync::Arc::new(SessionTable::new());
    let id = tab.insert(vec![0u8; 8]);
    let t = {
        let tab = std::sync::Arc::clone(&tab);
        std::thread::spawn(move || match tab.take(id) {
            Ok(mut s) => {
                s[0] = 1;
                tab.put_back(id, s)
            }
            Err(TakeError::Busy) | Err(TakeError::Unknown) => false,
        })
    };
    let closed = tab.close(id);
    let _stepped = t.join().unwrap();
    assert!(closed, "entry (ready or busy) must be removable exactly once");
    assert!(tab.is_empty(), "no resurrection after close");
}
