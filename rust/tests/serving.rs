//! Serving-path integration tests: engine, TCP server, wire protocol,
//! backpressure, batching behaviour under concurrent load.

use sqa::config::ServeConfig;
use sqa::coordinator::{Engine, Reject};
use sqa::runtime::{Backend, NativeBackend};
use sqa::server::{Client, Server};
use sqa::util::json::Json;
use std::sync::{Arc, OnceLock};

fn rt() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| Arc::new(NativeBackend::new()))
}

fn cfg() -> ServeConfig {
    ServeConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait_ms: 3,
        workers: 1,
        queue_capacity: 64,
        kernel: None,
    }
}

#[test]
fn engine_encodes_and_responds() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let resp = engine.encode(vec![5, 6, 7, 8]).unwrap();
    assert_eq!(resp.bucket, 64); // smallest tiny bucket
    assert_eq!(resp.top.len(), 5);
    assert!(resp.top[0].1 >= resp.top[1].1);
    assert!(resp.total_ms > 0.0);
    engine.shutdown();
}

#[test]
fn engine_routes_by_length() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    assert_eq!(engine.encode(vec![1; 60]).unwrap().bucket, 64);
    assert_eq!(engine.encode(vec![1; 65]).unwrap().bucket, 128);
    assert_eq!(engine.encode(vec![1; 256]).unwrap().bucket, 256);
    match engine.encode(vec![1; 257]) {
        Err(Reject::TooLong { max }) => assert_eq!(max, 256),
        other => panic!("expected TooLong, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
fn engine_batches_concurrent_requests() {
    let mut c = cfg();
    c.max_wait_ms = 30; // generous window so requests coalesce
    let engine = std::sync::Arc::new(Engine::start(rt(), &c, None).unwrap());
    let mut handles = Vec::new();
    for i in 0..4 {
        let e = std::sync::Arc::clone(&engine);
        handles.push(std::thread::spawn(move || {
            e.encode(vec![(4 + i) as u32; 32]).unwrap()
        }));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // At least one response should have been co-batched.
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch >= 2, "no batching observed: {responses:?}");
    assert!(engine.metrics.mean_batch_size() > 1.0);
}

#[test]
fn deterministic_logits_identical_requests() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let a = engine.encode(vec![9, 10, 11]).unwrap();
    let b = engine.encode(vec![9, 10, 11]).unwrap();
    assert_eq!(a.top, b.top, "same tokens must give same logits");
    engine.shutdown();
}

#[test]
fn padding_does_not_change_result() {
    // A request is padded to its bucket; the last-real-token logits must
    // not depend on how much padding follows (causal attention guarantee,
    // checked through the whole serving stack).
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let short = engine.encode(vec![42; 10]).unwrap(); // bucket 64, pad 54
    let engine2 = Engine::start(rt(), &cfg(), None).unwrap();
    let same = engine2.encode(vec![42; 10]).unwrap();
    assert_eq!(short.top, same.top);
    engine.shutdown();
    engine2.shutdown();
}

#[test]
fn kernel_override_serves_and_matches_default() {
    // The serving path runs the tiled kernel by default; forcing the naive
    // oracle through the engine must serve the same top-k (differential
    // check through the whole batching/padding stack).
    let tiled = Engine::start(rt(), &cfg(), None).unwrap();
    let want = tiled.encode(vec![7, 8, 9, 10]).unwrap();
    tiled.shutdown();
    let mut c = cfg();
    c.kernel = Some("naive".into());
    let naive = Engine::start(rt(), &c, None).unwrap();
    let got = naive.encode(vec![7, 8, 9, 10]).unwrap();
    naive.shutdown();
    let ids = |r: &sqa::coordinator::EncodeResponse| -> Vec<i32> {
        r.top.iter().map(|(i, _)| *i).collect()
    };
    assert_eq!(ids(&want), ids(&got), "kernels rank tokens differently");
}

#[test]
fn unknown_kernel_is_rejected_at_startup() {
    let mut c = cfg();
    c.kernel = Some("pallas".into());
    assert!(Engine::start(rt(), &c, None).is_err());
}

#[test]
fn tcp_server_roundtrip() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    let mut client = Client::connect(&addr).unwrap();
    // ping
    let pong = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));
    // tokens
    let resp = client.encode_tokens(&[4, 5, 6]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 5);
    // text (story tokenizer)
    let resp = client.encode_text("tom found a red ball").unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    // metrics
    let m = client.metrics().unwrap();
    let served = m
        .get("metrics")
        .unwrap()
        .get("responses")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(served >= 2.0);
    // malformed input
    let err = client.call(&Json::parse(r#"{"nope":1}"#).unwrap()).unwrap();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn empty_and_garbage_wire_input() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(b"this is not json\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));

    // Empty token list is rejected, connection stays alive.
    writer.write_all(b"{\"tokens\":[]}\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("ok").unwrap().as_bool(),
        Some(false)
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn trained_params_can_be_served() {
    // Wire a trained parameter vector into the engine (the deploy path).
    use sqa::config::TrainConfig;
    use sqa::train::Trainer;
    let tcfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 5,
        eval_every: 0,
        log_every: 0,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(rt(), tcfg).unwrap();
    for _ in 0..5 {
        trainer.step_once().unwrap();
    }
    let params = trainer.params_to_host().unwrap();
    let engine = Engine::start(rt(), &cfg(), Some(params)).unwrap();
    let resp = engine.encode(vec![4, 5, 6, 7]).unwrap();
    assert_eq!(resp.top.len(), 5);
    engine.shutdown();
}
