//! Serving-path integration tests: engine, TCP server, wire protocol,
//! backpressure, batching behaviour under concurrent load — and the
//! stateful generation path (prefill + incremental decode sessions,
//! continuous batching, eviction, the `generate` endpoint).

use sqa::config::ServeConfig;
use sqa::coordinator::{Engine, FinishReason, GenParams, Reject};
use sqa::runtime::{Backend, NativeBackend};
use sqa::server::{Client, Server};
use sqa::util::json::Json;
use std::sync::{Arc, OnceLock};

fn rt() -> &'static Arc<dyn Backend> {
    static B: OnceLock<Arc<dyn Backend>> = OnceLock::new();
    B.get_or_init(|| Arc::new(NativeBackend::new()))
}

fn cfg() -> ServeConfig {
    ServeConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        max_wait_ms: 3,
        workers: 1,
        queue_capacity: 64,
        ..ServeConfig::default()
    }
}

#[test]
fn engine_encodes_and_responds() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let resp = engine.encode(vec![5, 6, 7, 8]).unwrap();
    assert_eq!(resp.bucket, 64); // smallest tiny bucket
    assert_eq!(resp.top.len(), 5);
    assert!(resp.top[0].1 >= resp.top[1].1);
    assert!(resp.total_ms > 0.0);
    engine.shutdown();
}

#[test]
fn engine_routes_by_length() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    assert_eq!(engine.encode(vec![1; 60]).unwrap().bucket, 64);
    assert_eq!(engine.encode(vec![1; 65]).unwrap().bucket, 128);
    assert_eq!(engine.encode(vec![1; 256]).unwrap().bucket, 256);
    match engine.encode(vec![1; 257]) {
        Err(Reject::TooLong { max }) => assert_eq!(max, 256),
        other => panic!("expected TooLong, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
fn engine_batches_concurrent_requests() {
    let mut c = cfg();
    c.max_wait_ms = 30; // generous window so requests coalesce
    let engine = std::sync::Arc::new(Engine::start(rt(), &c, None).unwrap());
    let mut handles = Vec::new();
    for i in 0..4 {
        let e = std::sync::Arc::clone(&engine);
        handles.push(std::thread::spawn(move || {
            e.encode(vec![(4 + i) as u32; 32]).unwrap()
        }));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // At least one response should have been co-batched.
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch >= 2, "no batching observed: {responses:?}");
    assert!(engine.metrics.mean_batch_size() > 1.0);
}

#[test]
fn deterministic_logits_identical_requests() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let a = engine.encode(vec![9, 10, 11]).unwrap();
    let b = engine.encode(vec![9, 10, 11]).unwrap();
    assert_eq!(a.top, b.top, "same tokens must give same logits");
    engine.shutdown();
}

#[test]
fn padding_does_not_change_result() {
    // A request is padded to its bucket; the last-real-token logits must
    // not depend on how much padding follows (causal attention guarantee,
    // checked through the whole serving stack).
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let short = engine.encode(vec![42; 10]).unwrap(); // bucket 64, pad 54
    let engine2 = Engine::start(rt(), &cfg(), None).unwrap();
    let same = engine2.encode(vec![42; 10]).unwrap();
    assert_eq!(short.top, same.top);
    engine.shutdown();
    engine2.shutdown();
}

#[test]
fn kernel_override_serves_and_matches_default() {
    // The serving path runs the tiled kernel by default; forcing the naive
    // oracle through the engine must serve the same top-k (differential
    // check through the whole batching/padding stack).
    let tiled = Engine::start(rt(), &cfg(), None).unwrap();
    let want = tiled.encode(vec![7, 8, 9, 10]).unwrap();
    tiled.shutdown();
    let mut c = cfg();
    c.kernel = Some("naive".into());
    let naive = Engine::start(rt(), &c, None).unwrap();
    let got = naive.encode(vec![7, 8, 9, 10]).unwrap();
    naive.shutdown();
    let ids = |r: &sqa::coordinator::EncodeResponse| -> Vec<i32> {
        r.top.iter().map(|(i, _)| *i).collect()
    };
    assert_eq!(ids(&want), ids(&got), "kernels rank tokens differently");
}

#[test]
fn unknown_kernel_is_rejected_at_startup() {
    let mut c = cfg();
    c.kernel = Some("pallas".into());
    assert!(Engine::start(rt(), &c, None).is_err());
}

#[test]
fn tcp_server_roundtrip() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    let mut client = Client::connect(&addr).unwrap();
    // ping
    let pong = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));
    // tokens
    let resp = client.encode_tokens(&[4, 5, 6]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 5);
    // text (story tokenizer)
    let resp = client.encode_text("tom found a red ball").unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    // metrics
    let m = client.metrics().unwrap();
    let served = m
        .get("metrics")
        .unwrap()
        .get("responses")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(served >= 2.0);
    // malformed input
    let err = client.call(&Json::parse(r#"{"nope":1}"#).unwrap()).unwrap();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn empty_and_garbage_wire_input() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(b"this is not json\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));

    // Empty token list is rejected, connection stays alive.
    writer.write_all(b"{\"tokens\":[]}\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("ok").unwrap().as_bool(),
        Some(false)
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

fn gen_params(max_tokens: usize, seed: u64) -> GenParams {
    GenParams {
        max_tokens,
        top_k: 5,
        temperature: 1.0,
        seed,
    }
}

#[test]
fn engine_generates_tokens_deterministically() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let a = engine.generate(vec![5, 6, 7], gen_params(8, 3)).unwrap();
    assert_eq!(a.prompt_len, 3);
    assert!(!a.tokens.is_empty() || a.finish == FinishReason::Eos);
    assert!(a.tokens.len() <= 8);
    assert!(matches!(a.finish, FinishReason::MaxTokens | FinishReason::Eos));
    assert!(a.prefill_ms > 0.0);
    assert!(a.kv_bytes > 0, "live KV bytes must be reported");
    // Same prompt + params + seed -> identical continuation.
    let b = engine.generate(vec![5, 6, 7], gen_params(8, 3)).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.finish, b.finish);
    // A different seed at temperature 1.0 is allowed to differ (and the
    // engine must still serve it fine).
    let c = engine.generate(vec![5, 6, 7], gen_params(8, 4)).unwrap();
    assert!(c.tokens.len() <= 8);
    // Greedy sampling ignores the seed entirely.
    let g1 = engine
        .generate(vec![9, 10], GenParams { temperature: 0.0, ..gen_params(6, 1) })
        .unwrap();
    let g2 = engine
        .generate(vec![9, 10], GenParams { temperature: 0.0, ..gen_params(6, 2) })
        .unwrap();
    assert_eq!(g1.tokens, g2.tokens);
    engine.shutdown();
}

#[test]
fn generate_validates_prompts() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    // tiny's largest bucket (256) is the default session capacity.
    assert_eq!(engine.gen_capacity, 256);
    match engine.generate(vec![1; 300], gen_params(4, 0)) {
        Err(Reject::TooLong { max }) => assert_eq!(max, 256),
        other => panic!("expected TooLong, got {other:?}"),
    }
    match engine.generate(vec![], gen_params(4, 0)) {
        Err(Reject::Failed(msg)) => assert!(msg.contains("empty")),
        other => panic!("expected Failed, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
fn generation_stops_when_the_kv_cache_fills() {
    let mut c = cfg();
    c.gen_capacity = 16;
    let engine = Engine::start(rt(), &c, None).unwrap();
    let resp = engine.generate(vec![4, 5, 6, 7], gen_params(100, 11)).unwrap();
    // prompt 4 + 12 decode steps fill the 16-slot cache; the prefill
    // sample plus 12 step samples = 13 tokens (unless EOS got sampled
    // first, which the fixed seed makes deterministic either way).
    assert!(matches!(resp.finish, FinishReason::CacheFull | FinishReason::Eos));
    if resp.finish == FinishReason::CacheFull {
        assert_eq!(resp.tokens.len(), 13);
        assert_eq!(resp.steps, 12);
        // Cache is exactly full: 2 dirs * 2 layers * 16 rows * (Hkv=2 * 16) * 4B.
        assert_eq!(resp.kv_bytes, 2 * 2 * 16 * 32 * 4);
    }
    engine.shutdown();
}

#[test]
fn sessions_over_budget_are_evicted_with_partial_output() {
    let mut c = cfg();
    c.session_timeout_ms = 0; // everything is instantly over budget
    let engine = Engine::start(rt(), &c, None).unwrap();
    let resp = engine.generate(vec![8, 9, 10], gen_params(50, 2)).unwrap();
    assert!(matches!(resp.finish, FinishReason::Evicted | FinishReason::Eos));
    assert!(resp.tokens.len() <= 2, "evicted almost immediately: {resp:?}");
    assert!(engine.metrics.evicted_sessions.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    assert_eq!(engine.metrics.active_sessions.load(std::sync::atomic::Ordering::Relaxed), 0);
    engine.shutdown();
}

#[test]
fn concurrent_generations_batch_their_decode_steps() {
    let mut c = cfg();
    c.workers = 2;
    let engine = Arc::new(Engine::start(rt(), &c, None).unwrap());
    let mut handles = Vec::new();
    for i in 0..3u64 {
        let e = Arc::clone(&engine);
        handles.push(std::thread::spawn(move || {
            e.generate(vec![4 + i as u32; 8], gen_params(16, i)).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert!(resp.tokens.len() <= 16);
    }
    let m = &engine.metrics;
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(m.gen_responses.load(ord), 3);
    assert_eq!(m.active_sessions.load(ord), 0);
    assert_eq!(m.prefill_tokens.load(ord), 24);
    assert!(m.decode_tokens.load(ord) > 0);
    // Continuous batching: concurrent sessions must share worker ticks at
    // least some of the time (the coalesce-wait makes this reliable).
    assert!(
        m.decode_steps_per_batch() > 1.0,
        "no decode coalescing observed: {} tokens / {} batches",
        m.decode_tokens.load(ord),
        m.decode_batches.load(ord)
    );
    // Per-phase counters surface in the metrics snapshot.
    let snap = m.snapshot();
    assert!(snap.get("decode_tok_per_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(snap.get("gen_requests").unwrap().as_f64(), Some(3.0));
}

#[test]
fn server_generate_endpoint_roundtrip() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    let mut client = Client::connect(&addr).unwrap();
    let params = gen_params(6, 7);
    let resp = client.generate_text("tom found a red ball", &params).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    let toks = resp.get("tokens").unwrap().as_arr().unwrap();
    assert!(toks.len() <= 6);
    assert!(resp.get("finish").unwrap().as_str().is_some());
    assert!(resp.get("text").unwrap().as_str().is_some());
    assert!(resp.get("kv_bytes").unwrap().as_f64().unwrap() >= 0.0);
    // Token-level prompt + explicit knobs.
    let resp = client.generate_tokens(&[4, 5, 6], &gen_params(3, 0)).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert!(resp.get("tokens").unwrap().as_arr().unwrap().len() <= 3);
    // Bad request: no prompt at all.
    let err = client
        .call(&Json::parse(r#"{"cmd":"generate","max_tokens":4}"#).unwrap())
        .unwrap();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
    // The metrics snapshot reflects the generation phases.
    let m = client.metrics().unwrap();
    let gm = m.get("metrics").unwrap();
    assert!(gm.get("gen_responses").unwrap().as_f64().unwrap() >= 2.0);
    assert!(gm.get("prefill_tokens").unwrap().as_f64().unwrap() >= 3.0);

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn long_generate_does_not_block_other_connections() {
    // Connections are handled on a bounded pool: while one connection
    // streams a long generate, a second connection's metrics/encode calls
    // must keep being served on another handler thread.
    let mut c = cfg();
    c.gen_capacity = 256;
    let engine = Engine::start(rt(), &c, None).unwrap();
    let server = Server::bind_with("127.0.0.1:0", engine, 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    let running = Arc::new(std::sync::atomic::AtomicBool::new(true));
    let flag = Arc::clone(&running);
    let gen_addr = addr.clone();
    let gen_thread = std::thread::spawn(move || {
        let mut c = Client::connect(&gen_addr).unwrap();
        let resp = c.generate_tokens(&[5; 4], &gen_params(200, 1)).unwrap();
        flag.store(false, std::sync::atomic::Ordering::SeqCst);
        resp
    });

    // While the generate stream occupies one handler, a second connection
    // must be served concurrently.
    let mut other = Client::connect(&addr).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(5));
    let m = other.metrics().unwrap();
    assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
    let was_running = running.load(std::sync::atomic::Ordering::SeqCst);
    let enc = other.encode_tokens(&[7, 8, 9]).unwrap();
    assert_eq!(enc.get("ok").unwrap().as_bool(), Some(true));

    let resp = gen_thread.join().unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    // On any but an absurdly fast machine the 200-step generate was still
    // in flight when metrics returned — the actual non-blocking proof.
    assert!(
        was_running,
        "generate finished before the concurrent metrics call could race it"
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

// ---- streaming generation ---------------------------------------------------

/// Drain a [`TokenStream`]: the per-token events plus the terminal summary.
fn collect_stream(
    stream: sqa::coordinator::TokenStream,
) -> (Vec<u32>, sqa::coordinator::GenerateResponse) {
    use sqa::coordinator::StreamEvent;
    let mut toks = Vec::new();
    let mut done = None;
    for ev in stream {
        match ev {
            StreamEvent::Token(t) => toks.push(t),
            StreamEvent::Done(r) => done = Some(r.expect("stream rejected")),
        }
    }
    (toks, done.expect("stream must end with a Done event"))
}

#[test]
fn streamed_generation_matches_blocking_token_for_token() {
    // Streaming changes delivery, never sampling: same prompt + params +
    // seed must produce the identical token sequence on both paths, and
    // the per-token events must equal the terminal summary's tokens.
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    for (prompt, seed) in [(vec![5u32, 6, 7], 3u64), (vec![9, 10, 11, 12], 9)] {
        let want = engine.generate(prompt.clone(), gen_params(12, seed)).unwrap();
        let (toks, resp) = collect_stream(
            engine.generate_stream(prompt.clone(), gen_params(12, seed)).unwrap(),
        );
        assert_eq!(toks, want.tokens, "streamed tokens diverge (seed {seed})");
        assert_eq!(resp.tokens, want.tokens);
        assert_eq!(resp.finish, want.finish);
        assert_eq!(resp.steps, want.steps);
        if !resp.tokens.is_empty() {
            assert!(resp.ttft_ms > 0.0, "first token implies a TTFT sample");
        }
    }
    engine.shutdown();
}

#[test]
fn streamed_matches_blocking_across_variants() {
    for variant in ["gqa", "xsqa"] {
        let mut c = cfg();
        c.variant = variant.into();
        let engine = Engine::start(rt(), &c, None).unwrap();
        let want = engine.generate(vec![5, 6, 7, 8], gen_params(10, 4)).unwrap();
        let (toks, resp) = collect_stream(
            engine.generate_stream(vec![5, 6, 7, 8], gen_params(10, 4)).unwrap(),
        );
        assert_eq!(toks, want.tokens, "{variant}: streamed diverges from blocking");
        assert_eq!(resp.finish, want.finish);
        engine.shutdown();
    }
}

#[test]
fn interleaved_streams_are_isolated() {
    use sqa::coordinator::TokenStream;
    // Two concurrent streams share scheduler wakes and decode batches but
    // must each reproduce their solo (blocking) run exactly.
    let mut c = cfg();
    c.workers = 2;
    let engine = Arc::new(Engine::start(rt(), &c, None).unwrap());
    let want_a = engine.generate(vec![4; 8], gen_params(12, 1)).unwrap();
    let want_b = engine.generate(vec![9; 8], gen_params(12, 2)).unwrap();
    let spawn = |e: Arc<Engine>, prompt: Vec<u32>, seed: u64| {
        std::thread::spawn(move || {
            let s: TokenStream = e.generate_stream(prompt, gen_params(12, seed)).unwrap();
            collect_stream(s)
        })
    };
    let ha = spawn(Arc::clone(&engine), vec![4; 8], 1);
    let hb = spawn(Arc::clone(&engine), vec![9; 8], 2);
    let (ta, ra) = ha.join().unwrap();
    let (tb, rb) = hb.join().unwrap();
    assert_eq!(ta, want_a.tokens, "stream A leaked another session's tokens");
    assert_eq!(tb, want_b.tokens, "stream B leaked another session's tokens");
    assert_eq!(ra.finish, want_a.finish);
    assert_eq!(rb.finish, want_b.finish);
    engine.shutdown();
}

#[test]
fn dropping_a_stream_cancels_and_frees_the_session() {
    use sqa::coordinator::StreamEvent;
    let ord = std::sync::atomic::Ordering::Relaxed;
    let mut c = cfg();
    c.max_sessions = 1;
    c.stream_buffer = 1; // tiny credit window: the engine pauses quickly
    let engine = Engine::start(rt(), &c, None).unwrap();
    let drain = |e: &Engine| {
        let t0 = std::time::Instant::now();
        while e.metrics.active_sessions.load(ord) != 0 {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "session never freed after stream drop"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    };
    // A seed whose first sample is EOS finishes instead of cancelling; try
    // a few (deterministic per build) so one exercises mid-stream drop.
    let mut exercised = false;
    for seed in 1..6u64 {
        let mut stream = engine
            .generate_stream(vec![4, 5, 6, 7], gen_params(200, seed))
            .unwrap();
        let first = stream.next();
        let mid_stream = matches!(first, Some(StreamEvent::Token(_)));
        drop(stream); // Cancel is sent for any unfinished stream
        drain(&engine);
        if mid_stream {
            assert!(
                engine.metrics.cancelled_sessions.load(ord) >= 1,
                "mid-stream drop must count as a cancellation"
            );
            exercised = true;
            break;
        }
    }
    assert!(exercised, "every seed sampled EOS first-token; cannot test cancel");
    // With max_sessions=1 the freed slot must be immediately reusable.
    let resp = engine.generate(vec![7, 8], gen_params(4, 2)).unwrap();
    assert!(resp.tokens.len() <= 4);
    engine.shutdown();
}

#[test]
fn stream_drop_frees_paged_kv_blocks() {
    use sqa::coordinator::StreamEvent;
    use sqa::runtime::{NativeBackend, PagedConfig};
    let ord = std::sync::atomic::Ordering::Relaxed;
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend::new().with_paged(Some(PagedConfig {
        block_len: 16,
        pool_blocks: 256,
        spill_dir: None,
    })));
    let mut c = cfg();
    c.stream_buffer = 1;
    let engine = Engine::start(&backend, &c, None).unwrap();
    let mut stream = engine
        .generate_stream(vec![4, 5, 6, 7, 8, 9], gen_params(200, 1))
        .unwrap();
    let _ = matches!(stream.next(), Some(StreamEvent::Token(_)));
    drop(stream);
    let t0 = std::time::Instant::now();
    while engine.metrics.active_sessions.load(ord) != 0 {
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "paged session never freed after stream drop"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    // Every block is back in the pool; only trie-held (reclaimable) blocks
    // may stay resident.
    let ps = engine.kv_pool_stats().expect("paged backend exposes pool stats");
    assert_eq!(
        ps.blocks_in_use(),
        ps.blocks_reclaimable,
        "stream drop leaked session-held KV blocks"
    );
    engine.shutdown();
}

#[test]
fn mid_stream_eviction_flushes_partial_tokens_then_done() {
    let mut c = cfg();
    c.session_timeout_ms = 0; // instantly over the progress budget
    let engine = Engine::start(rt(), &c, None).unwrap();
    let (toks, resp) = collect_stream(
        engine.generate_stream(vec![8, 9, 10], gen_params(50, 2)).unwrap(),
    );
    assert!(matches!(resp.finish, FinishReason::Evicted | FinishReason::Eos));
    assert_eq!(toks, resp.tokens, "eviction must flush the outbox before Done");
    assert!(resp.tokens.len() <= 2, "evicted almost immediately: {resp:?}");
    assert_eq!(
        engine.metrics.active_sessions.load(std::sync::atomic::Ordering::Relaxed),
        0
    );
    engine.shutdown();
}

#[test]
fn stalled_stream_is_evicted_on_progress_timeout() {
    // A reader that stops consuming exhausts its credit window; the session
    // stops making progress and the progress budget evicts it — delivering
    // whatever was generated instead of pinning the slot forever.
    let mut c = cfg();
    c.session_timeout_ms = 150;
    c.stream_buffer = 1;
    let engine = Engine::start(rt(), &c, None).unwrap();
    let stream = engine.generate_stream(vec![4, 5, 6], gen_params(200, 1)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(600));
    let (toks, resp) = collect_stream(stream);
    assert!(
        matches!(resp.finish, FinishReason::Evicted | FinishReason::Eos),
        "stalled stream should be evicted, got {:?}",
        resp.finish
    );
    assert_eq!(toks, resp.tokens);
    engine.shutdown();
}

#[test]
fn chunked_prefill_generates_and_is_deterministic() {
    let mut c = cfg();
    c.prefill_chunk = 8;
    let engine = Engine::start(rt(), &c, None).unwrap();
    let prompt: Vec<u32> = (0..40).map(|i| 4 + (i % 50) as u32).collect();
    let a = engine.generate(prompt.clone(), gen_params(6, 5)).unwrap();
    assert_eq!(a.prompt_len, 40);
    assert!(a.tokens.len() <= 6);
    assert!(a.prefill_ms > 0.0);
    // All 40 prompt tokens were prefilled across the 8-token chunks.
    assert!(
        engine.metrics.prefill_tokens.load(std::sync::atomic::Ordering::Relaxed) >= 40
    );
    let b = engine.generate(prompt, gen_params(6, 5)).unwrap();
    assert_eq!(a.tokens, b.tokens, "chunked prefill must stay deterministic");
    // A prompt no longer than one chunk takes the whole-prompt path and is
    // bit-exact with prefill_chunk = 0.
    let small = engine.generate(vec![5, 6, 7], gen_params(8, 3)).unwrap();
    let unchunked = Engine::start(rt(), &cfg(), None).unwrap();
    let want = unchunked.generate(vec![5, 6, 7], gen_params(8, 3)).unwrap();
    assert_eq!(small.tokens, want.tokens);
    unchunked.shutdown();
    engine.shutdown();
}

#[test]
fn server_streams_tokens_and_matches_blocking_reply() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    let mut client = Client::connect(&addr).unwrap();
    let params = gen_params(8, 7);
    let blocking = client.generate_tokens(&[4, 5, 6], &params).unwrap();
    assert_eq!(blocking.get("ok").unwrap().as_bool(), Some(true), "{blocking}");
    assert!(blocking.get("ttft_ms").unwrap().as_f64().is_some());

    let mut frame_toks: Vec<u32> = Vec::new();
    let mut terminal = None;
    for frame in client.generate_stream(&[4, 5, 6], &params).unwrap() {
        let f = frame.unwrap();
        assert_eq!(f.get("stream").unwrap().as_bool(), Some(true), "{f}");
        if f.get("done").and_then(|d| d.as_bool()) == Some(true) {
            terminal = Some(f);
        } else {
            assert!(f.get("piece").unwrap().as_str().is_some());
            frame_toks.push(f.get("token").unwrap().as_f64().unwrap() as u32);
        }
    }
    let term = terminal.expect("stream must end with a done frame");
    assert_eq!(term.get("ok").unwrap().as_bool(), Some(true), "{term}");
    let summary_toks: Vec<u32> = term
        .get("tokens").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_f64().unwrap() as u32).collect();
    let blocking_toks: Vec<u32> = blocking
        .get("tokens").unwrap().as_arr().unwrap()
        .iter().map(|t| t.as_f64().unwrap() as u32).collect();
    assert_eq!(frame_toks, summary_toks, "token frames diverge from the summary");
    assert_eq!(summary_toks, blocking_toks, "streamed and blocking wire paths diverge");
    assert!(term.get("ttft_ms").unwrap().as_f64().is_some());

    // The connection is still usable for ordinary calls after a stream.
    let pong = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));
    // A rejected stream still produces exactly one terminal frame.
    let frames: Vec<_> = client
        .generate_stream(&[], &params)
        .unwrap()
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert_eq!(frames.len(), 1, "{frames:?}");
    assert_eq!(frames[0].get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(frames[0].get("done").unwrap().as_bool(), Some(true));

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn idle_connections_are_closed_at_the_deadline() {
    let engine = Engine::start(rt(), &cfg(), None).unwrap();
    let server = Server::bind("127.0.0.1:0", engine)
        .unwrap()
        .with_idle_deadline(std::time::Duration::from_millis(300));
    let addr = server.local_addr().unwrap().to_string();
    let (stop, handle) = server.serve_background();

    use std::io::{Read, Write};
    // Trickle half a request line and stall (slow loris): the server must
    // close the connection at the idle deadline instead of pinning one of
    // its pooled handler threads forever.
    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    loris.write_all(b"{\"cmd\":").unwrap();
    loris.flush().unwrap();
    loris
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let mut buf = [0u8; 16];
    let n = loris.read(&mut buf).unwrap();
    assert_eq!(n, 0, "expected EOF from the idle deadline, got {n} bytes");

    // A well-behaved client on the same server is unaffected.
    let mut client = Client::connect(&addr).unwrap();
    let pong = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = handle.join();
}

#[test]
fn trained_params_can_be_served() {
    // Wire a trained parameter vector into the engine (the deploy path).
    use sqa::config::TrainConfig;
    use sqa::train::Trainer;
    let tcfg = TrainConfig {
        family: "tiny".into(),
        variant: "sqa".into(),
        steps: 5,
        eval_every: 0,
        log_every: 0,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(rt(), tcfg).unwrap();
    for _ in 0..5 {
        trainer.step_once().unwrap();
    }
    let params = trainer.params_to_host().unwrap();
    let engine = Engine::start(rt(), &cfg(), Some(params)).unwrap();
    let resp = engine.encode(vec![4, 5, 6, 7]).unwrap();
    assert_eq!(resp.top.len(), 5);
    engine.shutdown();
}
