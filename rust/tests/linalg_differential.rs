//! Differential suite for the `linalg` subsystem: the blocked GEMM
//! micro-kernels and the SIMD tier vs the scalar oracle loops, from raw
//! products up through the full attention layer and a whole train step.
//!
//! Shape grids deliberately straddle every blocking boundary: the MR=4 /
//! NR=16 micro-tile edges, the KC=256 k-block edge, and the degenerate
//! s = 1 / n = 1 cases. Tolerance is 1e-4 — all impls share the
//! ascending-k summation order (the vector tier reassociates only within
//! an FMA), so observed diffs are near-zero; the tolerance guards against
//! future re-blocking. `Impl::Simd` runs everywhere: on hosts without
//! AVX2+FMA/NEON it resolves to the portable micro-kernel at runtime, so
//! these tests then degenerate to (still valid) blocked-vs-scalar checks.

use sqa::attention::tensor::Tensor;
use sqa::attention::{sqa_layer_slices, Kernel, Spec};
use sqa::linalg::{self, Impl};
use sqa::runtime::{Backend, NativeBackend};
use sqa::util::rng::Pcg64;

const TOL: f32 = 1e-4;

fn randn(len: usize, seed: u64, std: f32) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    (0..len).map(|_| rng.normal_f32(0.0, std)).collect()
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Dims straddling the micro-tile (4/16) and k-block (256) boundaries.
const ODD_DIMS: &[usize] = &[1, 3, 4, 5, 15, 16, 17, 33];

#[test]
fn blocked_matmul_matches_scalar_over_odd_shapes() {
    let mut seed = 10;
    for &s in ODD_DIMS {
        for &m in &[1usize, 5, 16, 31, 259] {
            // 259 > KC: exercises the multi-k-block accumulation path.
            for &n in &[1usize, 4, 15, 17, 40] {
                seed += 1;
                let x = randn(s * m, seed, 0.5);
                let w = randn(m * n, seed + 1000, 0.5);
                let want = linalg::matmul(Impl::Scalar, &x, &w, s, m, n, None);
                for imp in [Impl::Blocked, Impl::Simd] {
                    let got = linalg::matmul(imp, &x, &w, s, m, n, None);
                    let diff = max_diff(&want, &got);
                    assert!(diff < TOL, "{imp:?} matmul {s}x{m}x{n}: diff {diff}");
                }
            }
        }
    }
}

#[test]
fn transpose_variants_match_scalar_over_odd_shapes() {
    let mut seed = 5000;
    for &s in &[1usize, 2, 7, 33, 260] {
        // s is the contraction dim of xᵀ·dy: 260 > KC crosses a k block.
        for &(m, n) in &[(1usize, 1usize), (3, 17), (16, 16), (21, 5), (40, 33)] {
            seed += 1;
            let x = randn(s * m, seed, 0.5);
            let dy = randn(s * n, seed + 1, 0.5);
            let w = randn(m * n, seed + 2, 0.5);
            // Nonzero initial accumulators: all variants must *add*.
            let g0 = randn(m * n, seed + 3, 0.5);
            let mut g_s = g0.clone();
            linalg::accum_xt_dy(Impl::Scalar, &mut g_s, &x, &dy, s, m, n);
            let dx0 = randn(s * m, seed + 4, 0.5);
            let mut dx_s = dx0.clone();
            linalg::accum_dy_wt(Impl::Scalar, &mut dx_s, &dy, &w, s, m, n);
            for imp in [Impl::Blocked, Impl::Simd] {
                let mut g = g0.clone();
                linalg::accum_xt_dy(imp, &mut g, &x, &dy, s, m, n);
                let diff = max_diff(&g_s, &g);
                assert!(diff < TOL, "{imp:?} xt_dy s={s} {m}x{n}: diff {diff}");

                let mut dx = dx0.clone();
                linalg::accum_dy_wt(imp, &mut dx, &dy, &w, s, m, n);
                let diff = max_diff(&dx_s, &dx);
                assert!(diff < TOL, "{imp:?} dy_wt s={s} {m}x{n}: diff {diff}");
            }
        }
    }
}

#[test]
fn strided_attention_blocks_match_scalar() {
    // Head-interleaved slabs: stride > d, nonzero head offsets, nonzero
    // row bases — exactly how the tiled kernel addresses Q/K/V.
    let (s, d, heads) = (23usize, 6usize, 3usize);
    let stride = heads * d;
    let q = randn(s * stride, 70, 0.7);
    let k = randn(s * stride, 71, 0.7);
    let v = randn(s * stride, 72, 0.7);
    for &(i0, tq, j0, tk, h) in &[
        (0usize, 5usize, 0usize, 7usize, 0usize),
        (3, 8, 2, 16, 1),
        (16, 7, 15, 8, 2),
        (22, 1, 0, 1, 1), // degenerate 1x1 block
    ] {
        let q_off = h * d;
        let kv_off = ((h + 1) % heads) * d;
        let mut sc_s = vec![f32::NAN; tq * tk];
        linalg::score_block(
            Impl::Scalar, &q, stride, q_off, i0, tq, &k, stride, kv_off, j0, tk, d, 0.3,
            &mut sc_s, tk,
        );
        // probs: reuse |scores| so zeros stay zeros and weights are finite.
        let probs: Vec<f32> = sc_s.iter().map(|x| x.abs()).collect();
        let out0 = randn(tq * stride, 73, 0.2);
        let mut out_s = out0.clone();
        linalg::pv_block(
            Impl::Scalar, &probs, tk, tq, tk, &v, stride, kv_off, j0, d, &mut out_s, stride,
            q_off,
        );
        for imp in [Impl::Blocked, Impl::Simd] {
            let mut sc_b = vec![f32::NAN; tq * tk];
            linalg::score_block(
                imp, &q, stride, q_off, i0, tq, &k, stride, kv_off, j0, tk, d, 0.3, &mut sc_b,
                tk,
            );
            let diff = max_diff(&sc_s, &sc_b);
            assert!(diff < TOL, "{imp:?} score_block i0={i0} j0={j0}: diff {diff}");
            assert!(sc_b.iter().all(|x| x.is_finite()), "score overwrite left NaN");

            let mut out_b = out0.clone();
            linalg::pv_block(
                imp, &probs, tk, tq, tk, &v, stride, kv_off, j0, d, &mut out_b, stride, q_off,
            );
            let diff = max_diff(&out_s, &out_b);
            assert!(diff < TOL, "{imp:?} pv_block i0={i0} j0={j0}: diff {diff}");
            // Rows outside the written columns must be untouched by both.
            for ti in 0..tq {
                for c in 0..stride {
                    if !(q_off..q_off + d).contains(&c) {
                        assert_eq!(out_b[ti * stride + c], out_s[ti * stride + c]);
                    }
                }
            }
        }
    }
}

/// (label, Hq, Hkv) — the paper's head-geometry grid.
const GEOMETRIES: &[(&str, usize, usize)] = &[
    ("mha", 4, 4),
    ("gqa", 4, 2),
    ("mqa", 4, 1),
    ("sqa", 2, 1),
];

#[test]
fn sqa_layer_blocked_matches_scalar_across_geometries() {
    let d_head = 5; // deliberately not a multiple of MR/NR
    let dm = 12;
    for &(geom, hq, hkv) in GEOMETRIES {
        for s in [1usize, 9, 33] {
            for kernel in [Kernel::Tiled, Kernel::Naive] {
                let seed = (hq * 100 + hkv * 10 + s) as u64;
                let x = Tensor::from_vec(&[1, 1, s, dm], randn(s * dm, seed, 0.5)).unwrap();
                let wq = randn(dm * hq * d_head, seed + 1, 0.3);
                let wk = randn(dm * hkv * d_head, seed + 2, 0.3);
                let wv = randn(dm * hkv * d_head, seed + 3, 0.3);
                let wo = randn(hq * d_head * dm, seed + 4, 0.3);
                let spec = Spec::causal(hq, hkv);
                let run = |imp: Impl| {
                    sqa_layer_slices(
                        &x, &wq, &wk, &wv, &wo, d_head, spec, kernel, imp, None,
                    )
                    .unwrap()
                };
                let scalar = run(Impl::Scalar);
                for imp in [Impl::Blocked, Impl::Simd] {
                    let other = run(imp);
                    let diff = scalar.max_abs_diff(&other);
                    assert!(
                        diff < TOL,
                        "{geom} (Hq={hq} Hkv={hkv}) s={s} {kernel:?} {imp:?}: diff {diff}"
                    );
                }
            }
        }
    }
}

#[test]
fn forward_impl_blocked_and_simd_match_scalar_on_tiny_variants() {
    // End-to-end logits, blocked and simd vs scalar GEMMs under the same
    // (tiled) attention kernel, across the catalog's MHA/GQA/MQA/SQA
    // variants. "tiled+simd" additionally vectorizes the online softmax.
    let b = NativeBackend::new();
    let tokens: Vec<i32> = (0..24).map(|i| ((i * 131 + 17) % 2048) as i32).collect();
    for variant in ["mha", "gqa", "mqa", "sqa"] {
        let params = b.init_params("tiny", variant, 29).unwrap();
        let scalar = b
            .forward_impl("tiled+scalar", "tiny", variant, &params, &tokens, 1, 24)
            .unwrap();
        for impl_ in ["tiled", "tiled+simd"] {
            let got = b
                .forward_impl(impl_, "tiny", variant, &params, &tokens, 1, 24)
                .unwrap();
            let diff = max_diff(&got, &scalar);
            assert!(diff < TOL, "tiny/{variant} {impl_}: logits diverge by {diff}");
        }
    }
}

#[test]
fn train_step_gradients_match_between_impls() {
    // One fused forward+backward+AdamW step, scalar vs blocked vs simd
    // GEMMs end to end (projections, attention blocks, LM head,
    // xᵀ·dy / dy·wᵀ; the simd leg also runs the vectorized softmax
    // forward *and* backward): losses and the *updated* parameters must
    // agree to 1e-4.
    let blocked = NativeBackend::with_impls(Kernel::Tiled, Impl::Blocked);
    let simd = NativeBackend::with_impls(Kernel::Tiled, Impl::Simd);
    let scalar = NativeBackend::with_impls(Kernel::Tiled, Impl::Scalar);
    for variant in ["sqa", "mqa"] {
        let params = blocked.init_params("tiny", variant, 41).unwrap();
        let p = params.len();
        let (bs, s) = blocked.train_shape("tiny", variant).unwrap();
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 37 + 3) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t * 5 + 11) % 2048).collect();

        let run = |backend: &NativeBackend| -> (f32, Vec<f32>) {
            let mut state = vec![0.0f32; 3 * p + 2];
            state[..p].copy_from_slice(&params);
            let (loss, _) = backend
                .train_step("tiny", variant, &mut state, 1, 1e-2, &tokens, &targets, bs, s)
                .unwrap();
            (loss, state)
        };
        let (loss_s, state_s) = run(&scalar);
        for (name, backend) in [("blocked", &blocked), ("simd", &simd)] {
            let (loss_b, state_b) = run(backend);
            assert!(
                (loss_b - loss_s).abs() < 1e-4,
                "tiny/{variant} {name}: loss {loss_b} vs {loss_s}"
            );
            let diff = max_diff(&state_b, &state_s);
            assert!(diff < TOL, "tiny/{variant} {name}: train state diverges by {diff}");
        }
    }
}

#[test]
fn sqa_layer_slices_rejects_bad_weight_lengths() {
    let x = Tensor::from_vec(&[1, 1, 4, 6], vec![0.0; 24]).unwrap();
    let spec = Spec::causal(2, 1);
    let ok_q = vec![0.0f32; 6 * 2 * 3];
    let ok_kv = vec![0.0f32; 6 * 3];
    let ok_o = vec![0.0f32; 2 * 3 * 6];
    assert!(sqa_layer_slices(
        &x, &ok_q, &ok_kv, &ok_kv, &ok_o, 3, spec, Kernel::Tiled, Impl::Blocked, None
    )
    .is_ok());
    assert!(sqa_layer_slices(
        &x,
        &ok_q[..ok_q.len() - 1],
        &ok_kv,
        &ok_kv,
        &ok_o,
        3,
        spec,
        Kernel::Tiled,
        Impl::Blocked,
        None
    )
    .is_err());
    assert!(sqa_layer_slices(
        &x,
        &ok_q,
        &ok_kv,
        &ok_kv,
        &ok_o[1..],
        3,
        spec,
        Kernel::Tiled,
        Impl::Blocked,
        None
    )
    .is_err());
}
