//! Offline stub of the `xla` (PJRT) crate API surface used by this repo.
//!
//! The real PJRT bindings cannot be vendored into the offline image, but the
//! `--features pjrt` code path must still *type-check* so the XLA runtime
//! keeps compiling as the crate evolves. This stub mirrors exactly the
//! subset of the `xla` API the `sqa` crate calls; every runtime entry point
//! returns [`Error::Unavailable`], and `PjRtClient::cpu()` failing first
//! guarantees nothing downstream ever executes.
//!
//! Deployments with a real PJRT plugin replace this crate via a Cargo patch:
//!
//! ```toml
//! [patch.crates-io]            # or a [patch] on this path dependency
//! xla = { git = "..." }
//! ```

use std::fmt;

/// The stub's only error: the PJRT runtime is not present in this build.
#[derive(Debug)]
pub enum Error {
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT unavailable (built against rust/xla-stub; \
                 patch in a real `xla` crate to execute artifacts)"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types the sqa runtime moves across the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host types that can be uploaded/downloaded as PJRT buffers.
pub trait ArrayElement: Copy {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Device-resident buffer (stub: never constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal (stub: never constructed).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T, Error> {
        unavailable("Literal::get_first_element")
    }
}

/// Compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with device-resident args: replicas x outputs.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Graph-building handle (used for the runtime's device-side slicers).
pub struct XlaBuilder {
    _private: (),
}

impl XlaBuilder {
    pub fn new(_name: &str) -> Self {
        Self { _private: () }
    }

    pub fn parameter(
        &self,
        _id: i64,
        _ty: ElementType,
        _dims: &[i64],
        _name: &str,
    ) -> Result<XlaOp, Error> {
        unavailable("XlaBuilder::parameter")
    }
}

/// A node in a computation under construction.
pub struct XlaOp {
    _private: (),
}

impl XlaOp {
    pub fn slice_in_dim1(&self, _start: i64, _stop: i64, _dim: i64) -> Result<XlaOp, Error> {
        unavailable("XlaOp::slice_in_dim1")
    }

    pub fn build(&self) -> Result<XlaComputation, Error> {
        unavailable("XlaOp::build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("PJRT unavailable"), "{msg}");
    }

    #[test]
    fn element_types_map() {
        assert_eq!(<f32 as ArrayElement>::TY, ElementType::F32);
        assert_eq!(<i32 as ArrayElement>::TY, ElementType::S32);
    }
}
