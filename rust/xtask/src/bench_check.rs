//! `xtask bench-check <fresh.json> <baseline.json> [--update]` — diff a
//! freshly produced bench report against its committed `BENCH_*.json`
//! baseline.
//!
//! What "no worse than the baseline" means for a report whose timings are
//! measured on whatever machine CI happens to land on:
//!
//! * **Schema** — the key sets of every object must match, recursively.
//!   A bench that silently drops a column (or grows one nobody reviewed)
//!   fails the check, with `--update` as the explicit accept path.
//! * **Identity fields** — strings (`variant`, `impl`, `family`, shape
//!   labels) and *integer-valued* numbers (`hq`, `hkv`, `ctx`, `seq`,
//!   measured/predicted KV bytes per step, grid sizes) must match the
//!   baseline **exactly**: they are deterministic functions of the config
//!   and buffer geometry, so any drift is a real behavior change — e.g. a
//!   KV-cache accounting bug — not noise.
//! * **Timings** — fractional numbers are machine-dependent; they are
//!   only required to be finite. Perf regressions are enforced by the
//!   benches' own `--smoke`/`--enforce` guards, not by this diff.
//! * **Row grids** — arrays must keep their length and order (the benches
//!   sweep deterministic `variant × ctx/seq` grids).

use anyhow::{Context, Result};
use sqa::util::json::Json;
use std::path::Path;

fn kind(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// Identity numbers are integer-valued; timings carry fractions. (An f64
/// keeps integers exact well past any head count or byte total we emit.)
fn is_identity_num(x: f64) -> bool {
    x.fract() == 0.0 && x.abs() < 9.0e15
}

fn diff(path: &str, fresh: &Json, base: &Json, out: &mut Vec<String>) {
    match (fresh, base) {
        (Json::Obj(f), Json::Obj(b)) => {
            for k in b.keys() {
                if !f.contains_key(k) {
                    out.push(format!("{path}.{k}: key missing from the fresh report"));
                }
            }
            for (k, fv) in f {
                match b.get(k) {
                    None => out.push(format!(
                        "{path}.{k}: key not in the baseline (bench-check --update to accept)"
                    )),
                    Some(bv) => diff(&format!("{path}.{k}"), fv, bv, out),
                }
            }
        }
        (Json::Arr(f), Json::Arr(b)) => {
            if f.len() != b.len() {
                out.push(format!(
                    "{path}: {} rows vs baseline {} (sweep grid changed? --update to accept)",
                    f.len(),
                    b.len()
                ));
            }
            for (i, (fv, bv)) in f.iter().zip(b.iter()).enumerate() {
                diff(&format!("{path}[{i}]"), fv, bv, out);
            }
        }
        (Json::Str(f), Json::Str(b)) => {
            if f != b {
                out.push(format!("{path}: {f:?} != baseline {b:?}"));
            }
        }
        (Json::Bool(f), Json::Bool(b)) => {
            if f != b {
                out.push(format!("{path}: {f} != baseline {b}"));
            }
        }
        (Json::Num(f), Json::Num(b)) => {
            if is_identity_num(*f) && is_identity_num(*b) {
                if f != b {
                    out.push(format!(
                        "{path}: {f} != baseline {b} (integer-valued fields are identity, \
                         not timing — this is a real change)"
                    ));
                }
            } else if !f.is_finite() {
                out.push(format!("{path}: non-finite measurement {f}"));
            }
        }
        (Json::Null, Json::Null) => {}
        _ => out.push(format!(
            "{path}: type changed — fresh {} vs baseline {}",
            kind(fresh),
            kind(base)
        )),
    }
}

/// Returns the human-readable findings (empty = check passed).
pub fn run(fresh_path: &Path, base_path: &Path, update: bool) -> Result<Vec<String>> {
    let fresh_text = std::fs::read_to_string(fresh_path)
        .with_context(|| format!("reading fresh report {}", fresh_path.display()))?;
    let fresh = Json::parse(&fresh_text)
        .with_context(|| format!("parsing {}", fresh_path.display()))?;
    let name = fresh
        .get("bench")
        .and_then(Json::as_str)
        .with_context(|| format!("{}: no top-level \"bench\" key", fresh_path.display()))?
        .to_string();

    if update {
        // Reuse the schema gate: --update can only ever write valid reports.
        sqa::util::bench::write_bench_json(base_path, &fresh)?;
        println!("bench-check: baseline {} <- {} ({name})", base_path.display(), fresh_path.display());
        return Ok(Vec::new());
    }

    let base_text = std::fs::read_to_string(base_path).with_context(|| {
        format!(
            "reading baseline {} (first run? seed it with bench-check --update)",
            base_path.display()
        )
    })?;
    let base = Json::parse(&base_text)
        .with_context(|| format!("parsing {}", base_path.display()))?;
    let mut out = Vec::new();
    diff(&name, &fresh, &base, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(variant: &str, hkv: f64, bytes: f64, secs: f64) -> Json {
        Json::obj(vec![
            ("variant", Json::str(variant)),
            ("hkv", Json::num(hkv)),
            ("kv_bytes", Json::num(bytes)),
            ("secs", Json::num(secs)),
        ])
    }

    fn report(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("bench", Json::str("unit")),
            ("rows", Json::Arr(rows)),
        ])
    }

    fn diffs(fresh: &Json, base: &Json) -> Vec<String> {
        let mut out = Vec::new();
        diff("unit", fresh, base, &mut out);
        out
    }

    #[test]
    fn identical_reports_pass() {
        let a = report(vec![row("sqa", 4.0, 557_056.0, 0.012)]);
        assert!(diffs(&a, &a).is_empty());
    }

    #[test]
    fn timing_drift_is_ignored_but_identity_ints_are_exact() {
        let base = report(vec![row("sqa", 4.0, 557_056.0, 0.012)]);
        let timing_drift = report(vec![row("sqa", 4.0, 557_056.0, 3.7)]);
        assert!(diffs(&timing_drift, &base).is_empty(), "timings are machine-dependent");
        let cache_bug = report(vec![row("sqa", 4.0, 557_057.0, 0.012)]);
        let d = diffs(&cache_bug, &base);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("kv_bytes"));
    }

    #[test]
    fn schema_changes_are_findings() {
        let base = report(vec![row("sqa", 4.0, 557_056.0, 0.012)]);
        // Dropped column.
        let narrow = report(vec![Json::obj(vec![
            ("variant", Json::str("sqa")),
            ("hkv", Json::num(4.0)),
            ("secs", Json::num(0.011)),
        ])]);
        assert!(diffs(&narrow, &base).iter().any(|d| d.contains("kv_bytes")));
        // New unreviewed key.
        let wide = Json::obj(vec![
            ("bench", Json::str("unit")),
            ("rows", Json::Arr(vec![row("sqa", 4.0, 557_056.0, 0.012)])),
            ("extra", Json::num(1.0)),
        ]);
        assert!(diffs(&wide, &base).iter().any(|d| d.contains("extra")));
    }

    #[test]
    fn grid_and_identity_string_changes_are_findings() {
        let base = report(vec![
            row("gqa", 4.0, 557_056.0, 0.010),
            row("sqa", 4.0, 557_056.0, 0.012),
        ]);
        let shrunk = report(vec![row("gqa", 4.0, 557_056.0, 0.010)]);
        assert!(diffs(&shrunk, &base).iter().any(|d| d.contains("rows")));
        let renamed = report(vec![
            row("gqa", 4.0, 557_056.0, 0.010),
            row("ssqa", 4.0, 557_056.0, 0.012),
        ]);
        assert!(diffs(&renamed, &base).iter().any(|d| d.contains("ssqa")));
    }

    #[test]
    fn non_finite_timings_are_findings() {
        let base = report(vec![row("sqa", 4.0, 557_056.0, 0.012)]);
        let broken = report(vec![row("sqa", 4.0, 557_056.0, f64::NAN)]);
        let d = diffs(&broken, &base);
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("non-finite"));
    }

    fn pattern_row(pattern: &str, seq: f64, visited: f64, dense: f64, ratio: f64) -> Json {
        Json::obj(vec![
            ("pattern", Json::str(pattern)),
            ("seq", Json::num(seq)),
            ("visited_tiles", Json::num(visited)),
            ("dense_tiles", Json::num(dense)),
            ("ratio", Json::num(ratio)),
        ])
    }

    #[test]
    fn pattern_tile_counts_are_identity_but_ratios_are_not() {
        // `pattern_tiles` rows mix both field classes: visited/dense tile
        // counts are deterministic functions of the visibility seam (a
        // drifted count is a mask bug), while the derived ratio carries a
        // fraction and so is only checked for finiteness.
        let base = Json::obj(vec![
            ("bench", Json::str("unit")),
            (
                "pattern_tiles",
                Json::Arr(vec![
                    pattern_row("dense", 4096.0, 2080.0, 2080.0, 1.0),
                    pattern_row("strided:1024", 4096.0, 160.0, 2080.0, 0.0769),
                ]),
            ),
        ]);
        let mut fresh = base.clone();
        assert!(diffs(&fresh, &base).is_empty());
        // A seeded visited-tile mismatch (the seam visiting one extra tile)
        // must surface as a finding naming the drifted field...
        if let Json::Obj(o) = &mut fresh {
            o.insert(
                "pattern_tiles".into(),
                Json::Arr(vec![
                    pattern_row("dense", 4096.0, 2080.0, 2080.0, 1.0),
                    pattern_row("strided:1024", 4096.0, 161.0, 2080.0, 0.0769),
                ]),
            );
        }
        let d = diffs(&fresh, &base);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("visited_tiles") && d[0].contains("161"), "{d:?}");
        // ...while ratio drift (machine-independent but fractional) is not.
        let ratio_drift = Json::obj(vec![
            ("bench", Json::str("unit")),
            (
                "pattern_tiles",
                Json::Arr(vec![
                    pattern_row("dense", 4096.0, 2080.0, 2080.0, 1.0),
                    pattern_row("strided:1024", 4096.0, 160.0, 2080.0, 0.0770),
                ]),
            ),
        ]);
        assert!(diffs(&ratio_drift, &base).is_empty());
        // And a report that silently loses the whole pattern sweep fails.
        let dropped = Json::obj(vec![("bench", Json::str("unit"))]);
        assert!(diffs(&dropped, &base)
            .iter()
            .any(|d| d.contains("pattern_tiles") && d.contains("missing")));
    }
}
