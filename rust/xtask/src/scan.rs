//! Lossless source masking for the invariant linter.
//!
//! Splits a Rust source file into two same-shape views (one output char
//! per input char, newlines preserved, so line/column structure survives):
//!
//! * `code`     — comments and string/char-literal *contents* blanked to
//!   spaces; everything else verbatim. Rules that must not fire on prose
//!   (`.lock().unwrap()` in a doc comment, "unsafe" in a test string)
//!   match against this view.
//! * `comments` — the inverse: only comment text survives. The
//!   `// SAFETY:` rule reads this view so a `SAFETY` inside a string
//!   cannot justify an unsafe block.
//!
//! The tokenizer is deliberately hand-rolled (no `syn` — the offline
//! build image has no crates registry) and handles the constructs that
//! actually occur in this tree: line and nested block comments, plain and
//! byte strings with escapes, raw strings `r#"…"#` / `br#"…"#`, char and
//! byte-char literals, and the char-vs-lifetime ambiguity (`'x'` vs
//! `'env`).

pub struct Masked {
    pub code: String,
    pub comments: String,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// For a `'` at index `q`: `Some(end)` (one past the closing quote) if it
/// opens a char/byte-char literal, `None` if it starts a lifetime/label.
fn char_literal_end(chars: &[char], q: usize) -> Option<usize> {
    let mut j = q + 1;
    match chars.get(j)? {
        '\\' => {
            j += 1;
            if chars.get(j) == Some(&'u') && chars.get(j + 1) == Some(&'{') {
                j += 2;
                while chars.get(j).is_some_and(|c| *c != '}') {
                    j += 1;
                }
            }
            j += 1;
        }
        '\'' => return None, // `''` opens nothing
        _ => j += 1,
    }
    (chars.get(j) == Some(&'\'')).then_some(j + 1)
}

pub fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code: Vec<char> = Vec::with_capacity(n);
    let mut comments: Vec<char> = Vec::with_capacity(n);
    // Pushes one masked char into both views, preserving newlines.
    let blank = |c: char, keep_in: &mut Vec<char>, other: &mut Vec<char>| {
        if c == '\n' {
            keep_in.push('\n');
            other.push('\n');
        } else {
            keep_in.push(c);
            other.push(' ');
        }
    };

    let mut i = 0;
    // Whether the previous code char can end an identifier — gates the
    // raw-string/byte prefixes so `bar"` in (invalid) code or `let r = 1`
    // never misparse.
    let mut prev_ident = false;
    while i < n {
        let c = chars[i];

        // ---- line comment (incl. `///`, `//!`) --------------------------
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < n && chars[i] != '\n' {
                blank(chars[i], &mut comments, &mut code);
                i += 1;
            }
            prev_ident = false;
            continue;
        }

        // ---- block comment, nested --------------------------------------
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < n {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    blank(chars[i], &mut comments, &mut code);
                    blank(chars[i + 1], &mut comments, &mut code);
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    blank(chars[i], &mut comments, &mut code);
                    blank(chars[i + 1], &mut comments, &mut code);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(chars[i], &mut comments, &mut code);
                    i += 1;
                }
            }
            prev_ident = false;
            continue;
        }

        // ---- raw (byte) string: r"…", r#"…"#, br#"…"# -------------------
        if !prev_ident && (c == 'r' || (c == 'b' && chars.get(i + 1) == Some(&'r'))) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if chars.get(j) == Some(&'"') {
                for &pc in &chars[i..=j] {
                    code.push(pc);
                    comments.push(' ');
                }
                i = j + 1;
                while i < n {
                    if chars[i] == '"'
                        && (0..hashes).all(|h| chars.get(i + 1 + h) == Some(&'#'))
                    {
                        code.push('"');
                        comments.push(' ');
                        for _ in 0..hashes {
                            code.push('#');
                            comments.push(' ');
                        }
                        i += 1 + hashes;
                        break;
                    }
                    blank(if chars[i] == '\n' { '\n' } else { ' ' }, &mut code, &mut comments);
                    i += 1;
                }
                prev_ident = false;
                continue;
            }
            // `r`/`br` not followed by a string: plain identifier chars.
        }

        // ---- plain / byte string ----------------------------------------
        if c == '"' || (!prev_ident && c == 'b' && chars.get(i + 1) == Some(&'"')) {
            if c == 'b' {
                code.push('b');
                comments.push(' ');
                i += 1;
            }
            code.push('"');
            comments.push(' ');
            i += 1;
            while i < n {
                match chars[i] {
                    '\\' => {
                        blank(' ', &mut code, &mut comments);
                        i += 1;
                        if i < n {
                            blank(if chars[i] == '\n' { '\n' } else { ' ' }, &mut code, &mut comments);
                            i += 1;
                        }
                    }
                    '"' => {
                        code.push('"');
                        comments.push(' ');
                        i += 1;
                        break;
                    }
                    ch => {
                        blank(if ch == '\n' { '\n' } else { ' ' }, &mut code, &mut comments);
                        i += 1;
                    }
                }
            }
            prev_ident = false;
            continue;
        }

        // ---- char / byte-char literal (vs lifetime) ---------------------
        if c == '\'' || (!prev_ident && c == 'b' && chars.get(i + 1) == Some(&'\'')) {
            let q = if c == 'b' { i + 1 } else { i };
            if let Some(end) = char_literal_end(&chars, q) {
                for (k, &pc) in chars[i..end].iter().enumerate() {
                    // Keep the delimiters (and `b` prefix), blank contents.
                    if i + k <= q || i + k == end - 1 {
                        code.push(pc);
                        comments.push(' ');
                    } else {
                        blank(if pc == '\n' { '\n' } else { ' ' }, &mut code, &mut comments);
                    }
                }
                i = end;
                prev_ident = false;
                continue;
            }
            // Lifetime or label: falls through as ordinary code.
        }

        // ---- ordinary code ----------------------------------------------
        blank(c, &mut code, &mut comments);
        prev_ident = is_ident(c);
        i += 1;
    }

    Masked {
        code: code.into_iter().collect(),
        comments: comments.into_iter().collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_keep_line_structure() {
        let src = "let a = 1; // trailing\n/* block\n spans */ let b = \"s\ntr\";\n";
        let m = mask(src);
        assert_eq!(m.code.lines().count(), src.lines().count());
        assert_eq!(m.comments.lines().count(), src.lines().count());
    }

    #[test]
    fn comments_are_blanked_from_code() {
        let src = "x(); // calls .lock().unwrap() conceptually\n/* unsafe here too */ y();\n";
        let m = mask(src);
        assert!(!m.code.contains("unwrap"));
        assert!(!m.code.contains("unsafe"));
        assert!(m.code.contains("x();") && m.code.contains("y();"));
        assert!(m.comments.contains("unsafe here too"));
    }

    #[test]
    fn string_contents_are_blanked_from_both_views() {
        let src = "let s = \"unsafe impl\"; let r = r#\".lock().unwrap()\"#; let c = 'u';\n";
        let m = mask(src);
        assert!(!m.code.contains("unsafe"));
        assert!(!m.code.contains(".lock()"));
        assert!(!m.comments.contains("unsafe"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'env>(x: &'env str) -> &'static str { x }\n";
        let m = mask(src);
        // If `'env` were eaten as a char literal the rest of the line
        // would be blanked — `'static` must survive in the code view.
        assert!(m.code.contains("'static str"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let src = "/* outer /* inner */ still comment */ code();\n";
        let m = mask(src);
        assert!(m.code.contains("code();"));
        assert!(!m.code.contains("still"));
        assert!(m.comments.contains("still comment"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let src = "let s = \"a\\\"b.lock().unwrap()\"; done();\n";
        let m = mask(src);
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("done();"));
    }
}
