//! `cargo run -p xtask -- <command>` — repo automation, cargo-xtask style.
//!
//! Commands:
//!   lint                      run the in-tree invariant linter (exit 1 on
//!                             findings); see src/lint.rs for the rules
//!   bench-check F B           diff fresh bench report F against committed
//!                             baseline B (exit 1 on findings)
//!   bench-check F B --update  accept F as the new baseline B
//!
//! Both commands locate the repo root by walking up from this crate's
//! manifest (or the cwd) to the directory holding `rust/src/lib.rs`, so
//! they work from any working directory inside the checkout.

mod bench_check;
mod lint;
mod scan;

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: cargo run -p xtask -- <command>\n\
    \n\
    commands:\n\
    \x20 lint                                  invariant linter over the Rust tree\n\
    \x20 bench-check <fresh> <baseline>        diff a bench report against its baseline\n\
    \x20 bench-check <fresh> <baseline> --update   accept the fresh report as baseline\n";

pub fn repo_root() -> Result<PathBuf> {
    let base = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .map_or_else(std::env::current_dir, Ok)?;
    for dir in base.ancestors() {
        if dir.join("rust").join("src").join("lib.rs").is_file() {
            return Ok(dir.to_path_buf());
        }
    }
    bail!("no repo root (rust/src/lib.rs) at or above {}", base.display())
}

fn cmd_lint() -> Result<i32> {
    let root = repo_root()?;
    let (files, findings) = lint::run(&root)?;
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "lint: {files} files clean (safety-comment, lock-unwrap, kernel-clock, \
             bench-writer, simd-confinement, kv-block-confinement)"
        );
        Ok(0)
    } else {
        println!("lint: {} finding(s) across {files} files", findings.len());
        Ok(1)
    }
}

fn cmd_bench_check(args: &[String]) -> Result<i32> {
    let mut update = false;
    let mut paths: Vec<&str> = Vec::new();
    for a in args {
        if a == "--update" {
            update = true;
        } else {
            paths.push(a);
        }
    }
    if paths.len() != 2 {
        bail!("bench-check needs <fresh.json> <baseline.json> [--update]\n\n{USAGE}");
    }
    let (fresh, baseline) = (paths[0], paths[1]);
    let findings = bench_check::run(Path::new(fresh), Path::new(baseline), update)?;
    for f in &findings {
        println!("bench-check: {f}");
    }
    if findings.is_empty() {
        if !update {
            println!("bench-check: {fresh} matches baseline {baseline}");
        }
        Ok(0)
    } else {
        println!("bench-check: {} finding(s); --update accepts the fresh report", findings.len());
        Ok(1)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("lint") => cmd_lint(),
        Some("bench-check") => cmd_bench_check(&args[1..]),
        Some(other) => Err(anyhow::anyhow!("unknown command {other:?}\n\n{USAGE}")),
        None => Err(anyhow::anyhow!("missing command\n\n{USAGE}")),
    };
    match result {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("xtask: {e:#}");
            std::process::exit(2);
        }
    }
}
