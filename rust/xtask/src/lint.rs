//! The in-tree invariant linter (`cargo run -p xtask -- lint`).
//!
//! Six rules, each encoding an invariant the runtime's correctness
//! tooling depends on (see `rust/README.md` § Correctness tooling):
//!
//! | rule                  | invariant                                             |
//! |-----------------------|-------------------------------------------------------|
//! | `safety-comment`      | every `unsafe` block/impl carries a `// SAFETY:` note |
//! | `lock-unwrap`         | no `.lock().unwrap()` in server/coordinator/runtime — |
//! |                       | use the poison-tolerant `util::sync::lock` helper     |
//! | `kernel-clock`        | no `Instant::now`/`SystemTime` inside attention/linalg|
//! |                       | kernels — timing belongs to the bench/driver layer    |
//! | `bench-writer`        | benches persist JSON only via `write_bench_json`      |
//! | `simd-confinement`    | `core::arch`/`#[target_feature]`/feature detection    |
//! |                       | live only in `linalg/simd.rs` and `util/simd.rs` —    |
//! |                       | everything else stays portable and Miri-runnable      |
//! | `kv-block-confinement`| the paged-KV allocator internals (`PoolInner`,        |
//! |                       | `BlockData`, the `SPILLED` sentinel) stay inside      |
//! |                       | `runtime/session.rs` — everyone else goes through the |
//! |                       | `PagedKvCache`/`BlockPool` API so the refcount/COW    |
//! |                       | invariants have a single enforcement point            |
//!
//! Rules match against the masked code view ([`crate::scan::mask`]), so
//! prose in comments or strings never fires them. A finding on line *L*
//! can be waived by putting `// lint: allow(<rule>)` on *L* or *L−1* —
//! the marker is deliberately greppable so waivers stay auditable.

use crate::scan::mask;
use std::path::{Path, PathBuf};

pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    /// 1-based.
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `// lint: allow(<rule>)` on the finding's line or the line above.
fn allowed(orig_lines: &[&str], line0: usize, rule: &str) -> bool {
    let marker = format!("lint: allow({rule})");
    orig_lines.get(line0).is_some_and(|l| l.contains(&marker))
        || (line0 > 0 && orig_lines[line0 - 1].contains(&marker))
}

/// 0-based lines where `needle` matches `code` with ALL whitespace in the
/// haystack ignored — catches `.lock()\n.unwrap()` split across a method
/// chain just like the single-line form.
fn find_normalized(code: &str, needle: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    let nd: Vec<char> = needle.chars().collect();
    let mut hits = Vec::new();
    let mut line = 0usize;
    for start in 0..chars.len() {
        if chars[start] == '\n' {
            line += 1;
            continue;
        }
        if chars[start] != nd[0] {
            continue;
        }
        let (mut i, mut k) = (start, 0usize);
        while i < chars.len() && k < nd.len() {
            if chars[i].is_whitespace() {
                i += 1;
            } else if chars[i] == nd[k] {
                i += 1;
                k += 1;
            } else {
                break;
            }
        }
        if k == nd.len() {
            hits.push(line);
        }
    }
    hits
}

/// Word-boundary occurrences of `word` in one masked code line.
fn has_word(line: &str, word: &str) -> bool {
    let chars: Vec<char> = line.chars().collect();
    let w: Vec<char> = word.chars().collect();
    if chars.len() < w.len() {
        return false;
    }
    for s in 0..=chars.len() - w.len() {
        if chars[s..s + w.len()] == w[..]
            && (s == 0 || !is_ident(chars[s - 1]))
            && (s + w.len() == chars.len() || !is_ident(chars[s + w.len()]))
        {
            return true;
        }
    }
    false
}

// ---- rule: safety-comment ----------------------------------------------

/// Every `unsafe` keyword in code must be justified by a comment
/// containing `SAFETY` on the same line, or in the contiguous block of
/// comment/attribute lines immediately above (attributes like
/// `#[allow(...)]` may sit between the justification and the `unsafe`).
pub fn rule_safety_comment(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let code_lines: Vec<&str> = m.code.lines().collect();
    let comment_lines: Vec<&str> = m.comments.lines().collect();
    let orig_lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (ln, cl) in code_lines.iter().enumerate() {
        if !has_word(cl, "unsafe") || allowed(&orig_lines, ln, "safety-comment") {
            continue;
        }
        let mut justified = comment_lines.get(ln).is_some_and(|l| l.contains("SAFETY"));
        let mut j = ln;
        while !justified && j > 0 {
            j -= 1;
            if comment_lines[j].contains("SAFETY") {
                justified = true;
                break;
            }
            let code = code_lines[j].trim();
            // Walk through blank/comment-only lines and attributes; stop
            // at the first real code line — the comment block has ended.
            if !(code.is_empty() || code.starts_with("#[") || code.starts_with("#!") || code == ")]")
            {
                break;
            }
        }
        if !justified {
            out.push(Finding {
                rule: "safety-comment",
                path: path.to_string(),
                line: ln + 1,
                msg: "`unsafe` without a `// SAFETY:` justification above it".to_string(),
            });
        }
    }
    out
}

// ---- rule: lock-unwrap --------------------------------------------------

/// Scope: the concurrent subsystems that must survive a panicking peer.
pub fn lock_unwrap_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/server/")
        || rel.starts_with("rust/src/coordinator/")
        || rel.starts_with("rust/src/runtime/")
        || rel == "rust/src/util/threadpool.rs"
}

pub fn rule_lock_unwrap(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    find_normalized(&m.code, ".lock().unwrap()")
        .into_iter()
        .filter(|&ln| !allowed(&orig_lines, ln, "lock-unwrap"))
        .map(|ln| Finding {
            rule: "lock-unwrap",
            path: path.to_string(),
            line: ln + 1,
            msg: "poison-panic propagation: use util::sync::lock (PoisonError::into_inner) \
                  instead of .lock().unwrap()"
                .to_string(),
        })
        .collect()
}

// ---- rule: kernel-clock -------------------------------------------------

pub fn kernel_clock_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/attention") || rel.starts_with("rust/src/linalg")
}

pub fn rule_kernel_clock(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for needle in ["Instant::now", "SystemTime"] {
        for ln in find_normalized(&m.code, needle) {
            if allowed(&orig_lines, ln, "kernel-clock") {
                continue;
            }
            out.push(Finding {
                rule: "kernel-clock",
                path: path.to_string(),
                line: ln + 1,
                msg: format!(
                    "{needle} inside a kernel module — keep kernels clock-free; \
                     time at the bench/driver layer (util::bench)"
                ),
            });
        }
    }
    out
}

// ---- rule: bench-writer -------------------------------------------------

pub fn bench_writer_scope(rel: &str) -> bool {
    rel.starts_with("rust/benches/")
}

pub fn rule_bench_writer(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for needle in ["fs::write", "File::create"] {
        for ln in find_normalized(&m.code, needle) {
            if allowed(&orig_lines, ln, "bench-writer") {
                continue;
            }
            out.push(Finding {
                rule: "bench-writer",
                path: path.to_string(),
                line: ln + 1,
                msg: format!(
                    "{needle} in a bench — reports go through \
                     util::bench::write_bench_json (schema'd, baseline-diffable)"
                ),
            });
        }
    }
    out
}

// ---- rule: simd-confinement ----------------------------------------------

/// Scope: everywhere EXCEPT the two blessed intrinsic modules. Keeping
/// architecture-specific code behind these two seams is what lets the
/// Miri/loom suites and the scalar differential oracles cover the rest
/// of the tree unconditionally.
pub fn simd_confinement_scope(rel: &str) -> bool {
    rel != "rust/src/linalg/simd.rs" && rel != "rust/src/util/simd.rs"
}

pub fn rule_simd_confinement(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for needle in ["core::arch", "std::arch", "target_feature", "is_x86_feature_detected"] {
        for ln in find_normalized(&m.code, needle) {
            if allowed(&orig_lines, ln, "simd-confinement") {
                continue;
            }
            out.push(Finding {
                rule: "simd-confinement",
                path: path.to_string(),
                line: ln + 1,
                msg: format!(
                    "{needle} outside the intrinsic seams — arch-specific code \
                     belongs in linalg/simd.rs or util/simd.rs behind a \
                     runtime-detected dispatch"
                ),
            });
        }
    }
    out
}

// ---- rule: kv-block-confinement -------------------------------------------

/// Scope: all of `rust/src` EXCEPT the allocator module itself. The block
/// pool's refcount/COW/spill invariants ("a shared block is never written
/// in place", "refcounts never underflow", "byte accounting equals
/// blocks_in_use × block_bytes") are enforced inside `runtime/session.rs`;
/// code elsewhere touching the pool's internal types would create a second
/// place those invariants can silently break.
pub fn kv_block_confinement_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/") && rel != "rust/src/runtime/session.rs"
}

pub fn rule_kv_block_confinement(path: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (ln, cl) in m.code.lines().enumerate() {
        for word in ["PoolInner", "BlockData", "SPILLED"] {
            if !has_word(cl, word) || allowed(&orig_lines, ln, "kv-block-confinement") {
                continue;
            }
            out.push(Finding {
                rule: "kv-block-confinement",
                path: path.to_string(),
                line: ln + 1,
                msg: format!(
                    "{word} outside runtime/session.rs — go through the \
                     PagedKvCache/BlockPool API; raw block state has exactly \
                     one owner"
                ),
            });
        }
    }
    out
}

// ---- driver --------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().unwrap_or_default().to_string_lossy().into_owned();
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every Rust source in the tree; returns `(files scanned, findings)`.
pub fn run(root: &Path) -> anyhow::Result<(usize, Vec<Finding>)> {
    let mut files = Vec::new();
    for top in ["rust/src", "rust/benches", "rust/tests", "rust/xtask/src", "examples"] {
        collect_rs(&root.join(top), &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(file)
            .map_err(|e| anyhow::anyhow!("reading {rel}: {e}"))?;
        findings.extend(rule_safety_comment(&rel, &src));
        if lock_unwrap_scope(&rel) {
            findings.extend(rule_lock_unwrap(&rel, &src));
        }
        if kernel_clock_scope(&rel) {
            findings.extend(rule_kernel_clock(&rel, &src));
        }
        if bench_writer_scope(&rel) {
            findings.extend(rule_bench_writer(&rel, &src));
        }
        if simd_confinement_scope(&rel) {
            findings.extend(rule_simd_confinement(&rel, &src));
        }
        if kv_block_confinement_scope(&rel) {
            findings.extend(rule_kv_block_confinement(&rel, &src));
        }
    }
    Ok((files.len(), findings))
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- safety-comment: must fire on a seeded violation ---------------

    #[test]
    fn safety_fires_on_bare_unsafe_block() {
        let src = "pub fn f() -> *const u8 {\n    unsafe { std::ptr::null() }\n}\n";
        let f = rule_safety_comment("x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn safety_fires_on_undocumented_send_impl() {
        // The shape of the original runtime/client.rs finding: a comment
        // that asserts thread-safety without the SAFETY contract marker.
        let src = "struct Inner;\n\
                   // These raw pointers are fine to share across threads.\n\
                   unsafe impl Send for Inner {}\n";
        let f = rule_safety_comment("client.rs", src);
        assert_eq!(f.len(), 1, "an explanation is not a SAFETY contract");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn safety_accepts_contract_above_attributes() {
        let src = "fn f(x: &[u8]) -> u8 {\n\
                       // SAFETY: caller guarantees x is non-empty, so index\n\
                       // 0 is in bounds.\n\
                       #[allow(clippy::missing_transmute_annotations)]\n\
                       unsafe { *x.get_unchecked(0) }\n\
                   }\n";
        assert!(rule_safety_comment("x.rs", src).is_empty());
    }

    #[test]
    fn safety_honors_trailing_and_allow_marker() {
        let trailing = "let p = unsafe { q.add(1) }; // SAFETY: q has 2 elems\n";
        assert!(rule_safety_comment("x.rs", trailing).is_empty());
        let waived = "// lint: allow(safety-comment) — exercised by the miri suite\n\
                      let p = unsafe { q.add(1) };\n";
        assert!(rule_safety_comment("x.rs", waived).is_empty());
    }

    #[test]
    fn safety_ignores_prose_and_identifiers() {
        let src = "// unsafe is discussed here only.\n\
                   let s = \"unsafe impl Send\";\n\
                   #![deny(unsafe_code)]\n";
        assert!(rule_safety_comment("x.rs", src).is_empty());
    }

    // ---- lock-unwrap ---------------------------------------------------

    #[test]
    fn lock_unwrap_fires_on_the_original_client_pattern() {
        let src = "let exe = self.inner.exe_cache.lock().unwrap();\n";
        let f = rule_lock_unwrap("client.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn lock_unwrap_fires_across_line_breaks() {
        let src = "let g = state\n    .queue\n    .lock()\n    .unwrap();\n";
        let f = rule_lock_unwrap("engine.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3, "finding anchors at the .lock() line");
    }

    #[test]
    fn lock_unwrap_ignores_the_poison_tolerant_helper_and_prose() {
        let src = "// never .lock().unwrap() here\n\
                   let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n";
        assert!(rule_lock_unwrap("sync.rs", src).is_empty());
    }

    #[test]
    fn lock_unwrap_scope_covers_the_concurrent_subsystems() {
        assert!(lock_unwrap_scope("rust/src/runtime/client.rs"));
        assert!(lock_unwrap_scope("rust/src/coordinator/engine.rs"));
        assert!(lock_unwrap_scope("rust/src/server/mod.rs"));
        assert!(lock_unwrap_scope("rust/src/util/threadpool.rs"));
        assert!(!lock_unwrap_scope("rust/src/util/sync.rs"));
        assert!(!lock_unwrap_scope("rust/tests/integration.rs"));
    }

    // ---- kernel-clock --------------------------------------------------

    #[test]
    fn kernel_clock_fires_on_seeded_timing() {
        let src = "let t0 = std::time::Instant::now();\nlet w = SystemTime::now();\n";
        let f = rule_kernel_clock("rust/src/linalg/mod.rs", src);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn kernel_clock_ignores_comments_and_scope_is_kernels_only() {
        let src = "// Instant::now() would go here in a bench, not a kernel.\n";
        assert!(rule_kernel_clock("rust/src/linalg/mod.rs", src).is_empty());
        assert!(kernel_clock_scope("rust/src/attention/tiled.rs"));
        assert!(kernel_clock_scope("rust/src/linalg/mod.rs"));
        assert!(!kernel_clock_scope("rust/src/util/bench.rs"));
        assert!(!kernel_clock_scope("rust/benches/native_attention.rs"));
    }

    // ---- bench-writer --------------------------------------------------

    #[test]
    fn bench_writer_fires_on_raw_fs_write() {
        let src = "std::fs::write(path, doc.to_string()).expect(\"writing bench JSON\");\n";
        let f = rule_bench_writer("rust/benches/decode_throughput.rs", src);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn bench_writer_accepts_the_shared_writer() {
        let src = "sqa::util::bench::write_bench_json(path, &doc).expect(\"writing bench JSON\");\n";
        assert!(rule_bench_writer("rust/benches/decode_throughput.rs", src).is_empty());
    }

    // ---- simd-confinement ----------------------------------------------

    #[test]
    fn simd_confinement_fires_on_stray_intrinsics() {
        let src = "use core::arch::x86_64::*;\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn hot(xs: &[f32]) {}\n\
                   fn pick() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
        let f = rule_simd_confinement("rust/src/attention/tiled.rs", src);
        // line 1: core::arch; line 2: target_feature; line 4 matches both
        // the std::arch and is_x86_feature_detected needles.
        assert_eq!(f.len(), 4);
        assert!(f.iter().any(|x| x.line == 1));
        assert!(f.iter().any(|x| x.line == 2));
    }

    #[test]
    fn simd_confinement_ignores_prose_and_honors_waivers() {
        let src = "// core::arch is only mentioned in this comment.\n\
                   let s = \"#[target_feature]\";\n";
        assert!(rule_simd_confinement("rust/src/flops/mod.rs", src).is_empty());
        let waived = "// lint: allow(simd-confinement) — doc example, not compiled\n\
                      use core::arch::x86_64::*;\n";
        assert!(rule_simd_confinement("rust/src/flops/mod.rs", waived).is_empty());
    }

    #[test]
    fn simd_confinement_scope_exempts_only_the_two_seams() {
        assert!(!simd_confinement_scope("rust/src/linalg/simd.rs"));
        assert!(!simd_confinement_scope("rust/src/util/simd.rs"));
        assert!(simd_confinement_scope("rust/src/linalg/blocked.rs"));
        assert!(simd_confinement_scope("rust/src/attention/tiled.rs"));
        assert!(simd_confinement_scope("rust/benches/native_attention.rs"));
    }

    // ---- kv-block-confinement ------------------------------------------

    #[test]
    fn kv_block_confinement_fires_on_leaked_allocator_internals() {
        let src = "use crate::runtime::session::PoolInner;\n\
                   fn peek(b: &BlockData) {}\n\
                   let gone = table[i] == SPILLED;\n";
        let f = rule_kv_block_confinement("rust/src/runtime/native.rs", src);
        assert_eq!(f.len(), 3, "{:?}", f.iter().map(|x| x.to_string()).collect::<Vec<_>>());
        assert!(f.iter().all(|x| x.rule == "kv-block-confinement"));
    }

    #[test]
    fn kv_block_confinement_ignores_prose_api_types_and_waivers() {
        // Prose, strings, and the public API types are all fine.
        let src = "// PoolInner is private to session.rs by design.\n\
                   let s = \"BlockData\";\n\
                   let kv = PagedKvCache::new(pool, 8);\n\
                   let st: KvPoolStats = p.stats();\n";
        assert!(rule_kv_block_confinement("rust/src/runtime/native.rs", src).is_empty());
        let waived = "// lint: allow(kv-block-confinement) — doc example\n\
                      struct PoolInner;\n";
        assert!(rule_kv_block_confinement("rust/src/server/mod.rs", waived).is_empty());
    }

    #[test]
    fn kv_block_confinement_scope_exempts_only_the_allocator() {
        assert!(!kv_block_confinement_scope("rust/src/runtime/session.rs"));
        assert!(kv_block_confinement_scope("rust/src/runtime/native.rs"));
        assert!(kv_block_confinement_scope("rust/src/coordinator/engine.rs"));
        assert!(kv_block_confinement_scope("rust/src/server/mod.rs"));
        // Tests and benches may exercise internals through the public API
        // only, but they are outside rust/src and compile against the crate
        // surface anyway — the compiler already confines them.
        assert!(!kv_block_confinement_scope("rust/tests/decode_differential.rs"));
        assert!(!kv_block_confinement_scope("rust/benches/decode_throughput.rs"));
    }

    // ---- the tree itself is the seventh fixture ------------------------

    #[test]
    fn repo_is_lint_clean() {
        let root = crate::repo_root().expect("repo root");
        let (files, findings) = run(&root).expect("lint run");
        assert!(files > 30, "expected to scan the whole tree, saw {files} files");
        let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(findings.is_empty(), "lint findings:\n{}", report.join("\n"));
    }
}
