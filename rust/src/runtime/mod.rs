//! L3 runtime: the [`Backend`] abstraction plus its two implementations.
//!
//! * [`backend`] — the `Backend` trait every upper layer (engine, trainer,
//!   bench harness, CLI) programs against, and [`open_backend`], which
//!   picks the implementation for this build.
//! * [`native`] — the default pure-Rust backend: catalog-defined reference
//!   models executed on the `attention` oracle; zero external dependencies.
//! * [`session`] — per-session KV caches ([`KvCache`]), the paged block
//!   allocator ([`session::BlockPool`] / [`session::PagedKvCache`]: COW
//!   prefix sharing, LRU spill/restore), and the [`session::SessionTable`]
//!   backing the stateful prefill/decode generation path.
//! * [`catalog`] — built-in model zoo + flat-parameter [`catalog::Layout`].
//! * [`checkpoint`] — host-side checkpoints shared by all backends.
//! * [`manifest`] — the `artifacts/manifest.json` contract with the
//!   build-time Python layers (types reused by the native catalog).
//! * [`client`] / [`state`] / [`pjrt`] (`--features pjrt`) — the PJRT/XLA
//!   artifact path: executable cache, device buffers, and its `Backend`
//!   adapter. Type-checks offline against `rust/xla-stub`.

pub mod backend;
pub mod catalog;
pub mod checkpoint;
pub mod manifest;
pub mod native;
pub mod session;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod state;

pub use backend::{open_backend, Backend, SessionStats};
pub use manifest::{Artifact, FamilyEntry, Kind, Manifest, ParamSpec, VariantEntry};
pub use native::NativeBackend;
pub use session::KvCache;
pub use session::KvDtype;
pub use session::{KvPoolStats, PagedConfig};

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use state::ModelState;
