//! L3 runtime: PJRT client wrapper, artifact manifest, device-resident state.
//!
//! The contract with the build-time Python layers (L1 Pallas kernels, L2 JAX
//! models) is `artifacts/manifest.json` + HLO-text files; see
//! `python/compile/aot.py`. Python never runs at request time — after
//! `make artifacts` the Rust binary is self-contained.

pub mod client;
pub mod manifest;
pub mod state;

pub use client::Runtime;
pub use manifest::{Artifact, FamilyEntry, Kind, Manifest, ParamSpec, VariantEntry};
pub use state::ModelState;
