//! Device-resident model state: the flat parameter vector (+ optimizer
//! moments during training) kept as PJRT buffers across steps.
//!
//! Checkpoints are written as raw little-endian f32 with a JSON sidecar
//! (`<stem>.meta.json`) recording family/variant/step and the parameter
//! layout digest, so restores are validated against the manifest.

use crate::runtime::client::Runtime;
use crate::runtime::manifest::{Kind, VariantEntry};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Flat-parameter model state on device.
pub struct ModelState {
    pub family: String,
    pub variant: String,
    pub n_params: usize,
    pub params: xla::PjRtBuffer,
}

impl ModelState {
    /// Initialize parameters by running the `init` artifact with `seed`.
    pub fn init(rt: &Runtime, family: &str, variant: &str, seed: i32) -> Result<Self> {
        let entry = rt.manifest().variant(family, variant)?;
        let artifact = rt
            .manifest()
            .find(family, variant, Kind::Init, None, None)?;
        let exe = rt.compile_artifact(artifact)?;
        let seed_buf = rt.buf_scalar_i32(seed)?;
        let params = rt.execute1(&exe, &[&seed_buf])?;
        Ok(Self {
            family: family.to_string(),
            variant: variant.to_string(),
            n_params: entry.n_params,
            params,
        })
    }

    /// Wrap an existing device buffer (e.g. after a train step).
    pub fn from_buffer(
        family: &str,
        variant: &str,
        n_params: usize,
        params: xla::PjRtBuffer,
    ) -> Self {
        Self {
            family: family.to_string(),
            variant: variant.to_string(),
            n_params,
            params,
        }
    }

    /// Copy parameters to the host.
    pub fn to_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        let v = rt.to_vec_f32(&self.params)?;
        if v.len() != self.n_params {
            bail!("param buffer has {} floats, expected {}", v.len(), self.n_params);
        }
        Ok(v)
    }

    /// Extract one named parameter tensor (host copy) for inspection.
    pub fn get_param(
        &self,
        rt: &Runtime,
        entry: &VariantEntry,
        name: &str,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let spec = entry
            .params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("no parameter named {name:?}"))?;
        let host = self.to_host(rt)?;
        let data = host[spec.offset..spec.offset + spec.size()].to_vec();
        Ok((spec.shape.clone(), data))
    }

    /// Write a checkpoint: raw f32 LE + JSON sidecar.
    pub fn save(&self, rt: &Runtime, path: &Path, step: usize) -> Result<()> {
        let host = self.to_host(rt)?;
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let bytes: Vec<u8> = host.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        let meta = crate::util::json::Json::obj(vec![
            ("family", crate::util::json::Json::str(&self.family)),
            ("variant", crate::util::json::Json::str(&self.variant)),
            ("n_params", crate::util::json::Json::num(self.n_params as f64)),
            ("step", crate::util::json::Json::num(step as f64)),
        ]);
        std::fs::write(meta_path(path), meta.to_string())?;
        Ok(())
    }

    /// Load a checkpoint; validates family/variant/size against `self`'s ids.
    pub fn load(rt: &Runtime, family: &str, variant: &str, path: &Path) -> Result<(Self, usize)> {
        let entry = rt.manifest().variant(family, variant)?;
        let meta_text = std::fs::read_to_string(meta_path(path))
            .with_context(|| format!("reading {}", meta_path(path).display()))?;
        let meta = crate::util::json::Json::parse(&meta_text)?;
        let m_family = meta.req("family")?.as_str().unwrap_or_default();
        let m_variant = meta.req("variant")?.as_str().unwrap_or_default();
        if m_family != family || m_variant != variant {
            bail!(
                "checkpoint is for {m_family}/{m_variant}, wanted {family}/{variant}"
            );
        }
        let step = meta.req("step")?.as_usize().context("step")?;
        let mut f = std::fs::File::open(path)?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() != entry.n_params * 4 {
            bail!(
                "checkpoint has {} bytes, expected {}",
                bytes.len(),
                entry.n_params * 4
            );
        }
        let host: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let params = rt.buf_f32(&host, &[entry.n_params])?;
        Ok((
            Self::from_buffer(family, variant, entry.n_params, params),
            step,
        ))
    }
}

fn meta_path(path: &Path) -> std::path::PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".meta.json");
    std::path::PathBuf::from(p)
}
