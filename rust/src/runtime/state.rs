//! Device-resident model state: the flat parameter vector (+ optimizer
//! moments during training) kept as PJRT buffers across steps.
//!
//! Checkpoint I/O delegates to [`crate::runtime::checkpoint`] (raw LE f32 +
//! JSON sidecar — one on-disk format for all backends); restores are
//! validated against the manifest's parameter count before upload.

use crate::runtime::client::Runtime;
use crate::runtime::manifest::{Kind, VariantEntry};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Flat-parameter model state on device.
pub struct ModelState {
    pub family: String,
    pub variant: String,
    pub n_params: usize,
    pub params: xla::PjRtBuffer,
}

impl ModelState {
    /// Initialize parameters by running the `init` artifact with `seed`.
    pub fn init(rt: &Runtime, family: &str, variant: &str, seed: i32) -> Result<Self> {
        let entry = rt.manifest().variant(family, variant)?;
        let artifact = rt
            .manifest()
            .find(family, variant, Kind::Init, None, None)?;
        let exe = rt.compile_artifact(artifact)?;
        let seed_buf = rt.buf_scalar_i32(seed)?;
        let params = rt.execute1(&exe, &[&seed_buf])?;
        Ok(Self {
            family: family.to_string(),
            variant: variant.to_string(),
            n_params: entry.n_params,
            params,
        })
    }

    /// Wrap an existing device buffer (e.g. after a train step).
    pub fn from_buffer(
        family: &str,
        variant: &str,
        n_params: usize,
        params: xla::PjRtBuffer,
    ) -> Self {
        Self {
            family: family.to_string(),
            variant: variant.to_string(),
            n_params,
            params,
        }
    }

    /// Copy parameters to the host.
    pub fn to_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        let v = rt.to_vec_f32(&self.params)?;
        if v.len() != self.n_params {
            bail!("param buffer has {} floats, expected {}", v.len(), self.n_params);
        }
        Ok(v)
    }

    /// Extract one named parameter tensor (host copy) for inspection.
    pub fn get_param(
        &self,
        rt: &Runtime,
        entry: &VariantEntry,
        name: &str,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let spec = entry
            .params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("no parameter named {name:?}"))?;
        let host = self.to_host(rt)?;
        let data = host[spec.offset..spec.offset + spec.size()].to_vec();
        Ok((spec.shape.clone(), data))
    }

    /// Write a checkpoint (shared on-disk format; see `runtime::checkpoint`).
    pub fn save(&self, rt: &Runtime, path: &Path, step: usize) -> Result<()> {
        let host = self.to_host(rt)?;
        crate::runtime::checkpoint::save(path, &self.family, &self.variant, step, &host)
    }

    /// Load a checkpoint; validates family/variant/size against the manifest.
    pub fn load(rt: &Runtime, family: &str, variant: &str, path: &Path) -> Result<(Self, usize)> {
        let entry = rt.manifest().variant(family, variant)?;
        let (host, step) =
            crate::runtime::checkpoint::load_file(path, family, variant, entry.n_params)?;
        let params = rt.buf_f32(&host, &[entry.n_params])?;
        Ok((
            Self::from_buffer(family, variant, entry.n_params, params),
            step,
        ))
    }
}
