//! PJRT adapter: the AOT HLO artifact path exposed through the [`Backend`]
//! trait (`--features pjrt` only).
//!
//! Wraps the low-level [`Runtime`] (executable cache, buffers, slicers) and
//! moves data across the host boundary at the trait's granularity: params
//! and train state up per call, logits/metrics down. The device-resident
//! fast path (state buffer fed step-to-step) lives below the trait inside
//! [`Runtime`] consumers that need it; the trait surface trades one host
//! round-trip per step for a backend-agnostic engine and trainer.

use crate::runtime::backend::Backend;
use crate::runtime::client::Runtime;
use crate::runtime::manifest::{FamilyEntry, Kind};
use crate::runtime::state::ModelState;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// [`Backend`] over compiled HLO artifacts (see `python/compile/aot.py`).
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Self {
            rt: Runtime::new(artifact_dir)?,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn exec_logits(
        &self,
        impl_: Option<&str>,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let artifact =
            self.rt
                .manifest()
                .find(family, variant, Kind::Fwd, Some(seq), impl_)?;
        ensure!(
            artifact.batch == Some(batch),
            "fwd artifact batch {:?} != requested {batch}",
            artifact.batch
        );
        let exe = self.rt.compile_artifact(artifact)?;
        let entry = self.rt.manifest().variant(family, variant)?;
        ensure!(params.len() == entry.n_params, "param size mismatch");
        let params_buf = self.rt.buf_f32(params, &[entry.n_params])?;
        let token_buf = self.rt.buf_i32(tokens, &[batch, seq])?;
        let out = self.rt.execute1(&exe, &[&params_buf, &token_buf])?;
        self.rt.to_vec_f32(&out)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn families(&self) -> &BTreeMap<String, FamilyEntry> {
        &self.rt.manifest().families
    }

    fn fwd_buckets(&self, family: &str, variant: &str) -> Vec<usize> {
        self.rt.manifest().fwd_seqs(family, variant, "xla")
    }

    fn fwd_batch(&self, family: &str, variant: &str, seq: usize) -> Result<usize> {
        let a = self
            .rt
            .manifest()
            .find(family, variant, Kind::Fwd, Some(seq), None)?;
        a.batch.context("fwd artifact missing batch dim")
    }

    fn fixed_fwd_batch(&self) -> bool {
        true // compiled artifacts are fixed-shape; batches must be padded
    }

    fn train_shape(&self, family: &str, variant: &str) -> Result<(usize, usize)> {
        let a = self
            .rt
            .manifest()
            .find(family, variant, Kind::Train, None, None)?;
        Ok((
            a.batch.context("train artifact missing batch")?,
            a.seq.context("train artifact missing seq")?,
        ))
    }

    fn init_params(&self, family: &str, variant: &str, seed: i32) -> Result<Vec<f32>> {
        let state = ModelState::init(&self.rt, family, variant, seed)?;
        state.to_host(&self.rt)
    }

    fn forward(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        self.exec_logits(None, family, variant, params, tokens, batch, seq)
    }

    fn train_step(
        &self,
        family: &str,
        variant: &str,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let entry = self.rt.manifest().variant(family, variant)?;
        let p = entry.n_params;
        ensure!(state.len() == 3 * p + 2, "train state size mismatch");
        let artifact = self
            .rt
            .manifest()
            .find(family, variant, Kind::Train, None, None)?;
        let exe = self.rt.compile_artifact(artifact)?;
        let state_buf = self.rt.buf_f32(state, &[state.len()])?;
        let step_buf = self.rt.buf_scalar_i32(step)?;
        let lr_buf = self.rt.buf_scalar_f32(lr)?;
        let token_buf = self.rt.buf_i32(tokens, &[batch, seq])?;
        let target_buf = self.rt.buf_i32(targets, &[batch, seq])?;
        let new_state = self.rt.execute1(
            &exe,
            &[&state_buf, &step_buf, &lr_buf, &token_buf, &target_buf],
        )?;
        let host = self.rt.to_vec_f32(&new_state)?;
        ensure!(host.len() == state.len(), "train artifact changed state size");
        state.copy_from_slice(&host);
        Ok((state[3 * p], state[3 * p + 1]))
    }

    fn eval(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let entry = self.rt.manifest().variant(family, variant)?;
        ensure!(params.len() == entry.n_params, "param size mismatch");
        let artifact = self
            .rt
            .manifest()
            .find(family, variant, Kind::Eval, None, None)?;
        let exe = self.rt.compile_artifact(artifact)?;
        let params_buf = self.rt.buf_f32(params, &[params.len()])?;
        let token_buf = self.rt.buf_i32(tokens, &[batch, seq])?;
        let target_buf = self.rt.buf_i32(targets, &[batch, seq])?;
        let out = self
            .rt
            .execute1(&exe, &[&params_buf, &token_buf, &target_buf])?;
        let la = self.rt.to_vec_f32(&out)?;
        ensure!(la.len() >= 2, "eval artifact returned {} floats", la.len());
        Ok((la[0], la[1]))
    }

    fn impls(&self) -> Vec<&'static str> {
        vec!["xla", "pallas"]
    }

    fn forward_impl(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        self.exec_logits(Some(impl_), family, variant, params, tokens, batch, seq)
    }
}
