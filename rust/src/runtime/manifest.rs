//! Parse `artifacts/manifest.json` — the contract between the build-time
//! Python AOT pipeline and this runtime.
//!
//! See `python/compile/aot.py` for the emitting side. The key invariant:
//! model parameters travel as **one flat f32 vector**; the manifest records
//! every parameter's (name, shape, offset) inside that vector so tooling
//! (checkpoint inspection, per-tensor stats) can interpret it.

use crate::config::{ModelDims, VariantCfg};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named parameter inside the flat vector.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// A (family, variant) model: geometry + parameter layout.
#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub cfg: VariantCfg,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
}

#[derive(Debug, Clone)]
pub struct FamilyEntry {
    pub dims: ModelDims,
    pub causal: bool,
    pub variants: BTreeMap<String, VariantEntry>,
}

/// Kind of compiled entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    Init,
    Train,
    Eval,
    Fwd,
}

impl Kind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "init" => Kind::Init,
            "train" => Kind::Train,
            "eval" => Kind::Eval,
            "fwd" => Kind::Fwd,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Init => "init",
            Kind::Train => "train",
            Kind::Eval => "eval",
            Kind::Fwd => "fwd",
        }
    }
}

/// Tensor shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One HLO artifact on disk.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub family: String,
    pub variant: String,
    pub impl_: String,
    pub kind: Kind,
    pub path: PathBuf,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub families: BTreeMap<String, FamilyEntry>,
    pub artifacts: Vec<Artifact>,
}

fn io_specs(v: &Json) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .context("expected array of io specs")?
        .iter()
        .map(|s| {
            Ok(IoSpec {
                shape: s
                    .req("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: s.req("dtype")?.as_str().context("dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let version = root.req("version")?.as_i64().context("version")?;
        if version != 2 {
            bail!("manifest version {version} unsupported (want 2)");
        }

        let mut families = BTreeMap::new();
        for (fname, fval) in root.req("families")?.as_obj().context("families")? {
            let dims = ModelDims::from_json(fval)?;
            let causal = fval.get("causal").and_then(|c| c.as_bool()).unwrap_or(true);
            let mut variants = BTreeMap::new();
            for (vname, vval) in fval.req("variants")?.as_obj().context("variants")? {
                let cfg = VariantCfg::from_json(vval)?;
                let params = vval
                    .req("params")?
                    .as_arr()
                    .context("params")?
                    .iter()
                    .map(|p| {
                        Ok(ParamSpec {
                            name: p.req("name")?.as_str().context("name")?.to_string(),
                            shape: p
                                .req("shape")?
                                .as_arr()
                                .context("shape")?
                                .iter()
                                .map(|d| d.as_usize().context("dim"))
                                .collect::<Result<_>>()?,
                            offset: p.req("offset")?.as_usize().context("offset")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let n_params = vval.req("n_params")?.as_usize().context("n_params")?;
                let sum: usize = params.iter().map(|p| p.size()).sum();
                if sum != n_params {
                    bail!("{fname}/{vname}: param sizes sum {sum} != n_params {n_params}");
                }
                variants.insert(
                    vname.clone(),
                    VariantEntry {
                        cfg,
                        n_params,
                        params,
                    },
                );
            }
            families.insert(
                fname.clone(),
                FamilyEntry {
                    dims,
                    causal,
                    variants,
                },
            );
        }

        let mut artifacts = Vec::new();
        for a in root.req("artifacts")?.as_arr().context("artifacts")? {
            artifacts.push(Artifact {
                family: a.req("family")?.as_str().context("family")?.to_string(),
                variant: a.req("variant")?.as_str().context("variant")?.to_string(),
                impl_: a
                    .get("impl")
                    .and_then(|i| i.as_str())
                    .unwrap_or("xla")
                    .to_string(),
                kind: Kind::parse(a.req("kind")?.as_str().context("kind")?)?,
                path: dir.join(a.req("path")?.as_str().context("path")?),
                batch: a.get("batch").and_then(|b| b.as_usize()),
                seq: a.get("seq").and_then(|s| s.as_usize()),
                inputs: io_specs(a.req("inputs")?)?,
                outputs: io_specs(a.req("outputs")?)?,
            });
        }

        Ok(Self {
            dir,
            families,
            artifacts,
        })
    }

    pub fn family(&self, name: &str) -> Result<&FamilyEntry> {
        self.families
            .get(name)
            .with_context(|| format!("family {name:?} not in manifest (have: {:?})", self.families.keys().collect::<Vec<_>>()))
    }

    pub fn variant(&self, family: &str, variant: &str) -> Result<&VariantEntry> {
        self.family(family)?.variants.get(variant).with_context(|| {
            format!("variant {variant:?} not in family {family:?}")
        })
    }

    /// Find one artifact; `impl_` of `None` prefers "xla".
    pub fn find(
        &self,
        family: &str,
        variant: &str,
        kind: Kind,
        seq: Option<usize>,
        impl_: Option<&str>,
    ) -> Result<&Artifact> {
        let want_impl = impl_.unwrap_or("xla");
        self.artifacts
            .iter()
            .find(|a| {
                a.family == family
                    && a.variant == variant
                    && a.kind == kind
                    && a.impl_ == want_impl
                    && (seq.is_none() || a.seq == seq)
            })
            .with_context(|| {
                format!(
                    "no artifact {family}/{variant}/{}/seq={seq:?}/impl={want_impl}",
                    kind.as_str()
                )
            })
    }

    /// All fwd sequence buckets available for (family, variant, impl).
    pub fn fwd_seqs(&self, family: &str, variant: &str, impl_: &str) -> Vec<usize> {
        let mut seqs: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.family == family
                    && a.variant == variant
                    && a.kind == Kind::Fwd
                    && a.impl_ == impl_
            })
            .filter_map(|a| a.seq)
            .collect();
        seqs.sort_unstable();
        seqs.dedup();
        seqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let text = r#"{
 "version": 2,
 "families": {
  "tiny": {
   "vocab": 64, "d_model": 8, "n_layers": 1, "h_total": 2, "d_head": 4,
   "d_ff": 16, "n_experts": 0, "moe_top_k": 1, "causal": true,
   "variants": {
    "sqa": {
     "hq": 1, "hkv": 1, "window": null, "n_params": 520,
     "params": [
      {"name": "embed", "shape": [64, 8], "dtype": "f32", "offset": 0},
      {"name": "norm_f", "shape": [8], "dtype": "f32", "offset": 512}
     ]
    }
   }
  }
 },
 "artifacts": [
  {"family": "tiny", "variant": "sqa", "impl": "xla", "kind": "fwd",
   "path": "x.hlo.txt", "batch": 2, "seq": 16,
   "inputs": [{"shape": [520], "dtype": "f32"}, {"shape": [2,16], "dtype": "i32"}],
   "outputs": [{"shape": [2,16,64], "dtype": "f32"}]}
 ]
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("sqa_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant("tiny", "sqa").unwrap();
        assert_eq!(v.n_params, 520);
        assert_eq!(v.params[1].offset, 512);
        let a = m
            .find("tiny", "sqa", Kind::Fwd, Some(16), None)
            .unwrap();
        assert_eq!(a.batch, Some(2));
        assert_eq!(m.fwd_seqs("tiny", "sqa", "xla"), vec![16]);
        assert!(m.find("tiny", "sqa", Kind::Train, None, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_param_sum() {
        let dir = std::env::temp_dir().join(format!("sqa_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{"version":2,"families":{"f":{"vocab":1,"d_model":1,"n_layers":1,
            "h_total":1,"d_head":1,"d_ff":1,"causal":true,
            "variants":{"v":{"hq":1,"hkv":1,"n_params":99,
            "params":[{"name":"a","shape":[2],"dtype":"f32","offset":0}]}}}},
            "artifacts":[]}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
