//! PJRT runtime: compile HLO-text artifacts once, execute many times.
//!
//! Wraps the `xla` crate's CPU PJRT client with:
//!   * an executable cache (HLO parse + compile happen once per artifact),
//!   * device-resident buffer helpers (`f32`/`i32` host→device, device→host),
//!   * **on-device slicing**: artifacts return exactly one array (the AOT
//!     contract bans tuples — this PJRT wrapper can't feed a tuple output
//!     back as an input), so training state is one fused f32 vector; small
//!     XlaBuilder-compiled slicer executables (cached per signature) read
//!     the metrics tail / params prefix without copying the whole state to
//!     the host.

use crate::runtime::manifest::{Artifact, Manifest};
use crate::util::sync::lock;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Shared handle to the PJRT client + caches. Cheap to clone.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<Inner>,
}

struct Inner {
    client: xla::PjRtClient,
    manifest: Manifest,
    exe_cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
    /// (vector length, start, stop) -> slicer executable.
    slicer_cache: Mutex<HashMap<(usize, usize, usize), Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: `Inner` is not auto-Send/Sync only because the `xla` crate's
// PJRT wrappers hold raw pointers into the C API. Sharing them across
// threads is sound for this wrapper because:
//
// * `client` (`PjRtClient`) wraps a `PJRT_Client*`. The PJRT C API
//   specifies its entry points are thread-safe ("PJRT is expected to be
//   thread-safe... implementations must allow concurrent calls", see
//   `pjrt_c_api.h`; the CPU client is backed by TFRT's multi-threaded
//   runtime, which serves concurrent Compile/Execute/BufferFromHost calls
//   by design). We only ever call through `&self` methods; the client is
//   never mutated from Rust after construction.
// * `manifest` is plain owned Rust data (paths + metadata), immutable
//   after load — Send + Sync on its own.
// * `exe_cache`/`slicer_cache` are only touched through their `Mutex`es
//   (via the poison-tolerant `lock` helper below): the `HashMap` and the
//   `Arc<PjRtLoadedExecutable>` handles inside are never aliased without
//   the lock. Executables themselves are only *used* via `execute_b`,
//   which is one of the concurrent-safe PJRT entry points.
// * Every execution owns its inputs/outputs: buffers are created per call
//   and results are popped out of the returned replica vectors, so no
//   cross-thread aliasing of `PjRtBuffer` raw pointers exists unless the
//   caller clones one — and `PjRtBuffer` is not `Clone`.
//
// What this does NOT claim: that arbitrary `xla` crate types are Sync.
// Only `Inner`'s specific fields, used in the specific patterns above.
unsafe impl Send for Inner {}
// SAFETY: see the Send justification above — all `&Inner` access is
// through PJRT's thread-safe entry points or Mutex-guarded caches.
unsafe impl Sync for Inner {}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self {
            inner: Arc::new(Inner {
                client,
                manifest,
                exe_cache: Mutex::new(HashMap::new()),
                slicer_cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.inner.client
    }

    /// Compile (or fetch from cache) the executable for an artifact path.
    pub fn compile(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = lock(&self.inner.exe_cache).get(path) {
            return Ok(Arc::clone(exe));
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(wrap_xla)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.inner
                .client
                .compile(&comp)
                .map_err(wrap_xla)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        log::debug!(
            "compiled {} in {:.2}s",
            path.file_name().unwrap_or_default().to_string_lossy(),
            t0.elapsed().as_secs_f64()
        );
        lock(&self.inner.exe_cache).insert(path.to_path_buf(), Arc::clone(&exe));
        Ok(exe)
    }

    pub fn compile_artifact(&self, a: &Artifact) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.compile(&a.path)
    }

    // ---- host <-> device -------------------------------------------------

    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.inner
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(wrap_xla)
    }

    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.inner
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(wrap_xla)
    }

    pub fn buf_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.buf_i32(&[v], &[])
    }

    pub fn buf_scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.buf_f32(&[v], &[])
    }

    pub fn to_vec_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(wrap_xla)?;
        lit.to_vec::<f32>().map_err(wrap_xla)
    }

    pub fn scalar_f32(&self, buf: &xla::PjRtBuffer) -> Result<f32> {
        let lit = buf.to_literal_sync().map_err(wrap_xla)?;
        lit.get_first_element::<f32>().map_err(wrap_xla)
    }

    // ---- execution ---------------------------------------------------------

    /// Execute with device-resident inputs; returns the single output array
    /// (the AOT contract: every artifact returns exactly one array).
    pub fn execute1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let mut out = exe.execute_b(args).map_err(wrap_xla)?;
        let replica = out
            .pop()
            .ok_or_else(|| anyhow!("execution returned no replicas"))?;
        let mut iter = replica.into_iter();
        let buf = iter
            .next()
            .ok_or_else(|| anyhow!("execution returned no outputs"))?;
        if iter.next().is_some() {
            bail!("artifact returned multiple outputs; the AOT contract is one array");
        }
        Ok(buf)
    }

    /// Device-side `vec[start..stop]` via a cached slicer executable —
    /// reads small slices (metrics tail, params prefix) of the fused state
    /// vector without copying the whole buffer to the host.
    pub fn slice_f32(
        &self,
        vec: &xla::PjRtBuffer,
        len: usize,
        start: usize,
        stop: usize,
    ) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(start < stop && stop <= len, "bad slice [{start}, {stop}) of {len}");
        let exe = self.slicer(len, start, stop)?;
        self.execute1(&exe, &[vec])
    }

    fn slicer(
        &self,
        len: usize,
        start: usize,
        stop: usize,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = (len, start, stop);
        if let Some(exe) = lock(&self.inner.slicer_cache).get(&key) {
            return Ok(Arc::clone(exe));
        }
        let builder = xla::XlaBuilder::new(&format!("slice_{start}_{stop}"));
        let param = builder
            .parameter(0, <f32 as xla::ArrayElement>::TY, &[len as i64], "v")
            .map_err(wrap_xla)?;
        let comp = param
            .slice_in_dim1(start as i64, stop as i64, 0)
            .map_err(wrap_xla)?
            .build()
            .map_err(wrap_xla)?;
        let exe = Arc::new(self.inner.client.compile(&comp).map_err(wrap_xla)?);
        lock(&self.inner.slicer_cache).insert(key, Arc::clone(&exe));
        Ok(exe)
    }
}

/// The xla crate has its own error type; adapt it to anyhow.
pub fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
