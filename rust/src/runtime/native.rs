//! Native backend: the full SQA stack in pure Rust — no Python, no XLA,
//! no artifacts.
//!
//! * **Forward** composes token embedding, residual
//!   [`crate::attention::sqa_layer_with`] blocks and an LM head, running the
//!   tiled streaming attention kernel by default (the naive S×S oracle on
//!   request, see [`crate::attention::Kernel`]). Serving batches fan out one
//!   row per [`crate::util::threadpool::ThreadPool`] job; a single row fans
//!   its attention out across (head, query-tile) jobs instead.
//! * **Training** is a fused forward+backward+AdamW step over the shared
//!   state layout `[params | m | v | loss, acc]`. The forward half streams
//!   through the tiled kernel; the backward pass recomputes attention
//!   probabilities row-by-row (checkpointing) instead of storing the
//!   `[s, s]` score matrices; its math is differentially tested against
//!   the forward path (train-step loss vs `eval` on identical inputs) and
//!   against the oracle in `rust/tests/integration.rs`.
//! * **Eval** reuses the forward path and computes cross-entropy on host.
//!
//! The model is the catalog's reference architecture (embed + residual
//! attention blocks + untied LM head with bias — no MLP: attention is the
//! subject under test, and Table 3's `H/Hq` scaling claim needs nothing
//! else). MoE families run the same dense blocks; `n_experts` only feeds
//! the analytic FLOPs model.

use crate::attention::tensor::Tensor;
use crate::attention::{sqa_layer_with, tiled, visible_range, Kernel, Spec};
use crate::runtime::backend::Backend;
use crate::runtime::catalog::{self, Geometry, Layout};
use crate::runtime::manifest::FamilyEntry;
use crate::util::rng::Pcg64;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const INIT_STD: f32 = 0.02;

/// Everything a worker job needs to run one row — `Copy`, no borrows.
#[derive(Debug, Clone, Copy)]
struct Model {
    lay: Layout,
    spec: Spec,
    kernel: Kernel,
}

/// Pure-Rust implementation of [`Backend`].
pub struct NativeBackend {
    families: BTreeMap<String, FamilyEntry>,
    geoms: BTreeMap<String, Geometry>,
    pool: ThreadPool,
    /// Default attention lowering (`SQA_KERNEL` env; tiled unless told
    /// otherwise). `forward_impl` overrides it per call.
    kernel: Kernel,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::with_kernel(Kernel::from_env())
    }

    /// Backend with an explicit default attention kernel.
    pub fn with_kernel(kernel: Kernel) -> Self {
        let (families, geoms) = catalog::builtin();
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, 8);
        Self {
            families,
            geoms,
            pool: ThreadPool::new(workers, 256),
            kernel,
        }
    }

    fn geom(&self, family: &str) -> Result<&Geometry> {
        self.geoms
            .get(family)
            .with_context(|| format!("family {family:?} has no native geometry"))
    }

    fn model(&self, family: &str, variant: &str) -> Result<Model> {
        self.model_with_kernel(family, variant, self.kernel)
    }

    fn model_with_kernel(&self, family: &str, variant: &str, kernel: Kernel) -> Result<Model> {
        let fam = Backend::family(self, family)?;
        let var = fam
            .variants
            .get(variant)
            .with_context(|| format!("variant {variant:?} not in family {family:?}"))?;
        Ok(Model {
            lay: Layout::new(&fam.dims, &var.cfg),
            spec: Spec {
                hq: var.cfg.hq,
                hkv: var.cfg.hkv,
                causal: fam.causal,
                window: var.cfg.window,
            },
            kernel,
        })
    }

    fn check_batch(
        &self,
        model: &Model,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<()> {
        ensure!(batch > 0 && seq > 0, "empty batch geometry {batch}x{seq}");
        ensure!(
            params.len() == model.lay.n_params(),
            "params has {} floats, layout wants {}",
            params.len(),
            model.lay.n_params()
        );
        ensure!(
            tokens.len() == batch * seq,
            "tokens has {} ids, want {batch}x{seq}",
            tokens.len()
        );
        Ok(())
    }

    /// Forward with an explicit model (lets `forward_impl` override the
    /// kernel). A single row runs on the caller thread and fans its tiled
    /// attention out across the pool; multi-row batches fan out one row per
    /// pool job instead (pool jobs must not submit nested jobs — the
    /// bounded queue could deadlock).
    fn forward_model(
        &self,
        model: Model,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        self.check_batch(&model, params, tokens, batch, seq)?;
        let row_len = seq * model.lay.vocab;
        if batch == 1 {
            return forward_row(&model, params, tokens, Some(&self.pool));
        }
        let params = Arc::new(params.to_vec());
        let tokens = Arc::new(tokens.to_vec());
        let (tx, rx) = mpsc::channel();
        for ib in 0..batch {
            let params = Arc::clone(&params);
            let tokens = Arc::clone(&tokens);
            let tx = tx.clone();
            self.pool.submit(move || {
                let row = &tokens[ib * seq..(ib + 1) * seq];
                let _ = tx.send((ib, forward_row(&model, &params, row, None)));
            });
        }
        drop(tx);
        let mut out = vec![0.0f32; batch * row_len];
        for _ in 0..batch {
            let (ib, logits) = rx.recv().context("forward worker lost")?;
            out[ib * row_len..(ib + 1) * row_len].copy_from_slice(&logits?);
        }
        Ok(out)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn families(&self) -> &BTreeMap<String, FamilyEntry> {
        &self.families
    }

    fn fwd_buckets(&self, family: &str, variant: &str) -> Vec<usize> {
        match (self.geoms.get(family), self.variant(family, variant)) {
            (Some(g), Ok(_)) if g.fwd_batch > 0 => g.fwd_seqs.clone(),
            _ => Vec::new(),
        }
    }

    fn fwd_batch(&self, family: &str, variant: &str, seq: usize) -> Result<usize> {
        self.variant(family, variant)?;
        let g = self.geom(family)?;
        ensure!(
            g.fwd_batch > 0 && g.fwd_seqs.contains(&seq),
            "no fwd bucket seq={seq} for {family}/{variant} (have {:?})",
            g.fwd_seqs
        );
        Ok(g.fwd_batch)
    }

    fn train_shape(&self, family: &str, variant: &str) -> Result<(usize, usize)> {
        self.variant(family, variant)?;
        self.geom(family)?
            .train
            .with_context(|| format!("family {family:?} has no train entry point"))
    }

    fn init_params(&self, family: &str, variant: &str, seed: i32) -> Result<Vec<f32>> {
        let model = self.model(family, variant)?;
        let stream = fnv1a(family.as_bytes()) ^ fnv1a(variant.as_bytes()).rotate_left(17);
        let mut rng = Pcg64::new_stream(seed as i64 as u64, stream);
        let mut params = vec![0.0f32; model.lay.n_params()];
        for p in params.iter_mut() {
            *p = rng.normal_f32(0.0, INIT_STD);
        }
        // Zero LM bias: initial logits stay near-uniform, so the first
        // training loss lands at ln(vocab) — a cheap sanity anchor.
        let (b_off, b_len) = model.lay.lm_bias();
        for p in params[b_off..b_off + b_len].iter_mut() {
            *p = 0.0;
        }
        Ok(params)
    }

    fn forward(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let model = self.model(family, variant)?;
        self.forward_model(model, params, tokens, batch, seq)
    }

    fn train_step(
        &self,
        family: &str,
        variant: &str,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let model = self.model(family, variant)?;
        let p = model.lay.n_params();
        ensure!(
            state.len() == 3 * p + 2,
            "train state has {} floats, want 3x{p}+2",
            state.len()
        );
        ensure!(step >= 1, "step must be >= 1 (got {step})");
        self.check_batch(&model, &state[..p], tokens, batch, seq)?;
        ensure!(targets.len() == batch * seq, "targets/tokens length mismatch");
        let vocab = model.lay.vocab as i32;
        ensure!(
            targets.iter().all(|&t| t >= 0 && t < vocab),
            "target id out of vocab range"
        );

        // Per-row forward+backward in parallel; grads reduced in row order
        // so training stays bit-deterministic.
        let n_pos = batch * seq;
        let inv_n = 1.0 / n_pos as f32;
        let params = Arc::new(state[..p].to_vec());
        let tokens_arc = Arc::new(tokens.to_vec());
        let targets_arc = Arc::new(targets.to_vec());
        let (tx, rx) = mpsc::channel();
        for ib in 0..batch {
            let params = Arc::clone(&params);
            let tokens = Arc::clone(&tokens_arc);
            let targets = Arc::clone(&targets_arc);
            let tx = tx.clone();
            self.pool.submit(move || {
                let t = &tokens[ib * seq..(ib + 1) * seq];
                let g = &targets[ib * seq..(ib + 1) * seq];
                let _ = tx.send((ib, train_row(&model, &params, t, g, inv_n)));
            });
        }
        drop(tx);
        let mut rows: Vec<Option<RowGrad>> = (0..batch).map(|_| None).collect();
        for _ in 0..batch {
            let (ib, rg) = rx.recv().context("train worker lost")?;
            rows[ib] = Some(rg?);
        }
        let mut grad = vec![0.0f32; p];
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        for rg in rows.into_iter().flatten() {
            loss_sum += rg.loss_sum as f64;
            acc_sum += rg.acc_count as f64;
            for (gt, gr) in grad.iter_mut().zip(&rg.grad) {
                *gt += gr;
            }
        }
        let loss = (loss_sum / n_pos as f64) as f32;
        let acc = (acc_sum / n_pos as f64) as f32;

        // Fused AdamW (decoupled decay 0 — these reference models are tiny).
        let (ps, rest) = state.split_at_mut(p);
        let (ms, rest) = rest.split_at_mut(p);
        let (vs, tail) = rest.split_at_mut(p);
        let c1 = 1.0 - ADAM_B1.powi(step);
        let c2 = 1.0 - ADAM_B2.powi(step);
        for i in 0..p {
            let g = grad[i];
            ms[i] = ADAM_B1 * ms[i] + (1.0 - ADAM_B1) * g;
            vs[i] = ADAM_B2 * vs[i] + (1.0 - ADAM_B2) * g * g;
            let mhat = ms[i] / c1;
            let vhat = vs[i] / c2;
            ps[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
        }
        tail[0] = loss;
        tail[1] = acc;
        Ok((loss, acc))
    }

    fn eval(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let model = self.model(family, variant)?;
        ensure!(targets.len() == batch * seq, "targets/tokens length mismatch");
        let logits = self.forward(family, variant, params, tokens, batch, seq)?;
        let vocab = model.lay.vocab;
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        for (pos, &t) in targets.iter().enumerate() {
            ensure!(t >= 0 && (t as usize) < vocab, "target id out of range");
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let (lse, argmax) = log_sum_exp_argmax(row);
            loss_sum += (lse - row[t as usize]) as f64;
            acc_sum += (argmax == t as usize) as u8 as f64;
        }
        let n = (batch * seq) as f64;
        Ok(((loss_sum / n) as f32, (acc_sum / n) as f32))
    }

    fn impls(&self) -> Vec<&'static str> {
        vec!["tiled", "naive"]
    }

    fn forward_impl(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let kernel = Kernel::parse(impl_)
            .with_context(|| format!("native backend has no attention impl {impl_:?}"))?;
        let model = self.model_with_kernel(family, variant, kernel)?;
        self.forward_model(model, params, tokens, batch, seq)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Stable `log(sum(exp(row)))` plus the argmax index.
fn log_sum_exp_argmax(row: &[f32]) -> (f32, usize) {
    let mut maxv = f32::NEG_INFINITY;
    let mut argmax = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > maxv {
            maxv = x;
            argmax = i;
        }
    }
    let sum: f32 = row.iter().map(|&x| (x - maxv).exp()).sum();
    (maxv + sum.ln(), argmax)
}

/// Clamped embedding lookup (XLA gather semantics: OOB ids clamp).
fn token_index(t: i32, vocab: usize) -> usize {
    (t.max(0) as usize).min(vocab - 1)
}

fn weight_tensor(params: &[f32], (off, len): (usize, usize), shape: &[usize]) -> Tensor {
    Tensor::from_vec(shape, params[off..off + len].to_vec())
        .expect("catalog layout shape mismatch")
}

/// Forward one sequence: tokens `[s]` -> logits `[s * vocab]`.
///
/// Built on [`sqa_layer_with`] so the serving path exercises the shared
/// attention kernels (tiled streaming by default, naive oracle on request);
/// the training path below re-derives the same math with explicit buffers
/// (and the two are differentially tested against each other). `pool`
/// fans the tiled attention out across (head, query-tile) jobs — pass
/// `None` when already running on a pool worker.
fn forward_row(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    pool: Option<&ThreadPool>,
) -> Result<Vec<f32>> {
    let lay = &model.lay;
    let (s, d, dh) = (tokens.len(), lay.d_model, lay.d_head);
    let (dq, dkv) = (lay.hq * dh, lay.hkv * dh);

    // x [1, 1, s, d] from the embedding table.
    let (e_off, _) = lay.embed();
    let mut x = Tensor::zeros(&[1, 1, s, d]);
    for (i, &t) in tokens.iter().enumerate() {
        let row = &params[e_off + token_index(t, lay.vocab) * d..][..d];
        let base = x.idx4(0, 0, i, 0);
        x.data[base..base + d].copy_from_slice(row);
    }

    for l in 0..lay.n_layers {
        let wq = weight_tensor(params, lay.wq(l), &[d, dq]);
        let wk = weight_tensor(params, lay.wk(l), &[d, dkv]);
        let wv = weight_tensor(params, lay.wv(l), &[d, dkv]);
        let wo = weight_tensor(params, lay.wo(l), &[dq, d]);
        let a = sqa_layer_with(&x, &wq, &wk, &wv, &wo, dh, model.spec, model.kernel, pool)?;
        for (xv, av) in x.data.iter_mut().zip(&a.data) {
            *xv += av;
        }
    }

    // logits[i, :] = x[i, :] @ lm_head + lm_bias
    let vocab = lay.vocab;
    let (h_off, _) = lay.lm_head();
    let (b_off, _) = lay.lm_bias();
    let bias = &params[b_off..b_off + vocab];
    let mut logits = vec![0.0f32; s * vocab];
    for i in 0..s {
        let out = &mut logits[i * vocab..(i + 1) * vocab];
        out.copy_from_slice(bias);
        let xr = &x.data[x.idx4(0, 0, i, 0)..][..d];
        for (p, &xv) in xr.iter().enumerate() {
            let wr = &params[h_off + p * vocab..][..vocab];
            for (o, &wv) in out.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
    Ok(logits)
}

/// One row's contribution to the batch gradient.
struct RowGrad {
    loss_sum: f32,
    acc_count: f32,
    grad: Vec<f32>,
}

/// `out[s, n] = x[s, m] @ w[m, n]` (row-major, contiguous inner loop).
fn matmul(x: &[f32], w: &[f32], s: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; s * n];
    for i in 0..s {
        let xr = &x[i * m..(i + 1) * m];
        let or = &mut out[i * n..(i + 1) * n];
        for (p, &xv) in xr.iter().enumerate() {
            let wr = &w[p * n..(p + 1) * n];
            for (o, &wv) in or.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// `g[m, n] += x[s, m]^T @ dy[s, n]`.
fn accum_xt_dy(g: &mut [f32], x: &[f32], dy: &[f32], s: usize, m: usize, n: usize) {
    for i in 0..s {
        let xr = &x[i * m..(i + 1) * m];
        let dr = &dy[i * n..(i + 1) * n];
        for (p, &xv) in xr.iter().enumerate() {
            let gr = &mut g[p * n..(p + 1) * n];
            for (gv, &dv) in gr.iter_mut().zip(dr) {
                *gv += xv * dv;
            }
        }
    }
}

/// `dx[s, m] += dy[s, n] @ w[m, n]^T`.
fn accum_dy_wt(dx: &mut [f32], dy: &[f32], w: &[f32], s: usize, m: usize, n: usize) {
    for i in 0..s {
        let dr = &dy[i * n..(i + 1) * n];
        let xr = &mut dx[i * m..(i + 1) * m];
        for (p, xv) in xr.iter_mut().enumerate() {
            let wr = &w[p * n..(p + 1) * n];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dr.iter().zip(wr) {
                acc += dv * wv;
            }
            *xv += acc;
        }
    }
}

/// Softmax of one attention row over its visible range (max-subtracted,
/// identical ordering to the oracle's) — shared by fwd and bwd recompute.
fn attn_probs(
    q: &[f32],
    k: &[f32],
    i: usize,
    h: usize,
    hk: usize,
    s: usize,
    dh: usize,
    dq_cols: usize,
    dkv_cols: usize,
    scale: f32,
    lo: usize,
    hi: usize,
    probs: &mut [f32],
) {
    let qi = &q[i * dq_cols + h * dh..][..dh];
    let mut maxv = f32::NEG_INFINITY;
    debug_assert!(hi <= s && lo < hi);
    for j in lo..hi {
        let kj = &k[j * dkv_cols + hk * dh..][..dh];
        let mut acc = 0.0f32;
        for (a, b) in qi.iter().zip(kj) {
            acc += a * b;
        }
        let sc = acc * scale;
        probs[j - lo] = sc;
        maxv = maxv.max(sc);
    }
    let mut denom = 0.0f32;
    for p in probs[..hi - lo].iter_mut() {
        *p = (*p - maxv).exp();
        denom += *p;
    }
    let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
    for p in probs[..hi - lo].iter_mut() {
        *p *= inv;
    }
}

/// Fused forward + backward for one sequence; returns loss/acc sums and the
/// parameter gradient (already scaled by `inv_n = 1 / (batch * seq)`).
fn train_row(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    targets: &[i32],
    inv_n: f32,
) -> Result<RowGrad> {
    let lay = &model.lay;
    let spec = model.spec;
    let (s, d, dh, vocab) = (tokens.len(), lay.d_model, lay.d_head, lay.vocab);
    let (hq, hkv) = (lay.hq, lay.hkv);
    let (dq_cols, dkv_cols) = (hq * dh, hkv * dh);
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let n_layers = lay.n_layers;

    // ---- forward, caching per-layer activations -------------------------
    let (e_off, _) = lay.embed();
    let mut x = vec![0.0f32; s * d];
    for (i, &t) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d]
            .copy_from_slice(&params[e_off + token_index(t, vocab) * d..][..d]);
    }
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
    let mut caches: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> =
        Vec::with_capacity(n_layers);
    let mut probs = vec![0.0f32; s];
    for l in 0..n_layers {
        xs.push(x.clone());
        let (wq_o, wq_n) = lay.wq(l);
        let (wk_o, wk_n) = lay.wk(l);
        let (wv_o, wv_n) = lay.wv(l);
        let (wo_o, wo_n) = lay.wo(l);
        let q = matmul(&x, &params[wq_o..wq_o + wq_n], s, d, dq_cols);
        let k = matmul(&x, &params[wk_o..wk_o + wk_n], s, d, dkv_cols);
        let v = matmul(&x, &params[wv_o..wv_o + wv_n], s, d, dkv_cols);
        let mut o = vec![0.0f32; s * dq_cols];
        match model.kernel {
            // Default forward: stream the head-interleaved [s, H·dh]
            // projections through the tiled kernel (the backward below still
            // recomputes row softmaxes — checkpointing keeps it streaming).
            Kernel::Tiled => {
                for h in 0..hq {
                    let hk = h / group;
                    tiled::stream_head(
                        &q,
                        dq_cols,
                        h * dh,
                        &k,
                        dkv_cols,
                        hk * dh,
                        &v,
                        &mut o,
                        dq_cols,
                        h * dh,
                        s,
                        dh,
                        spec,
                        tiled::TileConfig::default(),
                        scale,
                    );
                }
            }
            Kernel::Naive => {
                for h in 0..hq {
                    let hk = h / group;
                    for i in 0..s {
                        let (lo, hi) = visible_range(i, s, spec);
                        attn_probs(
                            &q,
                            &k,
                            i,
                            h,
                            hk,
                            s,
                            dh,
                            dq_cols,
                            dkv_cols,
                            scale,
                            lo,
                            hi,
                            &mut probs,
                        );
                        let oi = i * dq_cols + h * dh;
                        for j in lo..hi {
                            let p = probs[j - lo];
                            if p == 0.0 {
                                continue;
                            }
                            let vj = &v[j * dkv_cols + hk * dh..][..dh];
                            for (ov, &vv) in o[oi..oi + dh].iter_mut().zip(vj) {
                                *ov += p * vv;
                            }
                        }
                    }
                }
            }
        }
        let a = matmul(&o, &params[wo_o..wo_o + wo_n], s, dq_cols, d);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
        caches.push((q, k, v, o));
    }
    xs.push(x);
    let x_top = &xs[n_layers];

    // ---- LM head: loss, accuracy, dlogits -> dx and head grads ----------
    let (h_off, _) = lay.lm_head();
    let (b_off, _) = lay.lm_bias();
    let mut grad = vec![0.0f32; lay.n_params()];
    let mut dx = vec![0.0f32; s * d];
    let mut loss_sum = 0.0f32;
    let mut acc_count = 0.0f32;
    let mut logits = vec![0.0f32; vocab];
    let mut dl = vec![0.0f32; vocab];
    for i in 0..s {
        logits.copy_from_slice(&params[b_off..b_off + vocab]);
        let xr = &x_top[i * d..(i + 1) * d];
        for (p, &xv) in xr.iter().enumerate() {
            let wr = &params[h_off + p * vocab..][..vocab];
            for (o, &wv) in logits.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
        let t = targets[i] as usize;
        let (lse, argmax) = log_sum_exp_argmax(&logits);
        loss_sum += lse - logits[t];
        acc_count += (argmax == t) as u8 as f32;
        for (c, dv) in dl.iter_mut().enumerate() {
            *dv = (logits[c] - lse).exp() * inv_n;
        }
        dl[t] -= inv_n;
        // grad accumulation: lm_bias, lm_head, and dx through the head.
        for (gb, &dv) in grad[b_off..b_off + vocab].iter_mut().zip(&dl) {
            *gb += dv;
        }
        let dxr = &mut dx[i * d..(i + 1) * d];
        for (p, &xv) in xr.iter().enumerate() {
            let wr = &params[h_off + p * vocab..][..vocab];
            let gr = &mut grad[h_off + p * vocab..h_off + (p + 1) * vocab];
            let mut acc = 0.0f32;
            for ((g, &wv), &dv) in gr.iter_mut().zip(wr).zip(&dl) {
                *g += xv * dv;
                acc += dv * wv;
            }
            dxr[p] += acc;
        }
    }

    // ---- layers, in reverse ---------------------------------------------
    for l in (0..n_layers).rev() {
        let (q, k, v, o) = &caches[l];
        let x_in = &xs[l];
        let (wq_o, wq_n) = lay.wq(l);
        let (wk_o, wk_n) = lay.wk(l);
        let (wv_o, wv_n) = lay.wv(l);
        let (wo_o, wo_n) = lay.wo(l);
        // x_out = x_in + o @ wo; dx currently holds d(x_out).
        accum_xt_dy(&mut grad[wo_o..wo_o + wo_n], o, &dx, s, dq_cols, d);
        let mut dout = vec![0.0f32; s * dq_cols];
        accum_dy_wt(&mut dout, &dx, &params[wo_o..wo_o + wo_n], s, dq_cols, d);

        let mut dq = vec![0.0f32; s * dq_cols];
        let mut dk = vec![0.0f32; s * dkv_cols];
        let mut dv = vec![0.0f32; s * dkv_cols];
        let mut dp = vec![0.0f32; s];
        for h in 0..hq {
            let hk = h / group;
            for i in 0..s {
                let (lo, hi) = visible_range(i, s, spec);
                attn_probs(q, k, i, h, hk, s, dh, dq_cols, dkv_cols, scale, lo, hi, &mut probs);
                let doi = &dout[i * dq_cols + h * dh..][..dh];
                let mut sum_pd = 0.0f32;
                for j in lo..hi {
                    let vj = &v[j * dkv_cols + hk * dh..][..dh];
                    let mut acc = 0.0f32;
                    for (a, b) in doi.iter().zip(vj) {
                        acc += a * b;
                    }
                    dp[j - lo] = acc;
                    sum_pd += probs[j - lo] * acc;
                }
                let qi_base = i * dq_cols + h * dh;
                for j in lo..hi {
                    let p = probs[j - lo];
                    let ds = p * (dp[j - lo] - sum_pd) * scale;
                    let kj = &k[j * dkv_cols + hk * dh..][..dh];
                    for (dqv, &kv) in dq[qi_base..qi_base + dh].iter_mut().zip(kj) {
                        *dqv += ds * kv;
                    }
                    let qi = &q[qi_base..qi_base + dh];
                    let dkj = &mut dk[j * dkv_cols + hk * dh..j * dkv_cols + hk * dh + dh];
                    for (dkv_, &qv) in dkj.iter_mut().zip(qi) {
                        *dkv_ += ds * qv;
                    }
                    if p != 0.0 {
                        let dvj =
                            &mut dv[j * dkv_cols + hk * dh..j * dkv_cols + hk * dh + dh];
                        for (dvv, &dov) in dvj.iter_mut().zip(doi) {
                            *dvv += p * dov;
                        }
                    }
                }
            }
        }
        accum_xt_dy(&mut grad[wq_o..wq_o + wq_n], x_in, &dq, s, d, dq_cols);
        accum_xt_dy(&mut grad[wk_o..wk_o + wk_n], x_in, &dk, s, d, dkv_cols);
        accum_xt_dy(&mut grad[wv_o..wv_o + wv_n], x_in, &dv, s, d, dkv_cols);
        // d(x_in) = d(x_out) [residual] + projections' input grads.
        accum_dy_wt(&mut dx, &dq, &params[wq_o..wq_o + wq_n], s, d, dq_cols);
        accum_dy_wt(&mut dx, &dk, &params[wk_o..wk_o + wk_n], s, d, dkv_cols);
        accum_dy_wt(&mut dx, &dv, &params[wv_o..wv_o + wv_n], s, d, dkv_cols);
    }

    // ---- embedding scatter ----------------------------------------------
    for (i, &t) in tokens.iter().enumerate() {
        let g = &mut grad[e_off + token_index(t, vocab) * d..][..d];
        for (gv, &dv) in g.iter_mut().zip(&dx[i * d..(i + 1) * d]) {
            *gv += dv;
        }
    }

    Ok(RowGrad {
        loss_sum,
        acc_count,
        grad,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn init_is_seed_deterministic() {
        let b = backend();
        let a1 = b.init_params("tiny", "sqa", 5).unwrap();
        let a2 = b.init_params("tiny", "sqa", 5).unwrap();
        let a3 = b.init_params("tiny", "sqa", 6).unwrap();
        assert_eq!(a1, a2);
        assert_ne!(a1, a3);
        assert_ne!(
            a1,
            b.init_params("tiny", "mha", 5).unwrap(),
            "variants must not share init streams"
        );
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 1).unwrap();
        let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 37 % 2048) as i32).collect();
        let l1 = b.forward("tiny", "sqa", &params, &tokens, 2, 16).unwrap();
        let l2 = b.forward("tiny", "sqa", &params, &tokens, 2, 16).unwrap();
        assert_eq!(l1.len(), 2 * 16 * 2048);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn train_step_loss_matches_eval_on_same_batch() {
        // The fused train step records the loss at the *pre-update* params;
        // eval on the same params/batch must agree. This differentially
        // tests train_row's forward against forward_row/sqa_layer.
        let b = backend();
        let params = b.init_params("tiny", "sqa", 3).unwrap();
        let p = params.len();
        let mut state = vec![0.0f32; 3 * p + 2];
        state[..p].copy_from_slice(&params);
        let (bs, s) = (2usize, 12usize);
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 13 + 7) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t + 1) % 2048).collect();
        let (train_loss, _) = b
            .train_step("tiny", "sqa", &mut state, 1, 1e-3, &tokens, &targets, bs, s)
            .unwrap();
        let (eval_loss, _) = b
            .eval("tiny", "sqa", &params, &tokens, &targets, bs, s)
            .unwrap();
        assert!(
            (train_loss - eval_loss).abs() < 2e-3,
            "train {train_loss} vs eval {eval_loss}"
        );
        // The update must actually move the parameters.
        assert_ne!(&state[..p], &params[..]);
        assert_eq!(state[3 * p], train_loss);
    }

    #[test]
    fn repeated_train_steps_reduce_loss_on_fixed_batch() {
        // Overfitting one batch is the cheapest end-to-end gradient check:
        // loss must fall monotonically-ish and substantially.
        let b = backend();
        let params = b.init_params("tiny", "xsqa", 9).unwrap();
        let p = params.len();
        let mut state = vec![0.0f32; 3 * p + 2];
        state[..p].copy_from_slice(&params);
        let (bs, s) = (2usize, 16usize);
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 31 + 11) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t * 7 + 3) % 2048).collect();
        let mut losses = Vec::new();
        for step in 1..=30 {
            let (loss, _) = b
                .train_step("tiny", "xsqa", &mut state, step, 5e-3, &tokens, &targets, bs, s)
                .unwrap();
            assert!(loss.is_finite());
            losses.push(loss);
        }
        assert!(
            losses[29] < losses[0] - 2.0,
            "no overfit on fixed batch: {losses:?}"
        );
    }

    #[test]
    fn forward_impls_agree_and_tiled_is_default() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 2).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i * 97 % 2048) as i32).collect();
        let tiled = b
            .forward_impl("tiled", "tiny", "sqa", &params, &tokens, 1, 16)
            .unwrap();
        let naive = b
            .forward_impl("naive", "tiny", "sqa", &params, &tokens, 1, 16)
            .unwrap();
        assert_eq!(tiled.len(), naive.len());
        let worst = tiled
            .iter()
            .zip(&naive)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 1e-3, "kernels diverge by {worst}");
        // The plain forward entry point runs the default (tiled) path.
        let default = b.forward("tiny", "sqa", &params, &tokens, 1, 16).unwrap();
        assert_eq!(default, tiled);
        assert_eq!(b.impls(), vec!["tiled", "naive"]);
    }

    #[test]
    fn geometry_lookups() {
        let b = backend();
        assert_eq!(b.fwd_buckets("tiny", "sqa"), vec![64, 128, 256]);
        assert_eq!(b.fwd_batch("tiny", "sqa", 128).unwrap(), 8);
        assert!(b.fwd_batch("tiny", "sqa", 100).is_err());
        assert_eq!(b.train_shape("tiny", "sqa").unwrap(), (4, 64));
        assert!(b.train_shape("bench", "mha").is_err());
        assert!(b.fwd_buckets("dense_sm", "sqa").is_empty());
        assert!(b.forward_impl("pallas", "tiny", "sqa", &[], &[], 1, 1).is_err());
    }
}
