//! Native backend: the full SQA stack in pure Rust — no Python, no XLA,
//! no artifacts.
//!
//! * **Forward** composes token embedding, residual
//!   [`crate::attention::sqa_layer_slices`] blocks and an LM head, running
//!   the tiled streaming attention kernel by default (the naive S×S oracle
//!   on request, see [`crate::attention::Kernel`]). Every dense product —
//!   projections, attention score/PV blocks, LM head — runs through
//!   [`crate::linalg`] (blocked GEMM by default, the scalar oracle loops
//!   via [`crate::linalg::Impl::Scalar`]); weights are borrowed slices of
//!   the flat parameter vector, never copied per layer. Serving batches fan
//!   out one row per [`crate::util::threadpool::ThreadPool`] job with jobs
//!   *borrowing* params/tokens (`ThreadPool::run_borrowed`, no per-request
//!   clones); a single row fans its attention tiles and GEMM row blocks
//!   out across the pool instead.
//! * **Training** is a fused forward+backward+AdamW step over the shared
//!   state layout `[params | m | v | loss, acc]`. The forward half streams
//!   through the tiled kernel, checkpointing one contiguous activation
//!   slab plus each layer's projection slabs and per-row attention
//!   logsumexp; the backward half replays attention through the
//!   flash-style streaming backward ([`crate::attention::backward`]) —
//!   tile-recomputed score blocks on the `linalg` micro-GEMMs, never an
//!   `[s, s]` buffer and never a re-run of the online-softmax search —
//!   and reduces its weight/input gradients through the same `linalg`
//!   GEMMs (`xᵀ·dy`, `dy·wᵀ`). `Kernel::Naive` selects the scalar
//!   row-loop backward oracle end-to-end; the two are differentially
//!   tested in `rust/tests/grad_differential.rs` (plus train-step loss vs
//!   `eval`, the oracle suite in `rust/tests/integration.rs`, and
//!   scalar-vs-blocked in `rust/tests/linalg_differential.rs`).
//! * **Eval** reuses the forward path and computes cross-entropy on host.
//!
//! The model is the catalog's reference architecture (embed + residual
//! attention blocks + untied LM head with bias — no MLP: attention is the
//! subject under test, and Table 3's `H/Hq` scaling claim needs nothing
//! else). MoE families run the same dense blocks; `n_experts` only feeds
//! the analytic FLOPs model.

use crate::attention::backward::{self, attn_probs};
use crate::attention::decode::decode_attend;
use crate::attention::tensor::Tensor;
use crate::attention::{sqa_layer_slices, tiled, visible_range, Kernel, MaskPattern, Spec};
use crate::linalg;
use crate::runtime::backend::{Backend, SessionStats};
use crate::runtime::catalog::{self, Geometry, Layout};
use crate::runtime::manifest::FamilyEntry;
use crate::runtime::session::{
    BlockPool, KvCache, KvDtype, KvPoolStats, PagedConfig, PagedKvCache, SessionCache,
    SessionTable, TakeError,
};
use crate::util::sync::{self, AtomicU64, Mutex, Ordering};
use crate::util::threadpool::ThreadPool;
use crate::util::rng::Pcg64;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{mpsc, Arc};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const INIT_STD: f32 = 0.02;

/// Everything a worker job needs to run one row — `Copy`, no borrows.
#[derive(Debug, Clone, Copy)]
struct Model {
    lay: Layout,
    spec: Spec,
    kernel: Kernel,
    linalg: linalg::Impl,
}

/// A live generation session: model geometry + per-layer KV cache
/// (contiguous slab or paged block-table view, behind [`SessionCache`]).
struct DecodeSession {
    model: Model,
    kv: SessionCache,
}

/// Paged-KV serving state: the configured geometry, one [`BlockPool`] per
/// (layers, dkv) cache shape, and the LRU stamps driving idle-session
/// eviction. Present only when paging is enabled (`--kv-block-len` /
/// `SQA_KV_BLOCK_LEN`).
struct PagedRuntime {
    cfg: PagedConfig,
    pools: Mutex<HashMap<(usize, usize), Arc<BlockPool>>>,
    /// Monotonic touch clock (Relaxed: stamps are heuristic recency data,
    /// not a synchronization edge — the session table publishes state).
    clock: AtomicU64,
    /// session id -> last-touch stamp.
    lru: Mutex<HashMap<u64, u64>>,
}

impl PagedRuntime {
    fn new(cfg: PagedConfig) -> Self {
        Self {
            cfg,
            pools: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            lru: Mutex::new(HashMap::new()),
        }
    }

    /// The shared pool for one cache geometry (every variant of one family
    /// maps to one (layers, Hkv·dh) shape; distinct shapes get their own
    /// pools and the stats view merges them).
    fn pool_for(&self, layers: usize, dkv: usize, dtype: KvDtype) -> Result<Arc<BlockPool>> {
        let mut pools = sync::lock(&self.pools);
        if let Some(p) = pools.get(&(layers, dkv)) {
            return Ok(Arc::clone(p));
        }
        let p = BlockPool::new(&self.cfg, layers, dkv, dtype)?;
        pools.insert((layers, dkv), Arc::clone(&p));
        Ok(p)
    }

    fn touch(&self, id: u64) {
        let t = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        sync::lock(&self.lru).insert(id, t);
    }

    fn forget(&self, id: u64) {
        sync::lock(&self.lru).remove(&id);
    }

    fn stamps(&self) -> HashMap<u64, u64> {
        sync::lock(&self.lru).clone()
    }
}

/// Pure-Rust implementation of [`Backend`].
pub struct NativeBackend {
    families: BTreeMap<String, FamilyEntry>,
    geoms: BTreeMap<String, Geometry>,
    pool: ThreadPool,
    /// Default attention lowering (`SQA_KERNEL` env; tiled unless told
    /// otherwise). `forward_impl` overrides it per call.
    kernel: Kernel,
    /// Default GEMM lowering (`SQA_LINALG` env; blocked unless told
    /// otherwise). `forward_impl` strings like `"tiled+scalar"` override it.
    linalg: linalg::Impl,
    /// Storage precision of new sessions' KV caches (`SQA_KV_DTYPE` env;
    /// f32 unless told otherwise). The kernels always compute in f32 —
    /// this narrows only what the cache *stores* (and therefore what a
    /// decode step streams).
    kv_dtype: KvDtype,
    /// Live decode sessions. The take/Busy/put-back step protocol (and why
    /// it is safe under concurrent step/close) lives in [`SessionTable`];
    /// the loom suite model-checks it directly.
    sessions: SessionTable<DecodeSession>,
    /// Paged-KV allocator state (`SQA_KV_BLOCK_LEN` env / `with_paged`);
    /// `None` keeps the historical contiguous per-session slabs.
    paged: Option<PagedRuntime>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Parse a `forward_impl` string: `kernel[+linalg][@pattern]`, e.g.
/// `"tiled"`, `"naive"`, `"tiled+scalar"`, `"naive+blocked"`,
/// `"tiled@strided:4"`, `"tiled+scalar@sink:4:64"`. A bare kernel name
/// leaves the linalg choice `None` so the caller falls back to the
/// backend's configured default — a bare `"naive"` under
/// `SQA_LINALG=scalar` must not silently re-enable the blocked GEMMs
/// under test. A missing `@pattern` suffix likewise leaves the model's
/// catalog mask untouched ([`MaskPattern::Dense`]).
fn parse_impl(s: &str) -> Result<(Kernel, Option<linalg::Impl>, Option<MaskPattern>)> {
    let (base, pattern) = match s.split_once('@') {
        Some((b, p)) => (b, Some(MaskPattern::parse(p)?)),
        None => (s, None),
    };
    let (kernel, imp) = match base.split_once('+') {
        Some((k, l)) => (Kernel::parse(k)?, Some(linalg::Impl::parse(l)?)),
        None => (Kernel::parse(base)?, None),
    };
    Ok((kernel, imp, pattern))
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::with_impls(Kernel::from_env(), linalg::Impl::from_env())
    }

    /// Backend with an explicit default attention kernel.
    pub fn with_kernel(kernel: Kernel) -> Self {
        Self::with_impls(kernel, linalg::Impl::from_env())
    }

    /// Backend with explicit default attention kernel *and* GEMM lowering.
    pub fn with_impls(kernel: Kernel, linalg: linalg::Impl) -> Self {
        let (families, geoms) = catalog::builtin();
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, 8);
        Self {
            families,
            geoms,
            pool: ThreadPool::new(workers, 256),
            kernel,
            linalg,
            kv_dtype: KvDtype::from_env(),
            sessions: SessionTable::new(),
            paged: PagedConfig::from_env().map(PagedRuntime::new),
        }
    }

    /// Override the storage precision of subsequently created sessions'
    /// KV caches (tests and `sqa serve --kv-dtype`; the env default is
    /// [`KvDtype::from_env`]).
    pub fn with_kv_dtype(mut self, dtype: KvDtype) -> Self {
        self.kv_dtype = dtype;
        self
    }

    /// Enable (`Some`) or disable (`None`) the paged KV allocator for
    /// subsequently created sessions (tests, benches and `sqa serve
    /// --kv-block-len`; the env default is [`PagedConfig::from_env`]).
    pub fn with_paged(mut self, cfg: Option<PagedConfig>) -> Self {
        self.paged = cfg.map(PagedRuntime::new);
        self
    }

    /// Whether new sessions go through the paged allocator.
    pub fn paged_enabled(&self) -> bool {
        self.paged.is_some()
    }

    /// Evict (spill to disk) one idle paged session's exclusive blocks.
    /// Fails on unknown ids and on sessions with a step in flight (the
    /// `Busy` marker — never spill state a worker is reading). Returns the
    /// number of blocks spilled; 0 means nothing exclusive/resident.
    pub fn spill_session(&self, session: u64) -> Result<usize> {
        let Some(rt) = &self.paged else {
            bail!("paged kv cache is not enabled")
        };
        let Some(dir) = rt.cfg.spill_dir.clone() else {
            bail!("kv spill disabled: no spill dir configured")
        };
        let mut sess = match self.sessions.take(session) {
            Ok(s) => s,
            Err(TakeError::Unknown) => bail!("unknown decode session {session}"),
            Err(TakeError::Busy) => bail!("decode session {session} is mid-step"),
        };
        let out = (|| {
            let Some(kv) = sess.kv.as_paged_mut() else {
                return Ok(0);
            };
            if kv.is_spilled() {
                return Ok(0);
            }
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("create spill dir {}", dir.display()))?;
            kv.spill(dir.join(format!("session-{session}.kv")))
        })();
        self.sessions.put_back(session, sess);
        out
    }

    /// LRU sweep: spill idle paged sessions (oldest touch stamp first,
    /// skipping `keep` and anything mid-step) until the pool has headroom
    /// again. Returns the total blocks spilled.
    fn evict_idle_except(&self, keep: u64) -> Result<usize> {
        let Some(rt) = &self.paged else { return Ok(0) };
        if rt.cfg.spill_dir.is_none() {
            return Ok(0);
        }
        let stamps = rt.stamps();
        let mut ids = self.sessions.ids();
        ids.sort_by_key(|id| stamps.get(id).copied().unwrap_or(0));
        let mut spilled = 0usize;
        for id in ids {
            if id == keep {
                continue;
            }
            if let Some(ps) = self.kv_pool_stats() {
                // One decode step needs at most a fresh block + one COW.
                if spilled > 0 && ps.blocks_free >= 2 {
                    break;
                }
            }
            // Busy / concurrently-closed sessions are simply not idle.
            if let Ok(n) = self.spill_session(id) {
                spilled += n;
            }
        }
        Ok(spilled)
    }

    fn geom(&self, family: &str) -> Result<&Geometry> {
        self.geoms
            .get(family)
            .with_context(|| format!("family {family:?} has no native geometry"))
    }

    fn model(&self, family: &str, variant: &str) -> Result<Model> {
        self.model_with_impls(family, variant, self.kernel, self.linalg)
    }

    fn model_with_impls(
        &self,
        family: &str,
        variant: &str,
        kernel: Kernel,
        linalg: linalg::Impl,
    ) -> Result<Model> {
        let fam = Backend::family(self, family)?;
        let var = fam
            .variants
            .get(variant)
            .with_context(|| format!("variant {variant:?} not in family {family:?}"))?;
        Ok(Model {
            lay: Layout::new(&fam.dims, &var.cfg),
            spec: Spec {
                hq: var.cfg.hq,
                hkv: var.cfg.hkv,
                causal: fam.causal,
                window: var.cfg.window,
                pattern: MaskPattern::Dense,
            },
            kernel,
            linalg,
        })
    }

    /// Overlay an impl-string `@pattern` suffix on a catalog model's mask,
    /// re-validating the composed spec (unregistered bitmap/table ids and
    /// degenerate patterns are rejected here, before any kernel runs).
    fn model_with_pattern(
        &self,
        family: &str,
        variant: &str,
        kernel: Kernel,
        linalg: linalg::Impl,
        pattern: Option<MaskPattern>,
    ) -> Result<Model> {
        let mut model = self.model_with_impls(family, variant, kernel, linalg)?;
        if let Some(p) = pattern {
            model.spec = model.spec.with_pattern(p);
            model.spec.validate()?;
        }
        Ok(model)
    }

    fn check_batch(
        &self,
        model: &Model,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<()> {
        ensure!(batch > 0 && seq > 0, "empty batch geometry {batch}x{seq}");
        ensure!(
            params.len() == model.lay.n_params(),
            "params has {} floats, layout wants {}",
            params.len(),
            model.lay.n_params()
        );
        ensure!(
            tokens.len() == batch * seq,
            "tokens has {} ids, want {batch}x{seq}",
            tokens.len()
        );
        Ok(())
    }

    /// Forward with an explicit model (lets `forward_impl` override the
    /// kernel). A single row runs on the caller thread and fans its tiled
    /// attention + GEMM row blocks out across the pool; multi-row batches
    /// fan out one row per pool job instead (pool jobs must not submit
    /// nested jobs — the bounded queue could deadlock). Batch jobs *borrow*
    /// params/tokens via [`ThreadPool::run_borrowed`]: the serving hot path
    /// allocates nothing per request beyond its activations.
    fn forward_model(
        &self,
        model: Model,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        self.check_batch(&model, params, tokens, batch, seq)?;
        let row_len = seq * model.lay.vocab;
        if batch == 1 {
            return forward_row(&model, params, tokens, Some(&self.pool));
        }
        let (tx, rx) = mpsc::channel();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(batch);
        for ib in 0..batch {
            let tx = tx.clone();
            jobs.push(Box::new(move || {
                let row = &tokens[ib * seq..(ib + 1) * seq];
                let _ = tx.send((ib, forward_row(&model, params, row, None)));
            }));
        }
        drop(tx);
        self.pool.run_borrowed(jobs);
        let mut out = vec![0.0f32; batch * row_len];
        let mut got = 0usize;
        for (ib, logits) in rx.try_iter() {
            out[ib * row_len..(ib + 1) * row_len].copy_from_slice(&logits?);
            got += 1;
        }
        ensure!(got == batch, "forward worker lost ({got}/{batch})");
        Ok(out)
    }

    /// The fused train step with an explicit model (lets `train_step_impl`
    /// override kernel + linalg). Multi-row batches fan one row per pool
    /// job; a single row runs on the caller thread and fans its attention
    /// tiles, backward waves and GEMM row blocks out across the pool
    /// instead — in both shapes the gradient reduction order is fixed
    /// (rows in order, backward waves in job order), so training stays
    /// bit-deterministic for any worker count.
    #[allow(clippy::too_many_arguments)]
    fn train_step_model(
        &self,
        model: Model,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let p = model.lay.n_params();
        ensure!(
            state.len() == 3 * p + 2,
            "train state has {} floats, want 3x{p}+2",
            state.len()
        );
        ensure!(step >= 1, "step must be >= 1 (got {step})");
        self.check_batch(&model, &state[..p], tokens, batch, seq)?;
        ensure!(targets.len() == batch * seq, "targets/tokens length mismatch");
        let vocab = model.lay.vocab as i32;
        ensure!(
            targets.iter().all(|&t| t >= 0 && t < vocab),
            "target id out of vocab range"
        );

        // Per-row forward+backward in parallel; grads reduced in row order
        // so training stays bit-deterministic. Jobs borrow the params half
        // of the state directly (no per-step copies).
        let n_pos = batch * seq;
        let inv_n = 1.0 / n_pos as f32;
        let mut rows: Vec<Option<RowGrad>> = (0..batch).map(|_| None).collect();
        {
            let params = &state[..p];
            if batch == 1 {
                rows[0] =
                    Some(train_row(&model, params, tokens, targets, inv_n, Some(&self.pool))?);
            } else {
                let (tx, rx) = mpsc::channel();
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(batch);
                for ib in 0..batch {
                    let tx = tx.clone();
                    jobs.push(Box::new(move || {
                        let t = &tokens[ib * seq..(ib + 1) * seq];
                        let g = &targets[ib * seq..(ib + 1) * seq];
                        let _ = tx.send((ib, train_row(&model, params, t, g, inv_n, None)));
                    }));
                }
                drop(tx);
                self.pool.run_borrowed(jobs);
                let mut got = 0usize;
                for (ib, rg) in rx.try_iter() {
                    rows[ib] = Some(rg?);
                    got += 1;
                }
                ensure!(got == batch, "train worker lost ({got}/{batch})");
            }
        }
        let mut grad = vec![0.0f32; p];
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        for rg in rows.into_iter().flatten() {
            loss_sum += rg.loss_sum as f64;
            acc_sum += rg.acc_count as f64;
            for (gt, gr) in grad.iter_mut().zip(&rg.grad) {
                *gt += gr;
            }
        }
        let loss = (loss_sum / n_pos as f64) as f32;
        let acc = (acc_sum / n_pos as f64) as f32;

        // Fused AdamW (decoupled decay 0 — these reference models are tiny).
        let (ps, rest) = state.split_at_mut(p);
        let (ms, rest) = rest.split_at_mut(p);
        let (vs, tail) = rest.split_at_mut(p);
        let c1 = 1.0 - ADAM_B1.powi(step);
        let c2 = 1.0 - ADAM_B2.powi(step);
        for i in 0..p {
            let g = grad[i];
            ms[i] = ADAM_B1 * ms[i] + (1.0 - ADAM_B1) * g;
            vs[i] = ADAM_B2 * vs[i] + (1.0 - ADAM_B2) * g * g;
            let mhat = ms[i] / c1;
            let vhat = vs[i] / c2;
            ps[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
        }
        tail[0] = loss;
        tail[1] = acc;
        Ok((loss, acc))
    }

    /// Mean loss and the full parameter gradient of one batch at `params`,
    /// through an explicit `kernel[+linalg]` lowering — no optimizer step.
    /// Test/diagnostic entry point: the finite-difference suite in
    /// `rust/tests/grad_differential.rs` pins both analytic backwards
    /// (streaming and scalar oracle) against central differences of this
    /// loss, parameter block by parameter block.
    #[allow(clippy::too_many_arguments)]
    pub fn loss_and_grad(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let (kernel, imp, pattern) = parse_impl(impl_)
            .with_context(|| format!("native backend has no train impl {impl_:?}"))?;
        let model =
            self.model_with_pattern(family, variant, kernel, imp.unwrap_or(self.linalg), pattern)?;
        self.check_batch(&model, params, tokens, batch, seq)?;
        ensure!(targets.len() == batch * seq, "targets/tokens length mismatch");
        let vocab = model.lay.vocab as i32;
        ensure!(
            targets.iter().all(|&t| t >= 0 && t < vocab),
            "target id out of vocab range"
        );
        let inv_n = 1.0 / (batch * seq) as f32;
        let mut grad = vec![0.0f32; model.lay.n_params()];
        let mut loss_sum = 0.0f64;
        for ib in 0..batch {
            let rg = train_row(
                &model,
                params,
                &tokens[ib * seq..(ib + 1) * seq],
                &targets[ib * seq..(ib + 1) * seq],
                inv_n,
                Some(&self.pool),
            )?;
            loss_sum += rg.loss_sum as f64;
            for (gt, gr) in grad.iter_mut().zip(&rg.grad) {
                *gt += gr;
            }
        }
        Ok(((loss_sum / (batch * seq) as f64) as f32, grad))
    }

    /// Shared session setup behind [`Backend::prefill`] and
    /// [`Backend::prefill_impl`]: validates the prompt/capacity geometry,
    /// allocates the per-layer KV cache, and stores the (possibly
    /// pattern-carrying) model with the session.
    fn prefill_model(
        &self,
        model: Model,
        family: &str,
        params: &[f32],
        tokens: &[i32],
        capacity: usize,
    ) -> Result<(u64, Vec<f32>)> {
        ensure!(
            model.spec.causal,
            "prefill/decode needs a causal family (got {family:?})"
        );
        ensure!(capacity > 0, "session capacity must be positive");
        ensure!(!tokens.is_empty(), "empty prompt");
        ensure!(
            tokens.len() <= capacity,
            "prompt of {} tokens exceeds the session cache capacity {capacity}",
            tokens.len()
        );
        self.check_batch(&model, params, tokens, 1, tokens.len())?;
        let dkv = model.lay.hkv * model.lay.d_head;
        let (kv, logits) = if let Some(rt) = &self.paged {
            let pool = rt.pool_for(model.lay.n_layers, dkv, self.kv_dtype)?;
            // Prefix namespace = params ⊕ full model description (layout,
            // mask spec, kernel + linalg lowering): reusing a cached block
            // is only sound between sessions that would have recomputed
            // bit-comparable K/V rows for those tokens.
            let ns = fnv1a(format!("{model:?}").as_bytes()) ^ fnv1a_f32(params);
            let (blocks, hit) = pool.prefix_lookup(ns, tokens);
            let mut paged = PagedKvCache::new(pool, capacity);
            if hit > 0 {
                paged.adopt_prefix(blocks, hit)?;
            }
            let mut kv = SessionCache::Paged(paged);
            let logits = if hit > 0 {
                // Trie hit: the shared blocks stand in for positions
                // 0..hit, so the forward runs only over the unshared
                // suffix — the FLOP saving that rides on top of SQA's
                // per-token Hq reduction.
                prefill_suffix(&model, params, tokens, hit, &mut kv, &self.pool)?
            } else {
                prefill_row(&model, params, tokens, &mut kv, Some(&self.pool))?
            };
            if let Some(p) = kv.as_paged() {
                p.publish_prefix(ns, tokens);
            }
            (kv, logits)
        } else {
            let mut kv = SessionCache::Contig(KvCache::new_with_dtype(
                model.lay.n_layers,
                capacity,
                dkv,
                self.kv_dtype,
            ));
            let logits = prefill_row(&model, params, tokens, &mut kv, Some(&self.pool))?;
            (kv, logits)
        };
        let id = self.sessions.insert(DecodeSession { model, kv });
        if let Some(rt) = &self.paged {
            rt.touch(id);
        }
        Ok((id, logits))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn families(&self) -> &BTreeMap<String, FamilyEntry> {
        &self.families
    }

    fn fwd_buckets(&self, family: &str, variant: &str) -> Vec<usize> {
        match (self.geoms.get(family), self.variant(family, variant)) {
            (Some(g), Ok(_)) if g.fwd_batch > 0 => g.fwd_seqs.clone(),
            _ => Vec::new(),
        }
    }

    fn fwd_batch(&self, family: &str, variant: &str, seq: usize) -> Result<usize> {
        self.variant(family, variant)?;
        let g = self.geom(family)?;
        ensure!(
            g.fwd_batch > 0 && g.fwd_seqs.contains(&seq),
            "no fwd bucket seq={seq} for {family}/{variant} (have {:?})",
            g.fwd_seqs
        );
        Ok(g.fwd_batch)
    }

    fn train_shape(&self, family: &str, variant: &str) -> Result<(usize, usize)> {
        self.variant(family, variant)?;
        self.geom(family)?
            .train
            .with_context(|| format!("family {family:?} has no train entry point"))
    }

    fn init_params(&self, family: &str, variant: &str, seed: i32) -> Result<Vec<f32>> {
        let model = self.model(family, variant)?;
        let stream = fnv1a(family.as_bytes()) ^ fnv1a(variant.as_bytes()).rotate_left(17);
        let mut rng = Pcg64::new_stream(seed as i64 as u64, stream);
        let mut params = vec![0.0f32; model.lay.n_params()];
        for p in params.iter_mut() {
            *p = rng.normal_f32(0.0, INIT_STD);
        }
        // Zero LM bias: initial logits stay near-uniform, so the first
        // training loss lands at ln(vocab) — a cheap sanity anchor.
        let (b_off, b_len) = model.lay.lm_bias();
        for p in params[b_off..b_off + b_len].iter_mut() {
            *p = 0.0;
        }
        Ok(params)
    }

    fn forward(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let model = self.model(family, variant)?;
        self.forward_model(model, params, tokens, batch, seq)
    }

    fn train_step(
        &self,
        family: &str,
        variant: &str,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let model = self.model(family, variant)?;
        self.train_step_model(model, state, step, lr, tokens, targets, batch, seq)
    }
    fn train_step_impl(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let (kernel, imp, pattern) = parse_impl(impl_)
            .with_context(|| format!("native backend has no train impl {impl_:?}"))?;
        let model =
            self.model_with_pattern(family, variant, kernel, imp.unwrap_or(self.linalg), pattern)?;
        self.train_step_model(model, state, step, lr, tokens, targets, batch, seq)
    }

    fn eval(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)> {
        let model = self.model(family, variant)?;
        ensure!(targets.len() == batch * seq, "targets/tokens length mismatch");
        let logits = self.forward(family, variant, params, tokens, batch, seq)?;
        let vocab = model.lay.vocab;
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        for (pos, &t) in targets.iter().enumerate() {
            ensure!(t >= 0 && (t as usize) < vocab, "target id out of range");
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let (lse, argmax) = log_sum_exp_argmax(row);
            loss_sum += (lse - row[t as usize]) as f64;
            acc_sum += (argmax == t as usize) as u8 as f64;
        }
        let n = (batch * seq) as f64;
        Ok(((loss_sum / n) as f32, (acc_sum / n) as f32))
    }

    fn impls(&self) -> Vec<&'static str> {
        // `kernel[+linalg]`: the bare names run the blocked GEMMs;
        // `+scalar` swaps in the element-at-a-time oracle loops
        // ("tiled+scalar" is the PR-2 execution path, the bench baseline);
        // `+simd` engages the vectorized micro-kernel + online-softmax
        // tier, silently degrading to blocked where the host lacks
        // AVX2+FMA/NEON.
        vec![
            "tiled",
            "naive",
            "tiled+scalar",
            "naive+scalar",
            "tiled+simd",
            "naive+simd",
        ]
    }

    fn forward_impl(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let (kernel, imp, pattern) = parse_impl(impl_)
            .with_context(|| format!("native backend has no attention impl {impl_:?}"))?;
        let model =
            self.model_with_pattern(family, variant, kernel, imp.unwrap_or(self.linalg), pattern)?;
        self.forward_model(model, params, tokens, batch, seq)
    }

    // ---- stateful generation --------------------------------------------

    fn supports_decode(&self) -> bool {
        true
    }

    fn prefill(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        capacity: usize,
    ) -> Result<(u64, Vec<f32>)> {
        let model = self.model(family, variant)?;
        self.prefill_model(model, family, params, tokens, capacity)
    }

    fn prefill_impl(
        &self,
        impl_: &str,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        capacity: usize,
    ) -> Result<(u64, Vec<f32>)> {
        let (kernel, imp, pattern) = parse_impl(impl_)
            .with_context(|| format!("native backend has no attention impl {impl_:?}"))?;
        let model =
            self.model_with_pattern(family, variant, kernel, imp.unwrap_or(self.linalg), pattern)?;
        // The session keeps the pattern-carrying model, so every subsequent
        // decode_step masks its cached positions by the same rules.
        self.prefill_model(model, family, params, tokens, capacity)
    }

    fn prefill_extend(&self, session: u64, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        // Same take/put_back protocol as decode_step: the Busy marker keeps
        // a concurrent close from racing the compute. Chunks appended here
        // are not published to the prefix trie — only the session-creating
        // prefill chunk is (a chunked prompt's later spans depend on the
        // session's full history, which the trie keys cannot express).
        let mut sess = match self.sessions.take(session) {
            Ok(s) => s,
            Err(TakeError::Unknown) => bail!("unknown decode session {session}"),
            Err(TakeError::Busy) => bail!("decode session {session} is mid-step"),
        };
        let out = (|| {
            self.check_batch(&sess.model, params, tokens, 1, tokens.len().max(1))?;
            // Same restore/evict/retry dance as decode_step; re-running a
            // failed append is sound because `advance` only commits at the
            // end and rewrites of uncommitted rows are idempotent.
            let mut attempt = || -> Result<Vec<f32>> {
                sess.kv.ensure_resident()?;
                append_rows(&sess.model, params, tokens, &mut sess.kv, &self.pool)
            };
            match attempt() {
                Err(e) if e.to_string().contains("block pool exhausted") => {
                    if self.evict_idle_except(session)? == 0 {
                        return Err(e);
                    }
                    attempt()
                }
                r => r,
            }
        })();
        if out.is_ok() {
            if let Some(rt) = &self.paged {
                rt.touch(session);
            }
        }
        self.sessions.put_back(session, sess);
        out
    }

    fn decode_step(&self, session: u64, params: &[f32], token: i32) -> Result<Vec<f32>> {
        // Take the session out of the table (leaving a Busy marker) so
        // steps for other sessions never serialize on the lock and a
        // concurrent close cannot race the compute.
        let mut sess = match self.sessions.take(session) {
            Ok(s) => s,
            Err(TakeError::Unknown) => bail!("unknown decode session {session}"),
            Err(TakeError::Busy) => bail!("decode session {session} is mid-step"),
        };
        let out = (|| {
            self.check_batch(&sess.model, params, &[token], 1, 1)?;
            // Spilled sessions restore transparently before the step; if
            // the pool is out of blocks (for the restore *or* a fresh
            // append), one LRU sweep spills idle sessions and the step
            // retries. Re-running a failed step is sound: nothing was
            // committed (`advance` never ran), and rewrites of the same
            // uncommitted rows are idempotent.
            let mut attempt = || -> Result<Vec<f32>> {
                sess.kv.ensure_resident()?;
                decode_step_row(&sess.model, params, token, &mut sess.kv)
            };
            match attempt() {
                Err(e) if e.to_string().contains("block pool exhausted") => {
                    if self.evict_idle_except(session)? == 0 {
                        return Err(e);
                    }
                    attempt()
                }
                r => r,
            }
        })();
        if out.is_ok() {
            if let Some(rt) = &self.paged {
                rt.touch(session);
            }
        }
        // Put the session back — unless it was closed while we computed,
        // in which case put_back drops the state.
        self.sessions.put_back(session, sess);
        out
    }

    fn close_session(&self, session: u64) -> bool {
        if let Some(rt) = &self.paged {
            rt.forget(session);
        }
        // Dropping a paged session's state returns its blocks to the pool
        // and deletes any spill file (PagedKvCache::drop).
        self.sessions.close(session)
    }

    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        let rt = self.paged.as_ref()?;
        let pools = sync::lock(&rt.pools);
        let mut merged = KvPoolStats::default();
        if pools.is_empty() {
            // No session yet: report the configured (empty) pool so
            // admission headroom checks see full capacity, not "no pool".
            merged.block_len = rt.cfg.block_len;
            merged.blocks_total = rt.cfg.pool_blocks;
            merged.blocks_free = rt.cfg.pool_blocks;
            return Some(merged);
        }
        for p in pools.values() {
            merged.absorb(&p.stats());
        }
        Some(merged)
    }

    fn session_stats(&self, session: u64) -> Result<SessionStats> {
        match self.sessions.with(session, |s| SessionStats {
            len: s.kv.len(),
            capacity: s.kv.capacity(),
            kv_bytes: s.kv.step_bytes(s.model.spec.window) as u64,
            alloc_bytes: s.kv.alloc_bytes() as u64,
        }) {
            Ok(stats) => Ok(stats),
            Err(TakeError::Busy) => bail!("decode session {session} is mid-step"),
            Err(TakeError::Unknown) => bail!("unknown decode session {session}"),
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over f32 bit patterns — the parameter half of the prefix-trie
/// namespace. O(n_params) per prefill, a rounding error next to the
/// prefill GEMMs it may let us skip.
fn fnv1a_f32(xs: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &x in xs {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Stable `log(sum(exp(row)))` plus the argmax index.
fn log_sum_exp_argmax(row: &[f32]) -> (f32, usize) {
    let mut maxv = f32::NEG_INFINITY;
    let mut argmax = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > maxv {
            maxv = x;
            argmax = i;
        }
    }
    let sum: f32 = row.iter().map(|&x| (x - maxv).exp()).sum();
    (maxv + sum.ln(), argmax)
}

/// Clamped embedding lookup (XLA gather semantics: OOB ids clamp).
fn token_index(t: i32, vocab: usize) -> usize {
    (t.max(0) as usize).min(vocab - 1)
}

/// Borrow a named weight slice out of the flat parameter vector — no copy;
/// the serving hot path must not allocate per layer per request.
#[inline]
fn weight_slice(params: &[f32], (off, len): (usize, usize)) -> &[f32] {
    &params[off..off + len]
}

/// Forward one sequence: tokens `[s]` -> logits `[s * vocab]`.
///
/// Built on [`sqa_layer_slices`] so the serving path exercises the shared
/// attention kernels (tiled streaming by default, naive oracle on request)
/// and the shared [`linalg`] GEMMs; weights stay borrowed views into
/// `params`. The training path below re-derives the same math with
/// explicit buffers (and the two are differentially tested against each
/// other). `pool` fans the tiled attention out across (head, query-tile)
/// jobs and the projection/LM-head GEMMs over row blocks — pass `None`
/// when already running on a pool worker.
fn forward_row(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    pool: Option<&ThreadPool>,
) -> Result<Vec<f32>> {
    let lay = &model.lay;
    let (s, d, dh) = (tokens.len(), lay.d_model, lay.d_head);

    // x [1, 1, s, d] from the embedding table.
    let (e_off, _) = lay.embed();
    let mut x = Tensor::zeros(&[1, 1, s, d]);
    for (i, &t) in tokens.iter().enumerate() {
        let row = &params[e_off + token_index(t, lay.vocab) * d..][..d];
        let base = x.idx4(0, 0, i, 0);
        x.data[base..base + d].copy_from_slice(row);
    }

    for l in 0..lay.n_layers {
        let a = sqa_layer_slices(
            &x,
            weight_slice(params, lay.wq(l)),
            weight_slice(params, lay.wk(l)),
            weight_slice(params, lay.wv(l)),
            weight_slice(params, lay.wo(l)),
            dh,
            model.spec,
            model.kernel,
            model.linalg,
            pool,
        )?;
        for (xv, av) in x.data.iter_mut().zip(&a.data) {
            *xv += av;
        }
    }

    // logits = x @ lm_head + lm_bias, one GEMM over the whole sequence.
    let vocab = lay.vocab;
    let head = weight_slice(params, lay.lm_head());
    let bias = weight_slice(params, lay.lm_bias());
    let mut logits = vec![0.0f32; s * vocab];
    linalg::matmul_bias_into(model.linalg, &x.data, head, bias, &mut logits, s, d, vocab, pool);
    Ok(logits)
}

/// Attention over head-interleaved projection slabs `q [s, Hq·dh]`,
/// `k`/`v [s, Hkv·dh]` into `o [s, Hq·dh]` (zero-initialized by the
/// caller), honouring the model's kernel choice. Shared by the training
/// forward and the generation prefill; `pool` fans the tiled kernel's
/// `(head, query-tile)` jobs out — pass `None` on a pool worker.
fn attend_slabs(
    model: &Model,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &mut [f32],
    s: usize,
    pool: Option<&ThreadPool>,
) {
    let lay = &model.lay;
    let (dh, hq, hkv) = (lay.d_head, lay.hq, lay.hkv);
    let (dq_cols, dkv_cols) = (hq * dh, hkv * dh);
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let spec = model.spec;
    let cfg = tiled::TileConfig::default().with_linalg(model.linalg);
    match model.kernel {
        Kernel::Tiled => match pool {
            Some(pool) if hq * s.div_ceil(cfg.q_tile) > 1 => {
                tiled::stream_slabs_parallel(q, k, v, o, s, dh, spec, cfg, scale, pool)
            }
            _ => {
                for h in 0..hq {
                    let hk = h / group;
                    tiled::stream_head(
                        q, dq_cols, h * dh, k, dkv_cols, hk * dh, v, o, dq_cols, h * dh, s,
                        dh, spec.for_head(h), cfg, scale,
                    );
                }
            }
        },
        Kernel::Naive => {
            let mut probs = vec![0.0f32; s];
            for h in 0..hq {
                let hk = h / group;
                let rm = spec.for_head(h).resolved();
                for i in 0..s {
                    let (lo, hi) = visible_range(i, s, spec);
                    attn_probs(
                        q, k, i, h, hk, s, dh, dq_cols, dkv_cols, scale, lo, hi, &rm, &mut probs,
                    );
                    let oi = i * dq_cols + h * dh;
                    for j in lo..hi {
                        let p = probs[j - lo];
                        if p == 0.0 {
                            continue;
                        }
                        let vj = &v[j * dkv_cols + hk * dh..][..dh];
                        for (ov, &vv) in o[oi..oi + dh].iter_mut().zip(vj) {
                            *ov += p * vv;
                        }
                    }
                }
            }
        }
    }
}

/// Prefill one prompt: a full forward over `tokens` that additionally
/// writes every layer's K/V projections into the session cache; returns
/// the *last* position's logits `[vocab]`. This is the compute-bound phase
/// where SQA's query-head reduction pays (§3.2) — the cache it leaves
/// behind is what the memory-bound [`decode_step_row`] then streams.
fn prefill_row(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    kv: &mut SessionCache,
    pool: Option<&ThreadPool>,
) -> Result<Vec<f32>> {
    let lay = &model.lay;
    let (s, d, dh, vocab) = (tokens.len(), lay.d_model, lay.d_head, lay.vocab);
    let (dq_cols, dkv_cols) = (lay.hq * dh, lay.hkv * dh);
    let imp = model.linalg;
    let (e_off, _) = lay.embed();
    let mut x = vec![0.0f32; s * d];
    for (i, &t) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d]
            .copy_from_slice(&params[e_off + token_index(t, vocab) * d..][..d]);
    }
    for l in 0..lay.n_layers {
        let q = linalg::matmul(imp, &x, weight_slice(params, lay.wq(l)), s, d, dq_cols, pool);
        let kf = linalg::matmul(imp, &x, weight_slice(params, lay.wk(l)), s, d, dkv_cols, pool);
        let vf = linalg::matmul(imp, &x, weight_slice(params, lay.wv(l)), s, d, dkv_cols, pool);
        kv.write(l, &kf, &vf)?;
        let mut o = vec![0.0f32; s * dq_cols];
        attend_slabs(model, &q, &kf, &vf, &mut o, s, pool);
        let a = linalg::matmul(imp, &o, weight_slice(params, lay.wo(l)), s, dq_cols, d, pool);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
    }
    kv.advance(s)?;
    let head = weight_slice(params, lay.lm_head());
    let bias = weight_slice(params, lay.lm_bias());
    let mut logits = vec![0.0f32; vocab];
    linalg::matmul_bias_into(imp, &x[(s - 1) * d..], head, bias, &mut logits, 1, d, vocab, None);
    Ok(logits)
}

/// Prefill *from* a shared prefix: positions `0..p` are already resident
/// (trie-adopted blocks), so only the suffix `tokens[p..]` runs through
/// [`append_rows`]. This is the "hit → skip prefill compute for the shared
/// span" saving: the shared span costs zero projections, zero attention
/// FLOPs and zero new cache bytes here.
fn prefill_suffix(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    p: usize,
    kv: &mut SessionCache,
    pool: &ThreadPool,
) -> Result<Vec<f32>> {
    ensure!(p < tokens.len(), "shared prefix must leave at least one suffix token");
    debug_assert_eq!(kv.len(), p, "cache length must match the shared prefix");
    append_rows(model, params, &tokens[p..], kv, pool)
}

/// Run `new_tokens` through the model at the session's current length
/// (`p = kv.len()`): embed and project only the new rows, write their K/V,
/// and attend them against the gathered visible prefix through
/// [`decode_attend`]'s chunked multi-row path (`pos0 = p`, `n_new = m`) —
/// exactly the incremental decode math, batched. Returns the *last new*
/// position's logits `[vocab]`. Backs both the trie-hit suffix prefill and
/// [`Backend::prefill_extend`]'s chunked prompt absorption.
fn append_rows(
    model: &Model,
    params: &[f32],
    new_tokens: &[i32],
    kv: &mut SessionCache,
    pool: &ThreadPool,
) -> Result<Vec<f32>> {
    let lay = &model.lay;
    let (d, dh, vocab) = (lay.d_model, lay.d_head, lay.vocab);
    let (dq_cols, dkv_cols) = (lay.hq * dh, lay.hkv * dh);
    let imp = model.linalg;
    let p = kv.len();
    let m = new_tokens.len();
    ensure!(m > 0, "no tokens to append");
    let s = p + m;
    ensure!(
        s <= kv.capacity(),
        "appending {m} tokens overflows the session cache capacity {} ({p} resident)",
        kv.capacity()
    );
    let pool = Some(pool);
    let (e_off, _) = lay.embed();
    let mut x = vec![0.0f32; m * d];
    for (i, &t) in new_tokens.iter().enumerate() {
        x[i * d..(i + 1) * d]
            .copy_from_slice(&params[e_off + token_index(t, vocab) * d..][..d]);
    }
    let mut o = vec![0.0f32; m * dq_cols];
    for l in 0..lay.n_layers {
        let q = linalg::matmul(imp, &x, weight_slice(params, lay.wq(l)), m, d, dq_cols, pool);
        let kf = linalg::matmul(imp, &x, weight_slice(params, lay.wk(l)), m, d, dkv_cols, pool);
        let vf = linalg::matmul(imp, &x, weight_slice(params, lay.wv(l)), m, d, dkv_cols, pool);
        kv.write(l, &kf, &vf)?;
        // Gather the layer's full visible prefix (shared rows + the rows
        // just written) and attend the suffix against it.
        let (kc, vc) = kv.layer_upto(l, s)?;
        o.fill(0.0);
        decode_attend(&q, kc, vc, &mut o, p, m, s, dh, model.spec, imp);
        let a = linalg::matmul(imp, &o, weight_slice(params, lay.wo(l)), m, dq_cols, d, pool);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
    }
    kv.advance(m)?;
    let head = weight_slice(params, lay.lm_head());
    let bias = weight_slice(params, lay.lm_bias());
    let mut logits = vec![0.0f32; vocab];
    linalg::matmul_bias_into(imp, &x[(m - 1) * d..], head, bias, &mut logits, 1, d, vocab, None);
    Ok(logits)
}

/// One incremental decode step: embed `token`, project its single row,
/// append the K/V row to every layer's cache, attend against the whole
/// cache via [`decode_attend`], and return the new position's logits.
///
/// The per-step FLOPs are O(d²) projections plus O(cache_len · Hq · dh)
/// attention — the memory-bound regime where only `Hkv` (the cache width)
/// differentiates the variants. The attention kernel choice does not enter
/// here: decode always runs the incremental streaming kernel; `Kernel`
/// selects the *prefill* lowering.
fn decode_step_row(
    model: &Model,
    params: &[f32],
    token: i32,
    kv: &mut SessionCache,
) -> Result<Vec<f32>> {
    let lay = &model.lay;
    let (d, dh, vocab) = (lay.d_model, lay.d_head, lay.vocab);
    let (dq_cols, dkv_cols) = (lay.hq * dh, lay.hkv * dh);
    let imp = model.linalg;
    let pos = kv.len();
    ensure!(
        pos < kv.capacity(),
        "session at capacity ({pos}/{} tokens)",
        kv.capacity()
    );
    let (e_off, _) = lay.embed();
    let mut x = params[e_off + token_index(token, vocab) * d..][..d].to_vec();
    let mut o = vec![0.0f32; dq_cols];
    for l in 0..lay.n_layers {
        let q = linalg::matmul(imp, &x, weight_slice(params, lay.wq(l)), 1, d, dq_cols, None);
        let kf = linalg::matmul(imp, &x, weight_slice(params, lay.wk(l)), 1, d, dkv_cols, None);
        let vf = linalg::matmul(imp, &x, weight_slice(params, lay.wv(l)), 1, d, dkv_cols, None);
        kv.write(l, &kf, &vf)?;
        let (kc, vc) = kv.layer_upto(l, pos + 1)?;
        decode_attend(&q, kc, vc, &mut o, pos, 1, pos + 1, dh, model.spec, imp);
        let a = linalg::matmul(imp, &o, weight_slice(params, lay.wo(l)), 1, dq_cols, d, None);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
    }
    kv.advance(1)?;
    let head = weight_slice(params, lay.lm_head());
    let bias = weight_slice(params, lay.lm_bias());
    let mut logits = vec![0.0f32; vocab];
    linalg::matmul_bias_into(imp, &x, head, bias, &mut logits, 1, d, vocab, None);
    Ok(logits)
}

/// One row's contribution to the batch gradient.
struct RowGrad {
    loss_sum: f32,
    acc_count: f32,
    grad: Vec<f32>,
}

/// Fused forward + backward for one sequence; returns loss/acc sums and the
/// parameter gradient (already scaled by `inv_n = 1 / (batch * seq)`).
///
/// The forward checkpoints one contiguous activation slab
/// `[n_layers + 1, s, d_model]` (every layer's input plus the final hidden
/// states — a single allocation, no per-layer clones) together with each
/// layer's Q/K/V/O projection slabs and, on the tiled kernel, the per-row
/// attention logsumexp. The backward replays attention through the
/// flash-style streaming kernel ([`backward::backward_tiled_slabs`],
/// driven by those statistics) or the scalar row-loop oracle
/// ([`backward::backward_naive_slabs`]) under `Kernel::Naive`. `pool` fans
/// the attention tiles, backward waves and GEMM row blocks out
/// (single-row steps); pass `None` when already on a pool worker.
fn train_row(
    model: &Model,
    params: &[f32],
    tokens: &[i32],
    targets: &[i32],
    inv_n: f32,
    pool: Option<&ThreadPool>,
) -> Result<RowGrad> {
    let lay = &model.lay;
    let spec = model.spec;
    let (s, d, dh, vocab) = (tokens.len(), lay.d_model, lay.d_head, lay.vocab);
    let (hq, hkv) = (lay.hq, lay.hkv);
    let (dq_cols, dkv_cols) = (hq * dh, hkv * dh);
    let scale = 1.0 / (dh as f32).sqrt();
    let n_layers = lay.n_layers;
    let imp = model.linalg;
    let cfg = tiled::TileConfig::default().with_linalg(imp);

    // ---- forward: one checkpointed activation slab ----------------------
    // acts[l*s*d..] is layer l's input; acts[n_layers*s*d..] the final
    // hidden states the LM head reads.
    let (e_off, _) = lay.embed();
    let mut acts = vec![0.0f32; (n_layers + 1) * s * d];
    for (i, &t) in tokens.iter().enumerate() {
        acts[i * d..(i + 1) * d]
            .copy_from_slice(&params[e_off + token_index(t, vocab) * d..][..d]);
    }
    let mut caches: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> =
        Vec::with_capacity(n_layers);
    // Per-(head, row) logsumexp from the tiled forward — the statistic that
    // lets the streaming backward recompute any probability block without
    // re-running the online-softmax max/normalizer search.
    let mut lses: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (done, rest) = acts.split_at_mut((l + 1) * s * d);
        let x = &done[l * s * d..];
        let x_out = &mut rest[..s * d];
        let q = linalg::matmul(imp, x, weight_slice(params, lay.wq(l)), s, d, dq_cols, pool);
        let k = linalg::matmul(imp, x, weight_slice(params, lay.wk(l)), s, d, dkv_cols, pool);
        let v = linalg::matmul(imp, x, weight_slice(params, lay.wv(l)), s, d, dkv_cols, pool);
        let mut o = vec![0.0f32; s * dq_cols];
        let lse = match model.kernel {
            Kernel::Tiled => {
                let mut lse = vec![0.0f32; hq * s];
                backward::forward_slabs_lse(
                    &q, &k, &v, &mut o, &mut lse, s, dh, spec, cfg, scale, pool,
                );
                lse
            }
            Kernel::Naive => {
                attend_slabs(model, &q, &k, &v, &mut o, s, pool);
                Vec::new() // the scalar backward recomputes its softmaxes
            }
        };
        let a = linalg::matmul(imp, &o, weight_slice(params, lay.wo(l)), s, dq_cols, d, pool);
        for ((xo, &xv), &av) in x_out.iter_mut().zip(x.iter()).zip(&a) {
            *xo = xv + av;
        }
        caches.push((q, k, v, o));
        lses.push(lse);
    }
    let x_top = &acts[n_layers * s * d..];

    // ---- LM head: loss, accuracy, dlogits -> dx and head grads ----------
    // Forward as one GEMM over the whole sequence, backward as two GEMM
    // reductions (xᵀ·dlogits for the head grad, dlogits·headᵀ for dx);
    // only the per-position softmax/loss stays scalar.
    let (h_off, h_len) = lay.lm_head();
    let (b_off, _) = lay.lm_bias();
    let head = &params[h_off..h_off + h_len];
    let bias = &params[b_off..b_off + vocab];
    let mut grad = vec![0.0f32; lay.n_params()];
    let mut dx = vec![0.0f32; s * d];
    let mut loss_sum = 0.0f32;
    let mut acc_count = 0.0f32;
    let mut logits = vec![0.0f32; s * vocab];
    linalg::matmul_bias_into(imp, x_top, head, bias, &mut logits, s, d, vocab, pool);
    let mut dlogits = vec![0.0f32; s * vocab];
    for i in 0..s {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let t = targets[i] as usize;
        let (lse, argmax) = log_sum_exp_argmax(row);
        loss_sum += lse - row[t];
        acc_count += (argmax == t) as u8 as f32;
        let dl = &mut dlogits[i * vocab..(i + 1) * vocab];
        for (dv, &lv) in dl.iter_mut().zip(row) {
            *dv = (lv - lse).exp() * inv_n;
        }
        dl[t] -= inv_n;
        for (gb, &dv) in grad[b_off..b_off + vocab].iter_mut().zip(dl.iter()) {
            *gb += dv;
        }
    }
    linalg::accum_xt_dy(imp, &mut grad[h_off..h_off + h_len], x_top, &dlogits, s, d, vocab);
    linalg::accum_dy_wt(imp, &mut dx, &dlogits, head, s, d, vocab);

    // ---- layers, in reverse ---------------------------------------------
    for l in (0..n_layers).rev() {
        let (q, k, v, o) = &caches[l];
        let x_in = &acts[l * s * d..][..s * d];
        let (wq_o, wq_n) = lay.wq(l);
        let (wk_o, wk_n) = lay.wk(l);
        let (wv_o, wv_n) = lay.wv(l);
        let (wo_o, wo_n) = lay.wo(l);
        // x_out = x_in + o @ wo; dx currently holds d(x_out).
        linalg::accum_xt_dy(imp, &mut grad[wo_o..wo_o + wo_n], o, &dx, s, dq_cols, d);
        let mut dout = vec![0.0f32; s * dq_cols];
        linalg::accum_dy_wt(imp, &mut dout, &dx, &params[wo_o..wo_o + wo_n], s, dq_cols, d);

        // Attention backward through the kernel the forward ran on: the
        // flash-style tile streamer (LSE reuse, blocked micro-GEMMs) or
        // the scalar row-loop oracle.
        let mut dq = vec![0.0f32; s * dq_cols];
        let mut dk = vec![0.0f32; s * dkv_cols];
        let mut dv = vec![0.0f32; s * dkv_cols];
        match model.kernel {
            Kernel::Tiled => backward::backward_tiled_slabs(
                q, k, v, o, &lses[l], &dout, &mut dq, &mut dk, &mut dv, s, dh, spec, cfg,
                scale, pool,
            ),
            Kernel::Naive => backward::backward_naive_slabs(
                q, k, v, &dout, &mut dq, &mut dk, &mut dv, s, dh, spec, scale,
            ),
        }
        linalg::accum_xt_dy(imp, &mut grad[wq_o..wq_o + wq_n], x_in, &dq, s, d, dq_cols);
        linalg::accum_xt_dy(imp, &mut grad[wk_o..wk_o + wk_n], x_in, &dk, s, d, dkv_cols);
        linalg::accum_xt_dy(imp, &mut grad[wv_o..wv_o + wv_n], x_in, &dv, s, d, dkv_cols);
        // d(x_in) = d(x_out) [residual] + projections' input grads.
        linalg::accum_dy_wt(imp, &mut dx, &dq, &params[wq_o..wq_o + wq_n], s, d, dq_cols);
        linalg::accum_dy_wt(imp, &mut dx, &dk, &params[wk_o..wk_o + wk_n], s, d, dkv_cols);
        linalg::accum_dy_wt(imp, &mut dx, &dv, &params[wv_o..wv_o + wv_n], s, d, dkv_cols);
    }

    // ---- embedding scatter ----------------------------------------------
    for (i, &t) in tokens.iter().enumerate() {
        let g = &mut grad[e_off + token_index(t, vocab) * d..][..d];
        for (gv, &dv) in g.iter_mut().zip(&dx[i * d..(i + 1) * d]) {
            *gv += dv;
        }
    }

    Ok(RowGrad {
        loss_sum,
        acc_count,
        grad,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn init_is_seed_deterministic() {
        let b = backend();
        let a1 = b.init_params("tiny", "sqa", 5).unwrap();
        let a2 = b.init_params("tiny", "sqa", 5).unwrap();
        let a3 = b.init_params("tiny", "sqa", 6).unwrap();
        assert_eq!(a1, a2);
        assert_ne!(a1, a3);
        assert_ne!(
            a1,
            b.init_params("tiny", "mha", 5).unwrap(),
            "variants must not share init streams"
        );
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 1).unwrap();
        let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 37 % 2048) as i32).collect();
        let l1 = b.forward("tiny", "sqa", &params, &tokens, 2, 16).unwrap();
        let l2 = b.forward("tiny", "sqa", &params, &tokens, 2, 16).unwrap();
        assert_eq!(l1.len(), 2 * 16 * 2048);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn train_step_loss_matches_eval_on_same_batch() {
        // The fused train step records the loss at the *pre-update* params;
        // eval on the same params/batch must agree. This differentially
        // tests train_row's forward against forward_row/sqa_layer.
        let b = backend();
        let params = b.init_params("tiny", "sqa", 3).unwrap();
        let p = params.len();
        let mut state = vec![0.0f32; 3 * p + 2];
        state[..p].copy_from_slice(&params);
        let (bs, s) = (2usize, 12usize);
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 13 + 7) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t + 1) % 2048).collect();
        let (train_loss, _) = b
            .train_step("tiny", "sqa", &mut state, 1, 1e-3, &tokens, &targets, bs, s)
            .unwrap();
        let (eval_loss, _) = b
            .eval("tiny", "sqa", &params, &tokens, &targets, bs, s)
            .unwrap();
        assert!(
            (train_loss - eval_loss).abs() < 2e-3,
            "train {train_loss} vs eval {eval_loss}"
        );
        // The update must actually move the parameters.
        assert_ne!(&state[..p], &params[..]);
        assert_eq!(state[3 * p], train_loss);
    }

    #[test]
    fn repeated_train_steps_reduce_loss_on_fixed_batch() {
        // Overfitting one batch is the cheapest end-to-end gradient check:
        // loss must fall monotonically-ish and substantially.
        let b = backend();
        let params = b.init_params("tiny", "xsqa", 9).unwrap();
        let p = params.len();
        let mut state = vec![0.0f32; 3 * p + 2];
        state[..p].copy_from_slice(&params);
        let (bs, s) = (2usize, 16usize);
        let tokens: Vec<i32> = (0..bs * s).map(|i| ((i * 31 + 11) % 2048) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t * 7 + 3) % 2048).collect();
        let mut losses = Vec::new();
        for step in 1..=30 {
            let (loss, _) = b
                .train_step("tiny", "xsqa", &mut state, step, 5e-3, &tokens, &targets, bs, s)
                .unwrap();
            assert!(loss.is_finite());
            losses.push(loss);
        }
        assert!(
            losses[29] < losses[0] - 2.0,
            "no overfit on fixed batch: {losses:?}"
        );
    }

    #[test]
    fn forward_impls_agree_and_tiled_is_default() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 2).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i * 97 % 2048) as i32).collect();
        let tiled = b
            .forward_impl("tiled", "tiny", "sqa", &params, &tokens, 1, 16)
            .unwrap();
        // Every lowering — both kernels x all three GEMM impls — must agree.
        for impl_ in [
            "naive",
            "tiled+scalar",
            "naive+scalar",
            "tiled+blocked",
            "tiled+simd",
            "naive+simd",
        ] {
            let other = b
                .forward_impl(impl_, "tiny", "sqa", &params, &tokens, 1, 16)
                .unwrap();
            assert_eq!(tiled.len(), other.len());
            let worst = tiled
                .iter()
                .zip(&other)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(worst < 1e-3, "{impl_} diverges by {worst}");
        }
        // The plain forward entry point runs the default path:
        // tiled kernel + blocked GEMMs.
        let default = b.forward("tiny", "sqa", &params, &tokens, 1, 16).unwrap();
        assert_eq!(default, tiled);
        let explicit = b
            .forward_impl("tiled+blocked", "tiny", "sqa", &params, &tokens, 1, 16)
            .unwrap();
        assert_eq!(default, explicit);
        assert_eq!(
            b.impls(),
            vec!["tiled", "naive", "tiled+scalar", "naive+scalar", "tiled+simd", "naive+simd"]
        );
    }

    #[test]
    fn pattern_impl_strings_select_masks_and_agree_across_kernels() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 4).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i * 97 % 2048) as i32).collect();
        let dense = b.forward("tiny", "sqa", &params, &tokens, 1, 16).unwrap();
        let diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        for pat in ["strided:3", "sink:2:4", "window:5", "dilated:2:3"] {
            let tiled = b
                .forward_impl(&format!("tiled@{pat}"), "tiny", "sqa", &params, &tokens, 1, 16)
                .unwrap();
            let naive = b
                .forward_impl(&format!("naive+scalar@{pat}"), "tiny", "sqa", &params, &tokens, 1, 16)
                .unwrap();
            assert!(diff(&tiled, &naive) < 1e-3, "{pat} diverges by {}", diff(&tiled, &naive));
            assert!(
                diff(&tiled, &dense) > 1e-3,
                "{pat} must actually change the mask"
            );
        }
        // `@dense` is the identity overlay.
        let explicit = b
            .forward_impl("tiled@dense", "tiny", "sqa", &params, &tokens, 1, 16)
            .unwrap();
        assert_eq!(explicit, dense);
        // Degenerate and unknown patterns are rejected up front.
        for bad in ["tiled@strided:0", "tiled@window:0", "tiled@bogus", "tiled@bitmap:999999"] {
            assert!(
                b.forward_impl(bad, "tiny", "sqa", &params, &tokens, 1, 16).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn prefill_impl_pattern_sessions_decode_like_the_pattern_forward() {
        // A session opened with a pattern must mask its cached positions by
        // the same rules as a stateless pattern forward — every decode step.
        let b = backend();
        let params = b.init_params("tiny", "sqa", 12).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| ((i * 53 + 5) % 2048) as i32).collect();
        let vocab = 2048usize;
        let diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        let imp = "tiled@sink:2:4";
        let full = b
            .forward_impl(imp, "tiny", "sqa", &params, &tokens, 1, 12)
            .unwrap();
        let (sid, logits) = b
            .prefill_impl(imp, "tiny", "sqa", &params, &tokens[..4], 32)
            .unwrap();
        assert!(diff(&logits, &full[3 * vocab..4 * vocab]) < 1e-4);
        for i in 4..12 {
            let l = b.decode_step(sid, &params, tokens[i]).unwrap();
            assert!(
                diff(&l, &full[i * vocab..(i + 1) * vocab]) < 1e-4,
                "pattern decode diverges at position {i}"
            );
        }
        assert!(b.close_session(sid));
    }

    #[test]
    fn decode_path_matches_full_forward() {
        // Prefill 4 tokens then decode 8 more; every step's logits must
        // match the corresponding row of a full stateless forward (the
        // exhaustive variant x kernel x linalg grid lives in
        // rust/tests/decode_differential.rs).
        let b = backend();
        let params = b.init_params("tiny", "sqa", 11).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| ((i * 53 + 5) % 2048) as i32).collect();
        let full = b.forward("tiny", "sqa", &params, &tokens, 1, 12).unwrap();
        let vocab = 2048usize;
        let diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        let (sid, logits) = b.prefill("tiny", "sqa", &params, &tokens[..4], 32).unwrap();
        assert!(diff(&logits, &full[3 * vocab..4 * vocab]) < 1e-4);
        for i in 4..12 {
            let l = b.decode_step(sid, &params, tokens[i]).unwrap();
            assert!(
                diff(&l, &full[i * vocab..(i + 1) * vocab]) < 1e-4,
                "step at position {i} diverges"
            );
        }
        // tiny/sqa: 2 layers, Hkv=2, dh=16 -> 2*2*12*32*4 bytes live.
        let stats = b.session_stats(sid).unwrap();
        assert_eq!(stats.len, 12);
        assert_eq!(stats.capacity, 32);
        assert_eq!(stats.kv_bytes, 2 * 2 * 12 * 32 * 4);
        assert_eq!(stats.alloc_bytes, 2 * 2 * 32 * 32 * 4);
        assert!(b.close_session(sid));
        assert!(!b.close_session(sid), "close is not idempotent-true");
        assert!(b.decode_step(sid, &params, 1).is_err(), "closed session");
        assert!(b.session_stats(sid).is_err());
    }

    #[test]
    fn half_precision_kv_sessions_decode_near_f32_at_half_the_bytes() {
        // The same prefill + decode under f16/bf16 cache storage: logits
        // stay within the narrowing error of the f32 session while every
        // session byte account exactly halves (the deeper round-trip
        // mirror check lives in rust/tests/decode_differential.rs).
        let f32_backend = backend();
        let params = f32_backend.init_params("tiny", "sqa", 9).unwrap();
        let tokens: Vec<i32> = (0..10).map(|i| ((i * 53 + 5) % 2048) as i32).collect();
        let (rid, _) = f32_backend
            .prefill("tiny", "sqa", &params, &tokens[..4], 32)
            .unwrap();
        let mut ref_logits = Vec::new();
        for &t in &tokens[4..] {
            ref_logits.push(f32_backend.decode_step(rid, &params, t).unwrap());
        }
        let ref_stats = f32_backend.session_stats(rid).unwrap();
        for (dtype, tol) in [(KvDtype::F16, 2e-2f32), (KvDtype::Bf16, 1e-1f32)] {
            let b = backend().with_kv_dtype(dtype);
            let (sid, _) = b.prefill("tiny", "sqa", &params, &tokens[..4], 32).unwrap();
            for (i, &t) in tokens[4..].iter().enumerate() {
                let l = b.decode_step(sid, &params, t).unwrap();
                let worst = l
                    .iter()
                    .zip(&ref_logits[i])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(worst < tol, "{} step {i} off by {worst}", dtype.name());
            }
            let stats = b.session_stats(sid).unwrap();
            assert_eq!(stats.len, ref_stats.len);
            assert_eq!(stats.kv_bytes * 2, ref_stats.kv_bytes);
            assert_eq!(stats.alloc_bytes * 2, ref_stats.alloc_bytes);
            assert!(b.close_session(sid));
        }
        assert!(f32_backend.close_session(rid));
    }

    #[test]
    fn prefill_rejects_bad_sessions() {
        let b = backend();
        let params = b.init_params("tiny", "sqa", 1).unwrap();
        assert!(b.supports_decode());
        // Prompt longer than the cache.
        let long: Vec<i32> = vec![7; 9];
        assert!(b.prefill("tiny", "sqa", &params, &long, 8).is_err());
        // Empty prompt / zero capacity.
        assert!(b.prefill("tiny", "sqa", &params, &[], 8).is_err());
        assert!(b.prefill("tiny", "sqa", &params, &[1], 0).is_err());
        // Unknown session ids.
        assert!(b.decode_step(999, &params, 1).is_err());
        assert!(b.session_stats(999).is_err());
        assert!(!b.close_session(999));
    }

    #[test]
    fn decode_step_at_capacity_fails_but_keeps_session() {
        let b = backend();
        let params = b.init_params("tiny", "gqa", 2).unwrap();
        let (sid, _) = b.prefill("tiny", "gqa", &params, &[1, 2, 3], 4).unwrap();
        b.decode_step(sid, &params, 4).unwrap(); // fills slot 4/4
        let err = b.decode_step(sid, &params, 5).unwrap_err();
        assert!(err.to_string().contains("capacity"), "{err:#}");
        // The failed step must not have corrupted or dropped the session.
        let stats = b.session_stats(sid).unwrap();
        assert_eq!((stats.len, stats.capacity), (4, 4));
        assert!(b.close_session(sid));
    }

    #[test]
    fn geometry_lookups() {
        let b = backend();
        assert_eq!(b.fwd_buckets("tiny", "sqa"), vec![64, 128, 256]);
        assert_eq!(b.fwd_batch("tiny", "sqa", 128).unwrap(), 8);
        assert!(b.fwd_batch("tiny", "sqa", 100).is_err());
        assert_eq!(b.train_shape("tiny", "sqa").unwrap(), (4, 64));
        assert!(b.train_shape("bench", "mha").is_err());
        assert!(b.fwd_buckets("dense_sm", "sqa").is_empty());
        assert!(b.forward_impl("pallas", "tiny", "sqa", &[], &[], 1, 1).is_err());
    }

    // ---- paged KV cache -------------------------------------------------

    fn paged_cfg(block_len: usize, pool_blocks: usize, dir: Option<&std::path::Path>) -> PagedConfig {
        PagedConfig {
            block_len,
            pool_blocks,
            spill_dir: dir.map(|d| d.to_path_buf()),
        }
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sqa-native-{}-{name}", std::process::id()))
    }

    #[test]
    fn paged_sessions_decode_identically_to_contiguous() {
        let contig = backend();
        let paged = backend().with_paged(Some(paged_cfg(3, 64, None)));
        assert!(!contig.paged_enabled() && paged.paged_enabled());
        let params = contig.init_params("tiny", "sqa", 21).unwrap();
        let tokens: Vec<i32> = (0..10).map(|i| ((i * 41 + 3) % 2048) as i32).collect();
        // A cold paged prefill runs the exact same compute path as the
        // contiguous one (write-through is the only difference), so the
        // logits must agree bitwise — prefill and every decode step.
        let (cid, cl) = contig.prefill("tiny", "sqa", &params, &tokens[..5], 16).unwrap();
        let (pid, pl) = paged.prefill("tiny", "sqa", &params, &tokens[..5], 16).unwrap();
        assert_eq!(cl, pl);
        for &t in &tokens[5..] {
            assert_eq!(
                contig.decode_step(cid, &params, t).unwrap(),
                paged.decode_step(pid, &params, t).unwrap()
            );
        }
        let cs = contig.session_stats(cid).unwrap();
        let ps = paged.session_stats(pid).unwrap();
        assert_eq!((cs.len, cs.kv_bytes), (ps.len, ps.kv_bytes));
        assert!(contig.kv_pool_stats().is_none());
        let pool = paged.kv_pool_stats().unwrap();
        assert_eq!(pool.blocks_in_use(), 10usize.div_ceil(3));
        assert_eq!(pool.block_len, 3);
        assert!(contig.close_session(cid) && paged.close_session(pid));
    }

    #[test]
    fn shared_prefixes_hit_the_trie_and_match_stateless() {
        let b = backend().with_paged(Some(paged_cfg(4, 64, None)));
        let params = b.init_params("tiny", "sqa", 5).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| ((i * 53 + 5) % 2048) as i32).collect();
        let full = b.forward("tiny", "sqa", &params, &tokens, 1, 12).unwrap();
        let vocab = 2048usize;
        let diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        let (s1, l1) = b.prefill("tiny", "sqa", &params, &tokens, 16).unwrap();
        assert_eq!(b.kv_pool_stats().unwrap().prefix_hits, 0, "cold trie");
        let (s2, l2) = b.prefill("tiny", "sqa", &params, &tokens, 16).unwrap();
        let ps = b.kv_pool_stats().unwrap();
        assert_eq!(ps.prefix_hits, 1);
        // 12 tokens, block_len 4, span capped at len-1: two exact chunks
        // (8) plus a 3-token partial match against the third = 11 shared.
        assert_eq!(ps.prefix_hit_tokens, 11);
        assert!(ps.prefix_hit_rate() > 0.0);
        // Both sessions' prefill logits pin to the stateless forward; the
        // hit session recomputed only 1 of 12 positions to get there.
        assert!(diff(&l1, &full[11 * vocab..]) < 1e-4);
        assert!(diff(&l2, &full[11 * vocab..]) < 1e-4);
        // Suffix-divergent third prompt: shares, COWs, stays correct.
        let mut t3 = tokens.clone();
        t3[9] = 1999;
        t3[10] = 1998;
        t3[11] = 1997;
        let full3 = b.forward("tiny", "sqa", &params, &t3, 1, 12).unwrap();
        let (s3, l3) = b.prefill("tiny", "sqa", &params, &t3, 16).unwrap();
        assert!(diff(&l3, &full3[11 * vocab..]) < 1e-4);
        let ps = b.kv_pool_stats().unwrap();
        assert_eq!(ps.prefix_hits, 2);
        assert!(ps.cow_splits >= 1, "divergence inside a shared block COWs");
        for sid in [s1, s2, s3] {
            assert!(b.close_session(sid));
        }
    }

    #[test]
    fn spill_refuses_sessions_mid_step_then_restores() {
        let dir = tmp_dir("busy");
        let b = backend().with_paged(Some(paged_cfg(4, 32, Some(&dir))));
        let params = b.init_params("tiny", "sqa", 7).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| ((i * 53 + 5) % 2048) as i32).collect();
        let full = b.forward("tiny", "sqa", &params, &tokens, 1, 12).unwrap();
        let (sid, _) = b.prefill("tiny", "sqa", &params, &tokens[..5], 16).unwrap();
        // Simulate a step in flight: the slot holds a Busy marker, so the
        // eviction policy must refuse to touch this session.
        let held = b.sessions.take(sid).unwrap();
        let e = b.spill_session(sid).unwrap_err().to_string();
        assert!(e.contains("mid-step"), "got: {e}");
        b.sessions.put_back(sid, held);
        // Idle now: the exclusive (unpublished tail) block spills...
        assert!(b.spill_session(sid).unwrap() >= 1);
        assert_eq!(b.spill_session(sid).unwrap(), 0, "spill is idempotent");
        assert!(b.kv_pool_stats().unwrap().blocks_spilled >= 1);
        // ...and the next decode step restores transparently and still
        // matches the stateless forward.
        let diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        let vocab = 2048usize;
        for i in 5..8 {
            let l = b.decode_step(sid, &params, tokens[i]).unwrap();
            assert!(diff(&l, &full[i * vocab..(i + 1) * vocab]) < 1e-4, "step {i}");
        }
        assert_eq!(b.kv_pool_stats().unwrap().blocks_spilled, 0);
        assert!(b.close_session(sid));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spilled_and_resident_twins_decode_identically() {
        let dir = tmp_dir("twin");
        let mk = || backend().with_paged(Some(paged_cfg(4, 32, Some(&dir))));
        let (a, b) = (mk(), mk());
        let params = a.init_params("tiny", "sqa", 8).unwrap();
        let tokens: Vec<i32> = (0..10).map(|i| ((i * 29 + 1) % 2048) as i32).collect();
        let (ida, _) = a.prefill("tiny", "sqa", &params, &tokens[..6], 16).unwrap();
        let (idb, _) = b.prefill("tiny", "sqa", &params, &tokens[..6], 16).unwrap();
        b.spill_session(idb).unwrap();
        // evict → restore → decode must be bit-identical to never-evicted.
        for &t in &tokens[6..] {
            assert_eq!(
                a.decode_step(ida, &params, t).unwrap(),
                b.decode_step(idb, &params, t).unwrap()
            );
        }
        assert!(a.close_session(ida) && b.close_session(idb));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pool_pressure_evicts_idle_sessions_and_steps_proceed() {
        let dir = tmp_dir("evict");
        let b = backend().with_paged(Some(paged_cfg(2, 4, Some(&dir))));
        let params = b.init_params("tiny", "sqa", 3).unwrap();
        let (ida, _) = b.prefill("tiny", "sqa", &params, &[1, 2, 3, 4], 8).unwrap();
        let (idb, _) = b.prefill("tiny", "sqa", &params, &[9, 8, 7, 6], 8).unwrap();
        assert_eq!(b.kv_pool_stats().unwrap().blocks_free, 0, "pool is full");
        // B's next step needs a 5th block: trie-only references are
        // reclaimed, idle A is spilled LRU-first, and the step proceeds.
        let l = b.decode_step(idb, &params, 5).unwrap();
        assert!(l.iter().all(|x| x.is_finite()));
        let ps = b.kv_pool_stats().unwrap();
        assert!(ps.evictions >= 1, "idle session was spilled: {ps:?}");
        // A comes back transparently (possibly evicting B in turn).
        let l = b.decode_step(ida, &params, 5).unwrap();
        assert!(l.iter().all(|x| x.is_finite()));
        assert!(b.kv_pool_stats().unwrap().restores >= 1);
        assert_eq!(b.session_stats(ida).unwrap().len, 5);
        assert!(b.close_session(ida) && b.close_session(idb));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
