//! Built-in model catalog for the native backend.
//!
//! The PJRT backend reads model geometry from `artifacts/manifest.json`;
//! the native backend needs no artifacts, so the same four families
//! (`tiny`, `dense_sm`, `moe_sm`, `bench`) and the paper's variant zoo are
//! defined here directly, CPU-scaled like `python/compile/configs.py`:
//!
//! | family   | vocab | d_model | layers | H  | train (b, s) | fwd (b, seqs) |
//! |----------|-------|---------|--------|----|--------------|---------------|
//! | tiny     | 2048  | 128     | 2      | 8  | (4, 64)      | (8, 64..256)  |
//! | dense_sm | 4096  | 256     | 8      | 16 | (2, 128)     | —             |
//! | moe_sm   | 2048  | 128     | 6      | 8  | (4, 128)     | —             |
//! | bench    | 1024  | 256     | 4      | 16 | —            | (1, 512..16k) |
//!
//! [`Layout`] is the native parameter layout — the flat-f32-vector contract
//! every backend shares (`[embed | per-layer wq wk wv wo | lm_head |
//! lm_bias]`), mirrored into [`ParamSpec`] entries so checkpoints and
//! per-tensor inspection work identically to the manifest path.

use crate::config::{ModelDims, VariantCfg};
use crate::runtime::manifest::{FamilyEntry, ParamSpec, VariantEntry};
use std::collections::BTreeMap;

/// Sliding-window width of the SWA variants (paper's CPU-scaled choice).
pub const SWA_WINDOW: usize = 128;

/// Fixed entry-point shapes of a native family.
#[derive(Debug, Clone)]
pub struct Geometry {
    /// Max rows a fwd batch is merged to (serving); 0 = no fwd entry point.
    pub fwd_batch: usize,
    /// Sequence buckets compiled for serving/sweeps.
    pub fwd_seqs: Vec<usize>,
    /// Training (batch, seq); None = no train entry point.
    pub train: Option<(usize, usize)>,
}

/// Offsets of every tensor inside the flat parameter vector.
///
/// The native reference model is deliberately small: token embedding, then
/// `n_layers` residual SQA attention blocks (no MLP — attention is the
/// subject under test), then an untied LM head with bias.
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub hq: usize,
    pub hkv: usize,
    pub d_head: usize,
}

impl Layout {
    pub fn new(dims: &ModelDims, cfg: &VariantCfg) -> Self {
        Self {
            vocab: dims.vocab,
            d_model: dims.d_model,
            n_layers: dims.n_layers,
            hq: cfg.hq,
            hkv: cfg.hkv,
            d_head: dims.d_head,
        }
    }

    fn wq_len(&self) -> usize {
        self.d_model * self.hq * self.d_head
    }

    fn wkv_len(&self) -> usize {
        self.d_model * self.hkv * self.d_head
    }

    fn wo_len(&self) -> usize {
        self.hq * self.d_head * self.d_model
    }

    fn layer_len(&self) -> usize {
        self.wq_len() + 2 * self.wkv_len() + self.wo_len()
    }

    fn layer_base(&self, l: usize) -> usize {
        self.vocab * self.d_model + l * self.layer_len()
    }

    /// `embed [vocab, d_model]` — offset and length.
    pub fn embed(&self) -> (usize, usize) {
        (0, self.vocab * self.d_model)
    }

    /// `wq [d_model, hq*d_head]` of layer `l`.
    pub fn wq(&self, l: usize) -> (usize, usize) {
        (self.layer_base(l), self.wq_len())
    }

    /// `wk [d_model, hkv*d_head]` of layer `l`.
    pub fn wk(&self, l: usize) -> (usize, usize) {
        (self.layer_base(l) + self.wq_len(), self.wkv_len())
    }

    /// `wv [d_model, hkv*d_head]` of layer `l`.
    pub fn wv(&self, l: usize) -> (usize, usize) {
        (self.layer_base(l) + self.wq_len() + self.wkv_len(), self.wkv_len())
    }

    /// `wo [hq*d_head, d_model]` of layer `l`.
    pub fn wo(&self, l: usize) -> (usize, usize) {
        (
            self.layer_base(l) + self.wq_len() + 2 * self.wkv_len(),
            self.wo_len(),
        )
    }

    /// `lm_head [d_model, vocab]`.
    pub fn lm_head(&self) -> (usize, usize) {
        (self.layer_base(self.n_layers), self.d_model * self.vocab)
    }

    /// `lm_bias [vocab]`.
    pub fn lm_bias(&self) -> (usize, usize) {
        let (off, len) = self.lm_head();
        (off + len, self.vocab)
    }

    pub fn n_params(&self) -> usize {
        let (off, len) = self.lm_bias();
        off + len
    }

    /// Named parameter table (the manifest-compatible view of this layout).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let mut specs = Vec::new();
        let mut push = |name: String, shape: Vec<usize>, (offset, len): (usize, usize)| {
            debug_assert_eq!(shape.iter().product::<usize>(), len);
            specs.push(ParamSpec { name, shape, offset });
        };
        push("embed".into(), vec![self.vocab, self.d_model], self.embed());
        for l in 0..self.n_layers {
            let dq = self.hq * self.d_head;
            let dkv = self.hkv * self.d_head;
            push(format!("l{l}.wq"), vec![self.d_model, dq], self.wq(l));
            push(format!("l{l}.wk"), vec![self.d_model, dkv], self.wk(l));
            push(format!("l{l}.wv"), vec![self.d_model, dkv], self.wv(l));
            push(format!("l{l}.wo"), vec![dq, self.d_model], self.wo(l));
        }
        push("lm_head".into(), vec![self.d_model, self.vocab], self.lm_head());
        push("lm_bias".into(), vec![self.vocab], self.lm_bias());
        specs
    }
}

/// The paper's named variants for an MHA head budget `h` (Tables 1-3):
/// GQA keeps H query heads with H/4 kv heads, SQA halves the query heads,
/// sSQA/xSQA are the symmetric reductions, SWA adds a sliding window.
fn variant_zoo(h: usize) -> Vec<(&'static str, VariantCfg)> {
    let q = |f: usize| (h / f).max(1);
    let mut zoo = vec![
        ("mha", VariantCfg { hq: h, hkv: h, window: None }),
        ("gqa", VariantCfg { hq: h, hkv: q(4), window: None }),
        ("mqa", VariantCfg { hq: h, hkv: 1, window: None }),
        ("sqa", VariantCfg { hq: q(2), hkv: q(4), window: None }),
        ("ssqa", VariantCfg { hq: q(2), hkv: q(2), window: None }),
        ("xsqa", VariantCfg { hq: q(4), hkv: q(4), window: None }),
        ("xsmqa", VariantCfg { hq: q(4), hkv: 1, window: None }),
        ("swa", VariantCfg { hq: h, hkv: h, window: Some(SWA_WINDOW) }),
        ("swsqa", VariantCfg { hq: q(2), hkv: q(4), window: Some(SWA_WINDOW) }),
    ];
    // §6 future-work variant: light SQA (25% query reduction).
    if h % 4 == 0 && (3 * h / 4) % q(4) == 0 {
        zoo.push(("lsqa", VariantCfg { hq: 3 * h / 4, hkv: q(4), window: None }));
    }
    zoo
}

fn family(dims: ModelDims) -> FamilyEntry {
    let mut variants = BTreeMap::new();
    for (name, cfg) in variant_zoo(dims.h_total) {
        let layout = Layout::new(&dims, &cfg);
        variants.insert(
            name.to_string(),
            VariantEntry {
                cfg,
                n_params: layout.n_params(),
                params: layout.param_specs(),
            },
        );
    }
    FamilyEntry {
        dims,
        causal: true,
        variants,
    }
}

/// Build the native catalog: families plus their entry-point geometry.
pub fn builtin() -> (BTreeMap<String, FamilyEntry>, BTreeMap<String, Geometry>) {
    let mut families = BTreeMap::new();
    let mut geoms = BTreeMap::new();

    families.insert(
        "tiny".to_string(),
        family(ModelDims {
            vocab: 2048,
            d_model: 128,
            n_layers: 2,
            h_total: 8,
            d_head: 16,
            d_ff: 352,
            n_experts: 0,
        }),
    );
    geoms.insert(
        "tiny".to_string(),
        Geometry {
            fwd_batch: 8,
            fwd_seqs: vec![64, 128, 256],
            train: Some((4, 64)),
        },
    );

    families.insert(
        "dense_sm".to_string(),
        family(ModelDims {
            vocab: 4096,
            d_model: 256,
            n_layers: 8,
            h_total: 16,
            d_head: 16,
            d_ff: 704,
            n_experts: 0,
        }),
    );
    geoms.insert(
        "dense_sm".to_string(),
        Geometry {
            fwd_batch: 0,
            fwd_seqs: vec![],
            train: Some((2, 128)),
        },
    );

    families.insert(
        "moe_sm".to_string(),
        family(ModelDims {
            vocab: 2048,
            d_model: 128,
            n_layers: 6,
            h_total: 8,
            d_head: 16,
            d_ff: 352,
            n_experts: 4,
        }),
    );
    geoms.insert(
        "moe_sm".to_string(),
        Geometry {
            fwd_batch: 0,
            fwd_seqs: vec![],
            train: Some((4, 128)),
        },
    );

    families.insert(
        "bench".to_string(),
        family(ModelDims {
            vocab: 1024,
            d_model: 256,
            n_layers: 4,
            h_total: 16,
            d_head: 16,
            d_ff: 704,
            n_experts: 0,
        }),
    );
    geoms.insert(
        "bench".to_string(),
        Geometry {
            fwd_batch: 1,
            // 8k/16k buckets serve the long-sequence perf trajectory
            // (BENCH_attention.json): the tiled kernel + blocked GEMMs
            // reach them easily; sweeps cap via --max-seq where needed.
            fwd_seqs: vec![512, 1024, 2048, 4096, 8192, 16384],
            train: None,
        },
    );

    (families, geoms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets_are_contiguous() {
        let dims = ModelDims {
            vocab: 64,
            d_model: 16,
            n_layers: 3,
            h_total: 4,
            d_head: 4,
            d_ff: 48,
            n_experts: 0,
        };
        let cfg = VariantCfg { hq: 2, hkv: 1, window: None };
        let lay = Layout::new(&dims, &cfg);
        let specs = lay.param_specs();
        let mut expect = 0usize;
        for s in &specs {
            assert_eq!(s.offset, expect, "{} misplaced", s.name);
            expect += s.shape.iter().product::<usize>();
        }
        assert_eq!(expect, lay.n_params());
    }

    #[test]
    fn builtin_catalog_is_consistent() {
        let (families, geoms) = builtin();
        for fam in ["tiny", "dense_sm", "moe_sm", "bench"] {
            let f = families.get(fam).expect(fam);
            assert!(geoms.contains_key(fam));
            assert_eq!(f.dims.d_model, f.dims.h_total * f.dims.d_head);
            for (vname, v) in &f.variants {
                v.cfg.validate().unwrap_or_else(|e| panic!("{fam}/{vname}: {e}"));
                let sum: usize = v.params.iter().map(|p| p.size()).sum();
                assert_eq!(sum, v.n_params, "{fam}/{vname}");
            }
        }
        // The paper's head counts at H = 16 (Table 1).
        let dense = &families["dense_sm"].variants;
        assert_eq!((dense["sqa"].cfg.hq, dense["sqa"].cfg.hkv), (8, 4));
        assert_eq!((dense["xsqa"].cfg.hq, dense["xsqa"].cfg.hkv), (4, 4));
        assert_eq!((dense["mqa"].cfg.hq, dense["mqa"].cfg.hkv), (16, 1));
        assert_eq!(dense["swa"].cfg.window, Some(SWA_WINDOW));
    }

    #[test]
    fn zoo_covers_every_table() {
        let (families, _) = builtin();
        for v in ["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa"] {
            assert!(families["dense_sm"].variants.contains_key(v), "{v}");
        }
        for v in ["gqa", "mqa", "sqa", "ssqa", "xsqa"] {
            assert!(families["moe_sm"].variants.contains_key(v), "{v}");
        }
        for v in ["xsqa", "sqa", "ssqa", "swa", "mqa", "gqa", "mha"] {
            assert!(families["bench"].variants.contains_key(v), "{v}");
        }
    }
}
