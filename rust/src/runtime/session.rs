//! Per-session KV caches for incremental decode.
//!
//! One [`KvCache`] backs one generation session: a contiguous per-layer
//! append buffer of projected key/value rows in the native backend's
//! head-interleaved `[capacity, Hkv·d_head]` layout. Sizing follows the
//! variant's `Hkv` — this is where the paper's §5 decode axis becomes
//! *observable* instead of simulated: an sSQA session (`Hkv = H/2`)
//! allocates and streams twice the bytes of a GQA/xSQA session
//! (`Hkv = H/4`) at the same context length, and
//! [`KvCache::live_bytes`] is exactly the cache traffic term of
//! [`crate::flops::decode::decode_step`].
//!
//! Write protocol (mirrors how a forward step visits layers): each layer
//! writes its fresh rows at the *same* base slot via [`KvCache::write`],
//! then the step commits once with [`KvCache::advance`]. Until `advance`,
//! readers that pass an explicit row count ([`KvCache::layer_upto`]) can
//! already see the fresh rows — the decode kernel attends `len + 1` rows
//! while the step that produced row `len` is still in flight across layers.
//!
//! Storage precision is a per-session choice ([`KvDtype`]): rows are
//! narrowed to f16/bf16 bits on write and widened back to f32 on read, so
//! the attention kernels never see anything but f32 while the *resident*
//! cache — and every byte-accounting method, and therefore the §5.2
//! roofline traffic term — shrinks by [`KvDtype::bytes`]. The conversions
//! are hand-rolled bit manipulation ([`f32_to_f16_bits`] and friends,
//! round-to-nearest-even) because the offline image has no `half` crate.

use crate::util::sync::{self, AtomicU64, Mutex, Ordering};
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

// ---- half-precision conversions ---------------------------------------------

/// Narrow an f32 to IEEE-754 binary16 bits, round-to-nearest-even.
///
/// Overflow (|x| ≥ 65520) saturates to ±inf like hardware `vcvtps2ph`;
/// NaN payload keeps its top 10 mantissa bits and is always quieted so it
/// survives the round trip as a NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf or NaN (quieted, top payload bits preserved).
        let payload = if abs > 0x7f80_0000 {
            0x0200 | ((abs >> 13) & 0x03ff) as u16
        } else {
            0
        };
        return sign | 0x7c00 | payload;
    }
    let exp = (abs >> 23) as i32 - 127 + 15; // re-bias 8-bit -> 5-bit
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // Subnormal (or zero) in f16: shift the implicit-1 mantissa down.
        if exp < -10 {
            return sign; // underflows to ±0
        }
        let man = (abs & 0x007f_ffff) | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let mid = 1u32 << (shift - 1);
        let up = rem > mid || (rem == mid && half & 1 == 1);
        return sign | (half + up as u32) as u16;
    }
    let man = abs & 0x007f_ffff;
    let half = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let up = rem > 0x1000 || (rem == 0x1000 && half & 1 == 1);
    // A mantissa carry bumps the exponent; carrying out of exp 30 lands
    // exactly on the inf encoding, which is the correct rounded result.
    sign | (half + up as u32) as u16
}

/// Widen IEEE-754 binary16 bits back to f32 (exact — every f16 value is
/// representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // ±0 or subnormal: the value is exactly man * 2^-24.
        let mag = man as f32 * f32::from_bits((127 - 24) << 23);
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp as u32 + 127 - 15) << 23) | (man << 13))
}

/// Narrow an f32 to bfloat16 bits (truncated-exponent format),
/// round-to-nearest-even on the dropped 16 mantissa bits.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep sign + top payload bits, force a non-zero mantissa so the
        // NaN can't collapse to an inf encoding.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round-to-nearest-even in one add: half-ulp plus the parity bit.
    // Finite overflow carries into the inf encoding, the correct result.
    (bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) >> 16) as u16
}

/// Widen bfloat16 bits back to f32 (exact — bf16 is f32's top half).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Element type of a session's resident K/V rows.
///
/// The decode kernels always compute in f32; this only selects what the
/// cache *stores* (and therefore what a step streams — the §5.2 traffic
/// term scales by [`KvDtype::bytes`]). `F16` keeps ~11 bits of mantissa
/// but saturates beyond ±65504; `Bf16` keeps f32's full exponent range at
/// ~8 bits of mantissa — both halve the cache against `F32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    F16,
    Bf16,
}

impl KvDtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Self::F32),
            "f16" => Ok(Self::F16),
            "bf16" => Ok(Self::Bf16),
            other => bail!("unknown kv dtype {other:?} (f32|f16|bf16)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
            Self::Bf16 => "bf16",
        }
    }

    /// Bytes per cached element — the factor every byte-accounting method
    /// and the decode roofline's cache term scale by.
    pub fn bytes(self) -> usize {
        match self {
            Self::F32 => 4,
            Self::F16 | Self::Bf16 => 2,
        }
    }

    /// `SQA_KV_DTYPE` env (f32 unless told otherwise).
    pub fn from_env() -> Self {
        match std::env::var("SQA_KV_DTYPE").ok().as_deref() {
            Some(s) if !s.is_empty() => {
                Self::parse(s).unwrap_or_else(|e| panic!("SQA_KV_DTYPE: {e:#}"))
            }
            _ => Self::default(),
        }
    }

    /// Narrow one element to this dtype's stored bits (f32 rows are
    /// stored verbatim and never take this path).
    fn narrow(self, x: f32) -> u16 {
        match self {
            Self::F32 => unreachable!("f32 rows are stored verbatim"),
            Self::F16 => f32_to_f16_bits(x),
            Self::Bf16 => f32_to_bf16_bits(x),
        }
    }

    /// Widen stored bits back to f32.
    fn widen(self, bits: u16) -> f32 {
        match self {
            Self::F32 => unreachable!("f32 rows are stored verbatim"),
            Self::F16 => f16_bits_to_f32(bits),
            Self::Bf16 => bf16_bits_to_f32(bits),
        }
    }
}

/// Per-layer K/V slabs at the cache's element type. `F32` rows read back
/// as zero-copy slab slices; `Half` rows (f16 *or* bf16 bits — the
/// [`KvCache::dtype`] tag disambiguates) are narrowed on write and widened
/// into the per-cache scratch slabs on read.
#[derive(Debug, Clone)]
enum Store {
    F32 {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Half {
        k: Vec<Vec<u16>>,
        v: Vec<Vec<u16>>,
        /// Widen targets for [`KvCache::layer_upto`] — one `[capacity, dkv]`
        /// f32 slab per direction, reused across layers and steps.
        wide_k: Vec<f32>,
        wide_v: Vec<f32>,
    },
}

/// Contiguous per-layer K/V append buffers for one generation session.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Per-layer `[capacity, dkv]` K/V slabs (flat, row-major).
    store: Store,
    dtype: KvDtype,
    layers: usize,
    /// Committed token rows (every layer has this many valid rows).
    len: usize,
    capacity: usize,
    /// Row width: `Hkv * d_head`.
    dkv: usize,
}

impl KvCache {
    /// Full-precision cache (the historical default).
    pub fn new(n_layers: usize, capacity: usize, dkv: usize) -> Self {
        Self::new_with_dtype(n_layers, capacity, dkv, KvDtype::F32)
    }

    /// Cache whose resident rows are stored at `dtype`: narrowed on write,
    /// widened back to f32 on read. An f16/bf16 session halves both the
    /// footprint and the per-step streamed bytes against f32 at the same
    /// geometry — the decode-side lever the SQA paper's §5 trade-off
    /// composes with (it shifts *every* variant's cache down 2x without
    /// touching the Hkv ratios between them).
    pub fn new_with_dtype(n_layers: usize, capacity: usize, dkv: usize, dtype: KvDtype) -> Self {
        assert!(n_layers > 0 && capacity > 0 && dkv > 0, "empty cache geometry");
        let store = match dtype {
            KvDtype::F32 => Store::F32 {
                k: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
                v: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
            },
            KvDtype::F16 | KvDtype::Bf16 => Store::Half {
                k: (0..n_layers).map(|_| vec![0; capacity * dkv]).collect(),
                v: (0..n_layers).map(|_| vec![0; capacity * dkv]).collect(),
                wide_k: vec![0.0; capacity * dkv],
                wide_v: vec![0.0; capacity * dkv],
            },
        };
        Self {
            store,
            dtype,
            layers: n_layers,
            len: 0,
            capacity,
            dkv,
        }
    }

    /// Committed token rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum token rows (prompt + generated) this session can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rows still free.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    pub fn n_layers(&self) -> usize {
        self.layers
    }

    /// Row width (`Hkv * d_head`).
    pub fn dkv(&self) -> usize {
        self.dkv
    }

    /// Element type the resident rows are stored at.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Write `n` fresh K/V rows for layer `l` at slots `[len, len + n)`
    /// (uncommitted until [`KvCache::advance`]). `k_rows`/`v_rows` are
    /// `[n, dkv]` head-interleaved slabs, `n` inferred from their length.
    pub fn write(&mut self, l: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        ensure!(l < self.layers, "layer {l} out of range ({})", self.layers);
        ensure!(
            k_rows.len() == v_rows.len() && !k_rows.is_empty() && k_rows.len() % self.dkv == 0,
            "kv rows must be equal non-empty multiples of dkv={} (got {}/{})",
            self.dkv,
            k_rows.len(),
            v_rows.len()
        );
        let n = k_rows.len() / self.dkv;
        ensure!(
            self.len + n <= self.capacity,
            "session at capacity: {} cached + {n} new > {}",
            self.len,
            self.capacity
        );
        let at = self.len * self.dkv;
        match &mut self.store {
            Store::F32 { k, v } => {
                k[l][at..at + k_rows.len()].copy_from_slice(k_rows);
                v[l][at..at + v_rows.len()].copy_from_slice(v_rows);
            }
            Store::Half { k, v, .. } => {
                let dt = self.dtype;
                for (dst, &x) in k[l][at..at + k_rows.len()].iter_mut().zip(k_rows) {
                    *dst = dt.narrow(x);
                }
                for (dst, &x) in v[l][at..at + v_rows.len()].iter_mut().zip(v_rows) {
                    *dst = dt.narrow(x);
                }
            }
        }
        Ok(())
    }

    /// Commit `n` rows written to every layer.
    pub fn advance(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.len + n <= self.capacity,
            "advance past capacity: {} + {n} > {}",
            self.len,
            self.capacity
        );
        self.len += n;
        Ok(())
    }

    /// Layer `l`'s first `rows` K/V rows as f32 (may exceed `len` by the
    /// uncommitted rows a step just wrote).
    ///
    /// Zero-copy for f32 caches; half caches widen into the per-cache
    /// scratch slabs, so the returned slices borrow `&mut self` and the
    /// next `layer_upto` call overwrites them — read one layer at a time,
    /// exactly the decode step's access pattern.
    pub fn layer_upto(&mut self, l: usize, rows: usize) -> (&[f32], &[f32]) {
        let n = rows * self.dkv;
        match &mut self.store {
            Store::F32 { k, v } => (&k[l][..n], &v[l][..n]),
            Store::Half { k, v, wide_k, wide_v } => {
                let dt = self.dtype;
                for (dst, &bits) in wide_k[..n].iter_mut().zip(&k[l][..n]) {
                    *dst = dt.widen(bits);
                }
                for (dst, &bits) in wide_v[..n].iter_mut().zip(&v[l][..n]) {
                    *dst = dt.widen(bits);
                }
                (&wide_k[..n], &wide_v[..n])
            }
        }
    }

    /// Bytes of K/V currently resident in the cache (`len` rows, every
    /// layer, both directions) at the storage dtype's width.
    pub fn live_bytes(&self) -> usize {
        2 * self.layers * self.len * self.dkv * self.dtype.bytes()
    }

    /// Bytes of cached K/V one decode step at the current length actually
    /// streams (the memory-bound cost the §5.2 roofline models). A sliding
    /// window caps the visible rows — the decode kernel's mask-aware tile
    /// skipping never touches older tiles — matching the
    /// `eff_s = min(len, window)` term of [`crate::flops::decode`].
    pub fn step_bytes(&self, window: Option<usize>) -> usize {
        let rows = match window {
            Some(w) => self.len.min(w),
            None => self.len,
        };
        2 * self.layers * rows * self.dkv * self.dtype.bytes()
    }

    /// Allocated *cache* footprint (capacity, not occupancy) — what a
    /// session's resident K/V costs in RSS at the storage dtype's width.
    /// The half-path widen scratch (one f32 slab pair per cache, not per
    /// layer) is a reuse buffer, not cache state, and is excluded so this
    /// stays the roofline-comparable `2·layers·capacity·dkv·bytes` term.
    pub fn alloc_bytes(&self) -> usize {
        2 * self.layers * self.capacity * self.dkv * self.dtype.bytes()
    }
}

// ---- session table ----------------------------------------------------------

/// Why [`SessionTable::take`] (or [`SessionTable::with`]) failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TakeError {
    /// No such session — never created, or already closed.
    Unknown,
    /// The session exists but a step is in flight (`Busy` marker in the
    /// slot): the caller raced another step on the same session.
    Busy,
}

/// Table slot. `Busy` marks a session whose step is in flight on some
/// worker with the table lock *released*; closing a busy session removes
/// the entry, and the step's put-back notices and drops the state instead
/// of resurrecting it.
enum Slot<S> {
    Ready(Box<S>),
    Busy,
}

/// Concurrent id → session map with a take/Busy/put-back step protocol.
///
/// The lock is held only for table lookups: a step [`SessionTable::take`]s
/// the session *out* (leaving a `Busy` marker), computes with the lock
/// released, then [`SessionTable::put_back`]s. Concurrently batched
/// sessions never serialize on the lock; two steps on the *same* id are
/// rejected (`TakeError::Busy`) instead of silently queued; a close during
/// a step wins — put-back sees the entry gone and drops the state.
///
/// This protocol is loom-model-checked (`rust/tests/loom_models.rs`,
/// `session_table_*`) via the [`crate::util::sync`] seam.
pub struct SessionTable<S> {
    slots: Mutex<HashMap<u64, Slot<S>>>,
    next: AtomicU64,
}

impl<S> Default for SessionTable<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> SessionTable<S> {
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(HashMap::new()),
            // Ids start at 1 so 0 is never a live session (callers use it
            // as a "no session" sentinel in logs and CLI plumbing).
            next: AtomicU64::new(1),
        }
    }

    /// Register a new session, returning its id.
    pub fn insert(&self, session: S) -> u64 {
        // Relaxed: the id is data, not a synchronization edge — the mutex
        // below publishes the slot itself, and uniqueness needs only the
        // RMW atomicity of fetch_add, not any ordering with other memory.
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        sync::lock(&self.slots).insert(id, Slot::Ready(Box::new(session)));
        id
    }

    /// Take the session out for a step, leaving a `Busy` marker.
    pub fn take(&self, id: u64) -> Result<Box<S>, TakeError> {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            None => Err(TakeError::Unknown),
            Some(Slot::Busy) => Err(TakeError::Busy),
            Some(slot) => match std::mem::replace(slot, Slot::Busy) {
                Slot::Ready(s) => Ok(s),
                Slot::Busy => unreachable!(),
            },
        }
    }

    /// Return a taken session. `false` means the session was closed while
    /// the step ran — the state is dropped, not resurrected.
    pub fn put_back(&self, id: u64, session: Box<S>) -> bool {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            Some(slot) if matches!(slot, Slot::Busy) => {
                *slot = Slot::Ready(session);
                true
            }
            _ => false,
        }
    }

    /// Remove a session. `true` if an entry (ready *or* busy) was removed;
    /// removing a `Busy` marker is fine — the in-flight step's put-back
    /// sees the missing entry and drops the session state.
    pub fn close(&self, id: u64) -> bool {
        sync::lock(&self.slots).remove(&id).is_some()
    }

    /// Read-only peek at a resident session (stats paths). Fails `Busy`
    /// rather than blocking behind an in-flight step.
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&S) -> R) -> Result<R, TakeError> {
        let tab = sync::lock(&self.slots);
        match tab.get(&id) {
            Some(Slot::Ready(s)) => Ok(f(s)),
            Some(Slot::Busy) => Err(TakeError::Busy),
            None => Err(TakeError::Unknown),
        }
    }

    /// Number of live entries (ready + busy).
    pub fn len(&self) -> usize {
        sync::lock(&self.slots).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_advance_commits_rows() {
        let mut kv = KvCache::new(2, 4, 3);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.remaining(), 4);
        for l in 0..2 {
            kv.write(l, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).unwrap();
        }
        // Uncommitted rows are already readable with an explicit count.
        let (k0, v0) = kv.layer_upto(0, 1);
        assert_eq!(k0, &[1.0, 2.0, 3.0]);
        assert_eq!(v0, &[4.0, 5.0, 6.0]);
        kv.advance(1).unwrap();
        assert_eq!(kv.len(), 1);
        // Next write lands at row 1.
        kv.write(1, &[7.0; 3], &[8.0; 3]).unwrap();
        let (k1, _) = kv.layer_upto(1, 2);
        assert_eq!(&k1[3..], &[7.0; 3]);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut kv = KvCache::new(1, 2, 2);
        kv.write(0, &[0.0; 4], &[0.0; 4]).unwrap(); // 2 rows at once
        kv.advance(2).unwrap();
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "cache is full");
        assert!(kv.advance(1).is_err());
        assert_eq!(kv.remaining(), 0);
    }

    #[test]
    fn byte_accounting_scales_with_hkv() {
        // Same context, 2x the kv heads -> exactly 2x the live bytes:
        // the sSQA-vs-GQA §5.2 difference as an actual buffer size.
        let mut small = KvCache::new(3, 8, 4); // Hkv*dh = 4
        let mut big = KvCache::new(3, 8, 8); // Hkv*dh = 8
        for kv in [&mut small, &mut big] {
            for l in 0..3 {
                let w = kv.dkv();
                kv.write(l, &vec![0.0; 5 * w], &vec![0.0; 5 * w]).unwrap();
            }
            kv.advance(5).unwrap();
        }
        assert_eq!(small.live_bytes(), 2 * 3 * 5 * 4 * 4);
        assert_eq!(big.live_bytes(), 2 * small.live_bytes());
        assert_eq!(big.alloc_bytes(), 2 * 3 * 8 * 8 * 4);
        // A sliding window caps the *streamed* rows, not the resident ones.
        assert_eq!(small.step_bytes(None), small.live_bytes());
        assert_eq!(small.step_bytes(Some(3)), 2 * 3 * 3 * 4 * 4);
        assert_eq!(small.step_bytes(Some(100)), small.live_bytes());
    }

    #[test]
    fn f16_conversion_is_ieee_round_to_nearest_even() {
        // Exactly representable values round-trip bit-perfectly.
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1035156e-5, 5.9604645e-8] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "round trip of {x}");
        }
        // Known bit patterns (cross-checked against numpy float16).
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        // Ties round to even mantissa: 1 + 2^-11 is exactly between
        // 1.0 (even) and 1 + 2^-10; 1 + 3*2^-11 between 1 + 2^-10 (odd)
        // and 1 + 2^-9 (even).
        assert_eq!(f32_to_f16_bits(1.0 + f32::powi(2.0, -11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * f32::powi(2.0, -11)), 0x3c02);
        // Overflow saturates to inf; tiny magnitudes flush to signed zero.
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000, "negative underflow keeps its sign");
        // NaN survives the round trip as NaN; infinities as infinities.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // Subnormal f16s widen exactly (man * 2^-24).
        assert_eq!(f16_bits_to_f32(0x0001), f32::powi(2.0, -24));
        assert_eq!(f16_bits_to_f32(0x8001), -f32::powi(2.0, -24));
    }

    #[test]
    fn bf16_conversion_truncates_with_round_to_nearest_even() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 3.0e38, 1.175e-38, 256.0] {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let rel = ((rt - x) / if x == 0.0 { 1.0 } else { x }).abs();
            assert!(rel <= f32::powi(2.0, -8), "bf16({x}) came back {rt}");
        }
        // bf16 is f32's top half: values with <= 7 mantissa bits are exact.
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.5)), 1.5);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        // Tie cases on the dropped 16 bits round to even.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f80_8000)), 0x3f80);
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f81_8000)), 0x3f82);
        // Full f32 exponent range survives (where f16 would saturate).
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(1e30)).is_finite());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn half_cache_reads_match_the_narrow_widen_mirror() {
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let mut kv = KvCache::new_with_dtype(2, 4, 3, dtype);
            let k_rows: Vec<f32> = (0..6).map(|i| 0.1 + i as f32 * 0.7).collect();
            let v_rows: Vec<f32> = k_rows.iter().map(|x| -x * 3.3).collect();
            for l in 0..2 {
                kv.write(l, &k_rows, &v_rows).unwrap();
            }
            kv.advance(2).unwrap();
            let mirror = |xs: &[f32]| -> Vec<f32> {
                xs.iter()
                    .map(|&x| match dtype {
                        KvDtype::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
                        KvDtype::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
                        KvDtype::F32 => x,
                    })
                    .collect()
            };
            let (want_k, want_v) = (mirror(&k_rows), mirror(&v_rows));
            for l in 0..2 {
                let (kc, vc) = kv.layer_upto(l, 2);
                assert_eq!(kc, &want_k[..], "{} keys, layer {l}", dtype.name());
                assert_eq!(vc, &want_v[..], "{} values, layer {l}", dtype.name());
            }
        }
    }

    #[test]
    fn half_dtypes_halve_every_byte_account() {
        let fill = |kv: &mut KvCache| {
            for l in 0..3 {
                let w = kv.dkv();
                kv.write(l, &vec![0.25; 5 * w], &vec![0.5; 5 * w]).unwrap();
            }
            kv.advance(5).unwrap();
        };
        let mut full = KvCache::new(3, 8, 4);
        fill(&mut full);
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let mut half = KvCache::new_with_dtype(3, 8, 4, dtype);
            fill(&mut half);
            assert_eq!(half.dtype(), dtype);
            assert_eq!(half.live_bytes() * 2, full.live_bytes());
            assert_eq!(half.live_bytes(), 2 * 3 * 5 * 4 * 2);
            assert_eq!(half.alloc_bytes() * 2, full.alloc_bytes());
            assert_eq!(half.step_bytes(None) * 2, full.step_bytes(None));
            assert_eq!(half.step_bytes(Some(3)) * 2, full.step_bytes(Some(3)));
        }
    }

    #[test]
    fn kv_dtype_parses_and_names_round_trip() {
        for dt in [KvDtype::F32, KvDtype::F16, KvDtype::Bf16] {
            assert_eq!(KvDtype::parse(dt.name()).unwrap(), dt);
        }
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.bytes(), 4);
        assert_eq!(KvDtype::F16.bytes(), 2);
        assert_eq!(KvDtype::Bf16.bytes(), 2);
        assert!(KvDtype::parse("f64").is_err());
        assert!(KvDtype::parse("half").is_err());
    }

    #[test]
    fn bad_writes_are_rejected() {
        let mut kv = KvCache::new(1, 4, 3);
        assert!(kv.write(1, &[0.0; 3], &[0.0; 3]).is_err(), "bad layer");
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "not a row multiple");
        assert!(kv.write(0, &[0.0; 3], &[0.0; 6]).is_err(), "k/v mismatch");
        assert!(kv.write(0, &[], &[]).is_err(), "empty write");
    }

    #[test]
    fn table_take_put_back_roundtrip() {
        let tab = SessionTable::new();
        let id = tab.insert(41u64);
        assert_eq!(tab.with(id, |s| *s), Ok(41));
        let mut s = tab.take(id).unwrap();
        *s += 1;
        // Mid-step: a second take and a stats peek both see Busy.
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Busy);
        assert_eq!(tab.with(id, |s| *s).unwrap_err(), TakeError::Busy);
        assert!(tab.put_back(id, s));
        assert_eq!(tab.with(id, |s| *s), Ok(42));
        assert!(tab.close(id));
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn table_close_during_step_drops_state() {
        let tab = SessionTable::new();
        let id = tab.insert("state".to_string());
        let s = tab.take(id).unwrap();
        assert!(tab.close(id), "closing a busy session removes the marker");
        assert!(!tab.put_back(id, s), "put-back after close must drop, not resurrect");
        assert_eq!(tab.with(id, |s| s.clone()).unwrap_err(), TakeError::Unknown);
        assert!(tab.is_empty());
    }

    #[test]
    fn table_ids_are_unique_and_nonzero() {
        let tab = SessionTable::new();
        let a = tab.insert(0u8);
        let b = tab.insert(1u8);
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(tab.len(), 2);
    }
}
