//! Per-session KV caches for incremental decode.
//!
//! One [`KvCache`] backs one generation session: a contiguous per-layer
//! append buffer of projected key/value rows in the native backend's
//! head-interleaved `[capacity, Hkv·d_head]` layout. Sizing follows the
//! variant's `Hkv` — this is where the paper's §5 decode axis becomes
//! *observable* instead of simulated: an sSQA session (`Hkv = H/2`)
//! allocates and streams twice the bytes of a GQA/xSQA session
//! (`Hkv = H/4`) at the same context length, and
//! [`KvCache::live_bytes`] is exactly the cache traffic term of
//! [`crate::flops::decode::decode_step`].
//!
//! Write protocol (mirrors how a forward step visits layers): each layer
//! writes its fresh rows at the *same* base slot via [`KvCache::write`],
//! then the step commits once with [`KvCache::advance`]. Until `advance`,
//! readers that pass an explicit row count ([`KvCache::layer_upto`]) can
//! already see the fresh rows — the decode kernel attends `len + 1` rows
//! while the step that produced row `len` is still in flight across layers.

use crate::util::sync::{self, AtomicU64, Mutex, Ordering};
use anyhow::{ensure, Result};
use std::collections::HashMap;

/// Contiguous per-layer K/V append buffers for one generation session.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Per-layer `[capacity, dkv]` key rows (flat, row-major).
    k: Vec<Vec<f32>>,
    /// Per-layer `[capacity, dkv]` value rows.
    v: Vec<Vec<f32>>,
    /// Committed token rows (every layer has this many valid rows).
    len: usize,
    capacity: usize,
    /// Row width: `Hkv * d_head`.
    dkv: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, capacity: usize, dkv: usize) -> Self {
        assert!(n_layers > 0 && capacity > 0 && dkv > 0, "empty cache geometry");
        Self {
            k: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
            v: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
            len: 0,
            capacity,
            dkv,
        }
    }

    /// Committed token rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum token rows (prompt + generated) this session can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rows still free.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Row width (`Hkv * d_head`).
    pub fn dkv(&self) -> usize {
        self.dkv
    }

    /// Write `n` fresh K/V rows for layer `l` at slots `[len, len + n)`
    /// (uncommitted until [`KvCache::advance`]). `k_rows`/`v_rows` are
    /// `[n, dkv]` head-interleaved slabs, `n` inferred from their length.
    pub fn write(&mut self, l: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        ensure!(l < self.k.len(), "layer {l} out of range ({})", self.k.len());
        ensure!(
            k_rows.len() == v_rows.len() && !k_rows.is_empty() && k_rows.len() % self.dkv == 0,
            "kv rows must be equal non-empty multiples of dkv={} (got {}/{})",
            self.dkv,
            k_rows.len(),
            v_rows.len()
        );
        let n = k_rows.len() / self.dkv;
        ensure!(
            self.len + n <= self.capacity,
            "session at capacity: {} cached + {n} new > {}",
            self.len,
            self.capacity
        );
        let at = self.len * self.dkv;
        self.k[l][at..at + k_rows.len()].copy_from_slice(k_rows);
        self.v[l][at..at + v_rows.len()].copy_from_slice(v_rows);
        Ok(())
    }

    /// Commit `n` rows written to every layer.
    pub fn advance(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.len + n <= self.capacity,
            "advance past capacity: {} + {n} > {}",
            self.len,
            self.capacity
        );
        self.len += n;
        Ok(())
    }

    /// Layer `l`'s first `rows` K/V rows (may exceed `len` by the
    /// uncommitted rows a step just wrote).
    pub fn layer_upto(&self, l: usize, rows: usize) -> (&[f32], &[f32]) {
        let n = rows * self.dkv;
        (&self.k[l][..n], &self.v[l][..n])
    }

    /// Bytes of K/V currently resident in the cache (`len` rows, every
    /// layer, both directions).
    pub fn live_bytes(&self) -> usize {
        2 * self.k.len() * self.len * self.dkv * std::mem::size_of::<f32>()
    }

    /// Bytes of cached K/V one decode step at the current length actually
    /// streams (the memory-bound cost the §5.2 roofline models). A sliding
    /// window caps the visible rows — the decode kernel's mask-aware tile
    /// skipping never touches older tiles — matching the
    /// `eff_s = min(len, window)` term of [`crate::flops::decode`].
    pub fn step_bytes(&self, window: Option<usize>) -> usize {
        let rows = match window {
            Some(w) => self.len.min(w),
            None => self.len,
        };
        2 * self.k.len() * rows * self.dkv * std::mem::size_of::<f32>()
    }

    /// Allocated cache footprint (capacity, not occupancy) — what a
    /// session costs in RSS.
    pub fn alloc_bytes(&self) -> usize {
        2 * self.k.len() * self.capacity * self.dkv * std::mem::size_of::<f32>()
    }
}

// ---- session table ----------------------------------------------------------

/// Why [`SessionTable::take`] (or [`SessionTable::with`]) failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TakeError {
    /// No such session — never created, or already closed.
    Unknown,
    /// The session exists but a step is in flight (`Busy` marker in the
    /// slot): the caller raced another step on the same session.
    Busy,
}

/// Table slot. `Busy` marks a session whose step is in flight on some
/// worker with the table lock *released*; closing a busy session removes
/// the entry, and the step's put-back notices and drops the state instead
/// of resurrecting it.
enum Slot<S> {
    Ready(Box<S>),
    Busy,
}

/// Concurrent id → session map with a take/Busy/put-back step protocol.
///
/// The lock is held only for table lookups: a step [`SessionTable::take`]s
/// the session *out* (leaving a `Busy` marker), computes with the lock
/// released, then [`SessionTable::put_back`]s. Concurrently batched
/// sessions never serialize on the lock; two steps on the *same* id are
/// rejected (`TakeError::Busy`) instead of silently queued; a close during
/// a step wins — put-back sees the entry gone and drops the state.
///
/// This protocol is loom-model-checked (`rust/tests/loom_models.rs`,
/// `session_table_*`) via the [`crate::util::sync`] seam.
pub struct SessionTable<S> {
    slots: Mutex<HashMap<u64, Slot<S>>>,
    next: AtomicU64,
}

impl<S> Default for SessionTable<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> SessionTable<S> {
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(HashMap::new()),
            // Ids start at 1 so 0 is never a live session (callers use it
            // as a "no session" sentinel in logs and CLI plumbing).
            next: AtomicU64::new(1),
        }
    }

    /// Register a new session, returning its id.
    pub fn insert(&self, session: S) -> u64 {
        // Relaxed: the id is data, not a synchronization edge — the mutex
        // below publishes the slot itself, and uniqueness needs only the
        // RMW atomicity of fetch_add, not any ordering with other memory.
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        sync::lock(&self.slots).insert(id, Slot::Ready(Box::new(session)));
        id
    }

    /// Take the session out for a step, leaving a `Busy` marker.
    pub fn take(&self, id: u64) -> Result<Box<S>, TakeError> {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            None => Err(TakeError::Unknown),
            Some(Slot::Busy) => Err(TakeError::Busy),
            Some(slot) => match std::mem::replace(slot, Slot::Busy) {
                Slot::Ready(s) => Ok(s),
                Slot::Busy => unreachable!(),
            },
        }
    }

    /// Return a taken session. `false` means the session was closed while
    /// the step ran — the state is dropped, not resurrected.
    pub fn put_back(&self, id: u64, session: Box<S>) -> bool {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            Some(slot) if matches!(slot, Slot::Busy) => {
                *slot = Slot::Ready(session);
                true
            }
            _ => false,
        }
    }

    /// Remove a session. `true` if an entry (ready *or* busy) was removed;
    /// removing a `Busy` marker is fine — the in-flight step's put-back
    /// sees the missing entry and drops the session state.
    pub fn close(&self, id: u64) -> bool {
        sync::lock(&self.slots).remove(&id).is_some()
    }

    /// Read-only peek at a resident session (stats paths). Fails `Busy`
    /// rather than blocking behind an in-flight step.
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&S) -> R) -> Result<R, TakeError> {
        let tab = sync::lock(&self.slots);
        match tab.get(&id) {
            Some(Slot::Ready(s)) => Ok(f(s)),
            Some(Slot::Busy) => Err(TakeError::Busy),
            None => Err(TakeError::Unknown),
        }
    }

    /// Number of live entries (ready + busy).
    pub fn len(&self) -> usize {
        sync::lock(&self.slots).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_advance_commits_rows() {
        let mut kv = KvCache::new(2, 4, 3);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.remaining(), 4);
        for l in 0..2 {
            kv.write(l, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).unwrap();
        }
        // Uncommitted rows are already readable with an explicit count.
        let (k0, v0) = kv.layer_upto(0, 1);
        assert_eq!(k0, &[1.0, 2.0, 3.0]);
        assert_eq!(v0, &[4.0, 5.0, 6.0]);
        kv.advance(1).unwrap();
        assert_eq!(kv.len(), 1);
        // Next write lands at row 1.
        kv.write(1, &[7.0; 3], &[8.0; 3]).unwrap();
        let (k1, _) = kv.layer_upto(1, 2);
        assert_eq!(&k1[3..], &[7.0; 3]);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut kv = KvCache::new(1, 2, 2);
        kv.write(0, &[0.0; 4], &[0.0; 4]).unwrap(); // 2 rows at once
        kv.advance(2).unwrap();
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "cache is full");
        assert!(kv.advance(1).is_err());
        assert_eq!(kv.remaining(), 0);
    }

    #[test]
    fn byte_accounting_scales_with_hkv() {
        // Same context, 2x the kv heads -> exactly 2x the live bytes:
        // the sSQA-vs-GQA §5.2 difference as an actual buffer size.
        let mut small = KvCache::new(3, 8, 4); // Hkv*dh = 4
        let mut big = KvCache::new(3, 8, 8); // Hkv*dh = 8
        for kv in [&mut small, &mut big] {
            for l in 0..3 {
                let w = kv.dkv();
                kv.write(l, &vec![0.0; 5 * w], &vec![0.0; 5 * w]).unwrap();
            }
            kv.advance(5).unwrap();
        }
        assert_eq!(small.live_bytes(), 2 * 3 * 5 * 4 * 4);
        assert_eq!(big.live_bytes(), 2 * small.live_bytes());
        assert_eq!(big.alloc_bytes(), 2 * 3 * 8 * 8 * 4);
        // A sliding window caps the *streamed* rows, not the resident ones.
        assert_eq!(small.step_bytes(None), small.live_bytes());
        assert_eq!(small.step_bytes(Some(3)), 2 * 3 * 3 * 4 * 4);
        assert_eq!(small.step_bytes(Some(100)), small.live_bytes());
    }

    #[test]
    fn bad_writes_are_rejected() {
        let mut kv = KvCache::new(1, 4, 3);
        assert!(kv.write(1, &[0.0; 3], &[0.0; 3]).is_err(), "bad layer");
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "not a row multiple");
        assert!(kv.write(0, &[0.0; 3], &[0.0; 6]).is_err(), "k/v mismatch");
        assert!(kv.write(0, &[], &[]).is_err(), "empty write");
    }

    #[test]
    fn table_take_put_back_roundtrip() {
        let tab = SessionTable::new();
        let id = tab.insert(41u64);
        assert_eq!(tab.with(id, |s| *s), Ok(41));
        let mut s = tab.take(id).unwrap();
        *s += 1;
        // Mid-step: a second take and a stats peek both see Busy.
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Busy);
        assert_eq!(tab.with(id, |s| *s).unwrap_err(), TakeError::Busy);
        assert!(tab.put_back(id, s));
        assert_eq!(tab.with(id, |s| *s), Ok(42));
        assert!(tab.close(id));
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn table_close_during_step_drops_state() {
        let tab = SessionTable::new();
        let id = tab.insert("state".to_string());
        let s = tab.take(id).unwrap();
        assert!(tab.close(id), "closing a busy session removes the marker");
        assert!(!tab.put_back(id, s), "put-back after close must drop, not resurrect");
        assert_eq!(tab.with(id, |s| s.clone()).unwrap_err(), TakeError::Unknown);
        assert!(tab.is_empty());
    }

    #[test]
    fn table_ids_are_unique_and_nonzero() {
        let tab = SessionTable::new();
        let a = tab.insert(0u8);
        let b = tab.insert(1u8);
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(tab.len(), 2);
    }
}
