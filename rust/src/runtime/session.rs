//! Per-session KV caches for incremental decode.
//!
//! One [`KvCache`] backs one generation session: a contiguous per-layer
//! append buffer of projected key/value rows in the native backend's
//! head-interleaved `[capacity, Hkv·d_head]` layout. Sizing follows the
//! variant's `Hkv` — this is where the paper's §5 decode axis becomes
//! *observable* instead of simulated: an sSQA session (`Hkv = H/2`)
//! allocates and streams twice the bytes of a GQA/xSQA session
//! (`Hkv = H/4`) at the same context length, and
//! [`KvCache::live_bytes`] is exactly the cache traffic term of
//! [`crate::flops::decode::decode_step`].
//!
//! Write protocol (mirrors how a forward step visits layers): each layer
//! writes its fresh rows at the *same* base slot via [`KvCache::write`],
//! then the step commits once with [`KvCache::advance`]. Until `advance`,
//! readers that pass an explicit row count ([`KvCache::layer_upto`]) can
//! already see the fresh rows — the decode kernel attends `len + 1` rows
//! while the step that produced row `len` is still in flight across layers.
//!
//! Storage precision is a per-session choice ([`KvDtype`]): rows are
//! narrowed to f16/bf16 bits on write and widened back to f32 on read, so
//! the attention kernels never see anything but f32 while the *resident*
//! cache — and every byte-accounting method, and therefore the §5.2
//! roofline traffic term — shrinks by [`KvDtype::bytes`]. The conversions
//! are hand-rolled bit manipulation ([`f32_to_f16_bits`] and friends,
//! round-to-nearest-even) because the offline image has no `half` crate.

use crate::util::sync::{self, AtomicU64, Mutex, Ordering};
use anyhow::{bail, ensure, Context as _, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

// ---- half-precision conversions ---------------------------------------------

/// Narrow an f32 to IEEE-754 binary16 bits, round-to-nearest-even.
///
/// Overflow (|x| ≥ 65520) saturates to ±inf like hardware `vcvtps2ph`;
/// NaN payload keeps its top 10 mantissa bits and is always quieted so it
/// survives the round trip as a NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf or NaN (quieted, top payload bits preserved).
        let payload = if abs > 0x7f80_0000 {
            0x0200 | ((abs >> 13) & 0x03ff) as u16
        } else {
            0
        };
        return sign | 0x7c00 | payload;
    }
    let exp = (abs >> 23) as i32 - 127 + 15; // re-bias 8-bit -> 5-bit
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // Subnormal (or zero) in f16: shift the implicit-1 mantissa down.
        if exp < -10 {
            return sign; // underflows to ±0
        }
        let man = (abs & 0x007f_ffff) | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let mid = 1u32 << (shift - 1);
        let up = rem > mid || (rem == mid && half & 1 == 1);
        return sign | (half + up as u32) as u16;
    }
    let man = abs & 0x007f_ffff;
    let half = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let up = rem > 0x1000 || (rem == 0x1000 && half & 1 == 1);
    // A mantissa carry bumps the exponent; carrying out of exp 30 lands
    // exactly on the inf encoding, which is the correct rounded result.
    sign | (half + up as u32) as u16
}

/// Widen IEEE-754 binary16 bits back to f32 (exact — every f16 value is
/// representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // ±0 or subnormal: the value is exactly man * 2^-24.
        let mag = man as f32 * f32::from_bits((127 - 24) << 23);
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp as u32 + 127 - 15) << 23) | (man << 13))
}

/// Narrow an f32 to bfloat16 bits (truncated-exponent format),
/// round-to-nearest-even on the dropped 16 mantissa bits.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep sign + top payload bits, force a non-zero mantissa so the
        // NaN can't collapse to an inf encoding.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round-to-nearest-even in one add: half-ulp plus the parity bit.
    // Finite overflow carries into the inf encoding, the correct result.
    (bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) >> 16) as u16
}

/// Widen bfloat16 bits back to f32 (exact — bf16 is f32's top half).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Element type of a session's resident K/V rows.
///
/// The decode kernels always compute in f32; this only selects what the
/// cache *stores* (and therefore what a step streams — the §5.2 traffic
/// term scales by [`KvDtype::bytes`]). `F16` keeps ~11 bits of mantissa
/// but saturates beyond ±65504; `Bf16` keeps f32's full exponent range at
/// ~8 bits of mantissa — both halve the cache against `F32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    F16,
    Bf16,
}

impl KvDtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Self::F32),
            "f16" => Ok(Self::F16),
            "bf16" => Ok(Self::Bf16),
            other => bail!("unknown kv dtype {other:?} (f32|f16|bf16)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
            Self::Bf16 => "bf16",
        }
    }

    /// Bytes per cached element — the factor every byte-accounting method
    /// and the decode roofline's cache term scale by.
    pub fn bytes(self) -> usize {
        match self {
            Self::F32 => 4,
            Self::F16 | Self::Bf16 => 2,
        }
    }

    /// Spill-file dtype tag (stable on-disk byte, not `as`-cast ordinal).
    fn tag(self) -> u8 {
        match self {
            Self::F32 => 0,
            Self::F16 => 1,
            Self::Bf16 => 2,
        }
    }

    /// `SQA_KV_DTYPE` env (f32 unless told otherwise).
    pub fn from_env() -> Self {
        match std::env::var("SQA_KV_DTYPE").ok().as_deref() {
            Some(s) if !s.is_empty() => {
                Self::parse(s).unwrap_or_else(|e| panic!("SQA_KV_DTYPE: {e:#}"))
            }
            _ => Self::default(),
        }
    }

    /// Narrow one element to this dtype's stored bits (f32 rows are
    /// stored verbatim and never take this path).
    fn narrow(self, x: f32) -> u16 {
        match self {
            Self::F32 => unreachable!("f32 rows are stored verbatim"),
            Self::F16 => f32_to_f16_bits(x),
            Self::Bf16 => f32_to_bf16_bits(x),
        }
    }

    /// Widen stored bits back to f32.
    fn widen(self, bits: u16) -> f32 {
        match self {
            Self::F32 => unreachable!("f32 rows are stored verbatim"),
            Self::F16 => f16_bits_to_f32(bits),
            Self::Bf16 => bf16_bits_to_f32(bits),
        }
    }
}

/// Per-layer K/V slabs at the cache's element type. `F32` rows read back
/// as zero-copy slab slices; `Half` rows (f16 *or* bf16 bits — the
/// [`KvCache::dtype`] tag disambiguates) are narrowed on write and widened
/// into the per-cache scratch slabs on read.
#[derive(Debug, Clone)]
enum Store {
    F32 {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Half {
        k: Vec<Vec<u16>>,
        v: Vec<Vec<u16>>,
        /// Widen targets for [`KvCache::layer_upto`] — one `[capacity, dkv]`
        /// f32 slab per direction, reused across layers and steps.
        wide_k: Vec<f32>,
        wide_v: Vec<f32>,
    },
}

/// Contiguous per-layer K/V append buffers for one generation session.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Per-layer `[capacity, dkv]` K/V slabs (flat, row-major).
    store: Store,
    dtype: KvDtype,
    layers: usize,
    /// Committed token rows (every layer has this many valid rows).
    len: usize,
    capacity: usize,
    /// Row width: `Hkv * d_head`.
    dkv: usize,
}

impl KvCache {
    /// Full-precision cache (the historical default).
    pub fn new(n_layers: usize, capacity: usize, dkv: usize) -> Self {
        Self::new_with_dtype(n_layers, capacity, dkv, KvDtype::F32)
    }

    /// Cache whose resident rows are stored at `dtype`: narrowed on write,
    /// widened back to f32 on read. An f16/bf16 session halves both the
    /// footprint and the per-step streamed bytes against f32 at the same
    /// geometry — the decode-side lever the SQA paper's §5 trade-off
    /// composes with (it shifts *every* variant's cache down 2x without
    /// touching the Hkv ratios between them).
    pub fn new_with_dtype(n_layers: usize, capacity: usize, dkv: usize, dtype: KvDtype) -> Self {
        assert!(n_layers > 0 && capacity > 0 && dkv > 0, "empty cache geometry");
        let store = match dtype {
            KvDtype::F32 => Store::F32 {
                k: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
                v: (0..n_layers).map(|_| vec![0.0; capacity * dkv]).collect(),
            },
            KvDtype::F16 | KvDtype::Bf16 => Store::Half {
                k: (0..n_layers).map(|_| vec![0; capacity * dkv]).collect(),
                v: (0..n_layers).map(|_| vec![0; capacity * dkv]).collect(),
                wide_k: vec![0.0; capacity * dkv],
                wide_v: vec![0.0; capacity * dkv],
            },
        };
        Self {
            store,
            dtype,
            layers: n_layers,
            len: 0,
            capacity,
            dkv,
        }
    }

    /// Committed token rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum token rows (prompt + generated) this session can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rows still free.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    pub fn n_layers(&self) -> usize {
        self.layers
    }

    /// Row width (`Hkv * d_head`).
    pub fn dkv(&self) -> usize {
        self.dkv
    }

    /// Element type the resident rows are stored at.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Write `n` fresh K/V rows for layer `l` at slots `[len, len + n)`
    /// (uncommitted until [`KvCache::advance`]). `k_rows`/`v_rows` are
    /// `[n, dkv]` head-interleaved slabs, `n` inferred from their length.
    pub fn write(&mut self, l: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        ensure!(l < self.layers, "layer {l} out of range ({})", self.layers);
        ensure!(
            k_rows.len() == v_rows.len() && !k_rows.is_empty() && k_rows.len() % self.dkv == 0,
            "kv rows must be equal non-empty multiples of dkv={} (got {}/{})",
            self.dkv,
            k_rows.len(),
            v_rows.len()
        );
        let n = k_rows.len() / self.dkv;
        ensure!(
            self.len + n <= self.capacity,
            "session at capacity: {} cached + {n} new > {}",
            self.len,
            self.capacity
        );
        let at = self.len * self.dkv;
        match &mut self.store {
            Store::F32 { k, v } => {
                k[l][at..at + k_rows.len()].copy_from_slice(k_rows);
                v[l][at..at + v_rows.len()].copy_from_slice(v_rows);
            }
            Store::Half { k, v, .. } => {
                let dt = self.dtype;
                for (dst, &x) in k[l][at..at + k_rows.len()].iter_mut().zip(k_rows) {
                    *dst = dt.narrow(x);
                }
                for (dst, &x) in v[l][at..at + v_rows.len()].iter_mut().zip(v_rows) {
                    *dst = dt.narrow(x);
                }
            }
        }
        Ok(())
    }

    /// Commit `n` rows written to every layer.
    pub fn advance(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.len + n <= self.capacity,
            "advance past capacity: {} + {n} > {}",
            self.len,
            self.capacity
        );
        self.len += n;
        Ok(())
    }

    /// Layer `l`'s first `rows` K/V rows as f32 (may exceed `len` by the
    /// uncommitted rows a step just wrote).
    ///
    /// Zero-copy for f32 caches; half caches widen into the per-cache
    /// scratch slabs, so the returned slices borrow `&mut self` and the
    /// next `layer_upto` call overwrites them — read one layer at a time,
    /// exactly the decode step's access pattern.
    pub fn layer_upto(&mut self, l: usize, rows: usize) -> (&[f32], &[f32]) {
        let n = rows * self.dkv;
        match &mut self.store {
            Store::F32 { k, v } => (&k[l][..n], &v[l][..n]),
            Store::Half { k, v, wide_k, wide_v } => {
                let dt = self.dtype;
                for (dst, &bits) in wide_k[..n].iter_mut().zip(&k[l][..n]) {
                    *dst = dt.widen(bits);
                }
                for (dst, &bits) in wide_v[..n].iter_mut().zip(&v[l][..n]) {
                    *dst = dt.widen(bits);
                }
                (&wide_k[..n], &wide_v[..n])
            }
        }
    }

    /// Bytes of K/V currently resident in the cache (`len` rows, every
    /// layer, both directions) at the storage dtype's width.
    pub fn live_bytes(&self) -> usize {
        2 * self.layers * self.len * self.dkv * self.dtype.bytes()
    }

    /// Bytes of cached K/V one decode step at the current length actually
    /// streams (the memory-bound cost the §5.2 roofline models). A sliding
    /// window caps the visible rows — the decode kernel's mask-aware tile
    /// skipping never touches older tiles — matching the
    /// `eff_s = min(len, window)` term of [`crate::flops::decode`].
    pub fn step_bytes(&self, window: Option<usize>) -> usize {
        let rows = match window {
            Some(w) => self.len.min(w),
            None => self.len,
        };
        2 * self.layers * rows * self.dkv * self.dtype.bytes()
    }

    /// Allocated *cache* footprint (capacity, not occupancy) — what a
    /// session's resident K/V costs in RSS at the storage dtype's width.
    /// The half-path widen scratch (one f32 slab pair per cache, not per
    /// layer) is a reuse buffer, not cache state, and is excluded so this
    /// stays the roofline-comparable `2·layers·capacity·dkv·bytes` term.
    pub fn alloc_bytes(&self) -> usize {
        2 * self.layers * self.capacity * self.dkv * self.dtype.bytes()
    }
}

// ---- paged KV allocator ------------------------------------------------------
//
// The paged tier replaces "one contiguous slab per session" with a global
// pool of fixed-size blocks (`block_len` positions × all layers × K and V)
// and per-session block tables. Invariants the whole seam leans on:
//
// * refcounts never underflow — every `free_ref_locked` asserts `refs > 0`;
// * a shared block (`refs > 1`) is never written in place — writers COW
//   first (`ensure_writable`), so trie-published and cross-session blocks
//   are immutable;
// * byte accounting is exact: pool residency is `blocks_in_use ×
//   block_bytes`, and a session's *streamed* bytes stay the same pure
//   function of `len` as the contiguous cache (`step_bytes`), which is
//   what the decode roofline cross-checks.
//
// Raw block/slab indexing is confined to this file (enforced by the
// `kv-block-confinement` xtask lint rule): everything outside goes through
// [`PagedKvCache`] / [`SessionCache`] / [`BlockPool`] methods.

/// Sentinel in a session's block table for a slot whose block currently
/// lives in the spill file, not the pool.
const SPILLED: u32 = u32::MAX;

/// Spill-file magic ("SQKV" little-endian).
const SPILL_MAGIC: u32 = 0x5651_4b53;

/// Spill-file header: magic u32 | dtype tag u8 | block count u32 |
/// block_len u32 | layers u32 | dkv u32, all little-endian.
const SPILL_HEADER: usize = 4 + 1 + 4 + 4 + 4 + 4;

fn read_f32_le(bytes: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
}

fn read_u16_le(bytes: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([bytes[off], bytes[off + 1]])
}

fn read_u32_le(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
}

/// Geometry + limits of a [`BlockPool`].
#[derive(Debug, Clone)]
pub struct PagedConfig {
    /// Positions per block (the paging granule).
    pub block_len: usize,
    /// Total blocks in the pool — the global KV budget.
    pub pool_blocks: usize,
    /// Where idle sessions' blocks spill to; `None` disables eviction.
    pub spill_dir: Option<PathBuf>,
}

impl PagedConfig {
    /// `SQA_KV_BLOCK_LEN` (0/unset = contiguous caches),
    /// `SQA_KV_POOL_BLOCKS` (default 4096), `SQA_KV_SPILL_DIR` (optional).
    pub fn from_env() -> Option<Self> {
        Self::from_vars(
            std::env::var("SQA_KV_BLOCK_LEN").ok().as_deref(),
            std::env::var("SQA_KV_POOL_BLOCKS").ok().as_deref(),
            std::env::var("SQA_KV_SPILL_DIR").ok().as_deref(),
        )
    }

    /// Pure parsing half of [`Self::from_env`] (env mutation in tests
    /// races the concurrent harness; this stays testable without it).
    fn from_vars(block_len: Option<&str>, pool: Option<&str>, dir: Option<&str>) -> Option<Self> {
        let block_len: usize = block_len?.parse().ok()?;
        if block_len == 0 {
            return None;
        }
        let pool_blocks = pool.and_then(|s| s.parse().ok()).unwrap_or(4096);
        let spill_dir = dir.filter(|s| !s.is_empty()).map(PathBuf::from);
        Some(Self { block_len, pool_blocks, spill_dir })
    }
}

/// One block's K/V payload at the pool dtype: `layers · block_len · dkv`
/// elements per direction, row `(l·block_len + pos_in_block)·dkv`.
/// Buffers are sized lazily on first allocation and reused thereafter.
#[derive(Debug)]
enum BlockData {
    F32 { k: Vec<f32>, v: Vec<f32> },
    Half { k: Vec<u16>, v: Vec<u16> },
}

impl BlockData {
    fn empty(dtype: KvDtype) -> Self {
        match dtype {
            KvDtype::F32 => Self::F32 { k: Vec::new(), v: Vec::new() },
            KvDtype::F16 | KvDtype::Bf16 => Self::Half { k: Vec::new(), v: Vec::new() },
        }
    }

    fn ensure_sized(&mut self, elems: usize) {
        match self {
            Self::F32 { k, v } => {
                if k.len() != elems {
                    k.resize(elems, 0.0);
                    v.resize(elems, 0.0);
                }
            }
            Self::Half { k, v } => {
                if k.len() != elems {
                    k.resize(elems, 0);
                    v.resize(elems, 0);
                }
            }
        }
    }

    /// Whole-payload copy for COW splits (both sides already sized).
    fn copy_from(&mut self, src: &BlockData) {
        match (self, src) {
            (Self::F32 { k, v }, Self::F32 { k: sk, v: sv }) => {
                k.copy_from_slice(sk);
                v.copy_from_slice(sv);
            }
            (Self::Half { k, v }, Self::Half { k: sk, v: sv }) => {
                k.copy_from_slice(sk);
                v.copy_from_slice(sv);
            }
            _ => unreachable!("pool blocks share one dtype"),
        }
    }
}

struct Block {
    data: BlockData,
    /// Holders: sessions mapping this block + prefix-trie nodes naming it.
    refs: u32,
}

/// Sentinel parent index for trie nodes hanging off a namespace root.
const NO_NODE: usize = usize::MAX;

/// One prefix-trie node: a full immutable block published under its
/// `block_len`-token chunk key. `parent`/`key`/`ns` exist so LRU
/// reclamation can unlink a leaf without a tree walk.
struct TrieNode {
    block: u32,
    children: HashMap<Vec<i32>, usize>,
    parent: usize,
    ns: u64,
    key: Vec<i32>,
    /// Logical LRU clock stamp, bumped on every hit/insert.
    stamp: u64,
}

struct PoolInner {
    blocks: Vec<Block>,
    free: Vec<u32>,
    /// Trie arena (`None` = reclaimed slot, reusable via `node_free`).
    nodes: Vec<Option<TrieNode>>,
    node_free: Vec<usize>,
    /// Per-namespace roots: chunk key → node index. The namespace is an
    /// opaque caller fingerprint (params + geometry + lowering) so prefix
    /// hits can never cross models whose K/V projections differ.
    roots: HashMap<u64, HashMap<Vec<i32>, usize>>,
    clock: u64,
}

/// Global block pool: fixed-size refcounted KV blocks shared by every
/// paged session of one (layers, dkv, dtype) geometry, plus the prefix
/// trie that lets sessions with a common prompt prefix share blocks and
/// skip the prefill compute for the shared span.
pub struct BlockPool {
    layers: usize,
    dkv: usize,
    block_len: usize,
    dtype: KvDtype,
    spill_dir: Option<PathBuf>,
    inner: Mutex<PoolInner>,
    // Monotonic event counters (Relaxed — same argument as
    // `coordinator::metrics`: independent counters, no reader derives
    // correctness from a cross-counter snapshot).
    allocs: AtomicU64,
    frees: AtomicU64,
    cow_splits: AtomicU64,
    evictions: AtomicU64,
    restores: AtomicU64,
    prefix_queries: AtomicU64,
    prefix_hits: AtomicU64,
    prefix_hit_tokens: AtomicU64,
    spilled_blocks: AtomicU64,
}

/// Point-in-time view of a [`BlockPool`] (plus its lifetime counters) —
/// what `/metrics`, the engine's admission check and the decode bench
/// summary read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvPoolStats {
    pub block_len: usize,
    /// Bytes of one block: `2 · layers · block_len · dkv · dtype.bytes()`.
    pub block_bytes: usize,
    pub blocks_total: usize,
    pub blocks_free: usize,
    /// Blocks held *only* by the prefix trie — reclaimable on demand.
    pub blocks_reclaimable: usize,
    /// Blocks currently living in spill files instead of the pool.
    pub blocks_spilled: usize,
    pub allocs: u64,
    pub frees: u64,
    pub cow_splits: u64,
    pub evictions: u64,
    pub restores: u64,
    pub prefix_queries: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
}

impl KvPoolStats {
    /// Blocks currently resident and referenced.
    pub fn blocks_in_use(&self) -> usize {
        self.blocks_total - self.blocks_free
    }

    /// Resident pool bytes: the ISSUE invariant
    /// `blocks_in_use × block_bytes`, exact by construction.
    pub fn resident_bytes(&self) -> usize {
        self.blocks_in_use() * self.block_bytes
    }

    /// Shared-prefix hit rate over all lookups (0.0 when none ran).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_queries == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_queries as f64
    }

    /// Fold another pool's stats in (multi-geometry backends expose one
    /// merged view; block_len/block_bytes keep the first pool's values).
    pub fn absorb(&mut self, o: &KvPoolStats) {
        if self.block_len == 0 {
            self.block_len = o.block_len;
            self.block_bytes = o.block_bytes;
        }
        self.blocks_total += o.blocks_total;
        self.blocks_free += o.blocks_free;
        self.blocks_reclaimable += o.blocks_reclaimable;
        self.blocks_spilled += o.blocks_spilled;
        self.allocs += o.allocs;
        self.frees += o.frees;
        self.cow_splits += o.cow_splits;
        self.evictions += o.evictions;
        self.restores += o.restores;
        self.prefix_queries += o.prefix_queries;
        self.prefix_hits += o.prefix_hits;
        self.prefix_hit_tokens += o.prefix_hit_tokens;
    }
}

impl BlockPool {
    pub fn new(cfg: &PagedConfig, layers: usize, dkv: usize, dtype: KvDtype) -> Result<Arc<Self>> {
        ensure!(cfg.block_len > 0 && cfg.pool_blocks > 0, "empty paged pool geometry");
        ensure!(layers > 0 && dkv > 0, "empty cache geometry");
        ensure!(
            cfg.pool_blocks < SPILLED as usize,
            "pool too large for u32 block ids"
        );
        let blocks = (0..cfg.pool_blocks)
            .map(|_| Block { data: BlockData::empty(dtype), refs: 0 })
            .collect();
        // Reverse so pops hand out ids 0, 1, 2, … (deterministic tests).
        let free = (0..cfg.pool_blocks as u32).rev().collect();
        Ok(Arc::new(Self {
            layers,
            dkv,
            block_len: cfg.block_len,
            dtype,
            spill_dir: cfg.spill_dir.clone(),
            inner: Mutex::new(PoolInner {
                blocks,
                free,
                nodes: Vec::new(),
                node_free: Vec::new(),
                roots: HashMap::new(),
                clock: 0,
            }),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            cow_splits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            restores: AtomicU64::new(0),
            prefix_queries: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            spilled_blocks: AtomicU64::new(0),
        }))
    }

    pub fn block_len(&self) -> usize {
        self.block_len
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn spill_dir(&self) -> Option<&PathBuf> {
        self.spill_dir.as_ref()
    }

    /// Bytes of one block (both directions, all layers).
    pub fn block_bytes(&self) -> usize {
        2 * self.layers * self.block_len * self.dkv * self.dtype.bytes()
    }

    /// Elements per direction in one block.
    fn elems(&self) -> usize {
        self.layers * self.block_len * self.dkv
    }

    /// Pop a free block (refs = 1), reclaiming LRU trie-only blocks under
    /// pressure. Errors with the load-bearing "block pool exhausted"
    /// string when every block is referenced by a live session.
    fn alloc_locked(&self, inner: &mut PoolInner) -> Result<u32> {
        loop {
            if let Some(id) = inner.free.pop() {
                let b = &mut inner.blocks[id as usize];
                debug_assert_eq!(b.refs, 0, "free-list block still referenced");
                b.refs = 1;
                b.data.ensure_sized(self.elems());
                self.allocs.fetch_add(1, Ordering::Relaxed);
                return Ok(id);
            }
            if !self.reclaim_lru_locked(inner) {
                bail!(
                    "block pool exhausted: all {} blocks referenced by live sessions",
                    inner.blocks.len()
                );
            }
        }
    }

    /// Drop one reference; a block hitting zero returns to the free list.
    fn free_ref_locked(&self, inner: &mut PoolInner, id: u32) {
        let b = &mut inner.blocks[id as usize];
        assert!(b.refs > 0, "kv block {id} refcount underflow");
        b.refs -= 1;
        if b.refs == 0 {
            inner.free.push(id);
            self.frees.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Unlink the least-recently-touched trie *leaf* and drop its block
    /// reference. Returns false when the trie is empty (nothing left to
    /// reclaim). Reclaiming leaves-first keeps interior prefixes (which
    /// more sessions share) cached longest.
    fn reclaim_lru_locked(&self, inner: &mut PoolInner) -> bool {
        let mut best: Option<(usize, u64)> = None;
        for (i, slot) in inner.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.children.is_empty() && best.map_or(true, |(_, s)| n.stamp < s) {
                    best = Some((i, n.stamp));
                }
            }
        }
        let Some((i, _)) = best else {
            return false;
        };
        let node = inner.nodes[i].take().expect("scanned live node");
        inner.node_free.push(i);
        if node.parent == NO_NODE {
            if let Some(root) = inner.roots.get_mut(&node.ns) {
                root.remove(&node.key);
            }
        } else if let Some(p) = inner.nodes[node.parent].as_mut() {
            p.children.remove(&node.key);
        }
        self.free_ref_locked(inner, node.block);
        true
    }

    fn alloc_node_locked(inner: &mut PoolInner, node: TrieNode) -> usize {
        match inner.node_free.pop() {
            Some(i) => {
                inner.nodes[i] = Some(node);
                i
            }
            None => {
                inner.nodes.push(Some(node));
                inner.nodes.len() - 1
            }
        }
    }

    /// Longest shared prefix of `tokens` already cached under namespace
    /// `ns`: returns the shared blocks (references already taken — the
    /// caller must hand them to [`PagedKvCache::adopt_prefix`], whose Drop
    /// releases them) and the number of positions they cover. Full-chunk
    /// descent first; a final partial match against one child's key shares
    /// that immutable block as a partially-valid tail (the COW-on-write
    /// case). The span is capped at `tokens.len() - 1` so at least one
    /// suffix row is always computed (the caller needs its logits).
    pub fn prefix_lookup(&self, ns: u64, tokens: &[i32]) -> (Vec<u32>, usize) {
        self.prefix_queries.fetch_add(1, Ordering::Relaxed);
        let bl = self.block_len;
        let limit = tokens.len().saturating_sub(1);
        let mut blocks = Vec::new();
        let mut pos = 0usize;
        let mut cur: Option<usize> = None;
        let mut inner = sync::lock(&self.inner);
        let inner = &mut *inner;
        loop {
            let exact: Option<usize> = {
                let children = match cur {
                    None => match inner.roots.get(&ns) {
                        Some(r) => r,
                        None => break,
                    },
                    Some(i) => &inner.nodes[i].as_ref().expect("live trie node").children,
                };
                if pos + bl <= limit {
                    children.get(&tokens[pos..pos + bl]).copied()
                } else {
                    None
                }
            };
            if let Some(ni) = exact {
                inner.clock += 1;
                let stamp = inner.clock;
                let node = inner.nodes[ni].as_mut().expect("live trie node");
                node.stamp = stamp;
                let b = node.block;
                inner.blocks[b as usize].refs += 1;
                blocks.push(b);
                pos += bl;
                cur = Some(ni);
                continue;
            }
            // Mid-block divergence: share the child whose chunk key agrees
            // with our tokens for the longest m ≥ 1 positions. Ties break
            // by node index — any tied child holds identical rows (same
            // trie path ⇒ same upstream context), so this is determinism
            // hygiene, not a correctness choice.
            let partial: Option<(usize, usize)> = {
                let children = match cur {
                    None => match inner.roots.get(&ns) {
                        Some(r) => r,
                        None => break,
                    },
                    Some(i) => &inner.nodes[i].as_ref().expect("live trie node").children,
                };
                let want = &tokens[pos..limit.min(pos + bl)];
                let mut best: Option<(usize, usize)> = None;
                for (key, &ni) in children {
                    let m = key.iter().zip(want).take_while(|(a, b)| a == b).count();
                    if m >= 1
                        && best.map_or(true, |(bni, bm)| m > bm || (m == bm && ni < bni))
                    {
                        best = Some((ni, m));
                    }
                }
                best
            };
            if let Some((ni, m)) = partial {
                inner.clock += 1;
                let stamp = inner.clock;
                let node = inner.nodes[ni].as_mut().expect("live trie node");
                node.stamp = stamp;
                let b = node.block;
                inner.blocks[b as usize].refs += 1;
                blocks.push(b);
                pos += m;
            }
            break;
        }
        drop(inner);
        if pos > 0 {
            self.prefix_hits.fetch_add(1, Ordering::Relaxed);
            self.prefix_hit_tokens.fetch_add(pos as u64, Ordering::Relaxed);
        }
        (blocks, pos)
    }

    /// Publish a freshly prefilled session's *complete* blocks under its
    /// token chunks. Existing nodes win (their blocks are already shared);
    /// new nodes take one trie reference on the session's block, which
    /// outlives the session until LRU reclamation.
    pub fn prefix_insert(&self, ns: u64, tokens: &[i32], table: &[u32]) {
        let bl = self.block_len;
        let nfull = (tokens.len() / bl).min(table.len());
        let mut inner = sync::lock(&self.inner);
        let inner = &mut *inner;
        let mut cur: Option<usize> = None;
        for b in 0..nfull {
            if table[b] == SPILLED {
                return; // never publish a non-resident block
            }
            let chunk = &tokens[b * bl..(b + 1) * bl];
            let existing = match cur {
                None => inner.roots.get(&ns).and_then(|r| r.get(chunk).copied()),
                Some(i) => inner.nodes[i]
                    .as_ref()
                    .expect("live trie node")
                    .children
                    .get(chunk)
                    .copied(),
            };
            inner.clock += 1;
            let stamp = inner.clock;
            let ni = match existing {
                Some(ni) => {
                    inner.nodes[ni].as_mut().expect("live trie node").stamp = stamp;
                    ni
                }
                None => {
                    let block = table[b];
                    inner.blocks[block as usize].refs += 1;
                    let node = TrieNode {
                        block,
                        children: HashMap::new(),
                        parent: cur.unwrap_or(NO_NODE),
                        ns,
                        key: chunk.to_vec(),
                        stamp,
                    };
                    let ni = Self::alloc_node_locked(inner, node);
                    match cur {
                        None => {
                            inner.roots.entry(ns).or_default().insert(chunk.to_vec(), ni);
                        }
                        Some(p) => {
                            inner.nodes[p]
                                .as_mut()
                                .expect("live trie node")
                                .children
                                .insert(chunk.to_vec(), ni);
                        }
                    }
                    ni
                }
            };
            cur = Some(ni);
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        let inner = sync::lock(&self.inner);
        let mut trie_refs: HashMap<u32, u32> = HashMap::new();
        for n in inner.nodes.iter().flatten() {
            *trie_refs.entry(n.block).or_insert(0) += 1;
        }
        let reclaimable = trie_refs
            .iter()
            .filter(|(&b, &r)| inner.blocks[b as usize].refs == r)
            .count();
        KvPoolStats {
            block_len: self.block_len,
            block_bytes: self.block_bytes(),
            blocks_total: inner.blocks.len(),
            blocks_free: inner.free.len(),
            blocks_reclaimable: reclaimable,
            blocks_spilled: self.spilled_blocks.load(Ordering::Relaxed) as usize,
            allocs: self.allocs.load(Ordering::Relaxed),
            frees: self.frees.load(Ordering::Relaxed),
            cow_splits: self.cow_splits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            restores: self.restores.load(Ordering::Relaxed),
            prefix_queries: self.prefix_queries.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_hit_tokens: self.prefix_hit_tokens.load(Ordering::Relaxed),
        }
    }
}

/// Disjoint mutable borrows of two pool blocks (COW source + target).
fn two_blocks(blocks: &mut [Block], a: usize, b: usize) -> (&mut Block, &mut Block) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = blocks.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = blocks.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Table positions + destination file of a spilled session.
#[derive(Debug)]
struct SpillState {
    path: PathBuf,
    /// Table indices whose blocks live in the file, in file order.
    ix: Vec<usize>,
}

/// A session's view of the pool: logical positions → physical blocks.
///
/// Mirrors the [`KvCache`] write/advance/`layer_upto` protocol exactly —
/// same commit semantics, same capacity error strings, same
/// `step_bytes`/`live_bytes` formulas (pure functions of `len`, so the
/// roofline's measured-vs-predicted cross-check is dtype- and
/// layout-agnostic). Only `alloc_bytes` differs: it reports the resident
/// block footprint (`resident_blocks × block_bytes`) instead of a
/// contiguous capacity reservation.
pub struct PagedKvCache {
    pool: Arc<BlockPool>,
    table: Vec<u32>,
    len: usize,
    capacity: usize,
    /// Per-layer gather targets for `layer_upto` (f32, reused across
    /// layers and steps — the paged twin of the Half store's widen slabs).
    wide_k: Vec<f32>,
    wide_v: Vec<f32>,
    spill: Option<SpillState>,
}

impl PagedKvCache {
    pub fn new(pool: Arc<BlockPool>, capacity: usize) -> Self {
        assert!(capacity > 0, "empty cache geometry");
        Self {
            pool,
            table: Vec::new(),
            len: 0,
            capacity,
            wide_k: Vec::new(),
            wide_v: Vec::new(),
            spill: None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    pub fn n_layers(&self) -> usize {
        self.pool.layers
    }

    pub fn dkv(&self) -> usize {
        self.pool.dkv
    }

    pub fn dtype(&self) -> KvDtype {
        self.pool.dtype
    }

    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Whether this session's exclusive blocks live in a spill file.
    pub fn is_spilled(&self) -> bool {
        self.spill.is_some()
    }

    /// Resident blocks mapped by this session (spilled slots excluded).
    pub fn resident_blocks(&self) -> usize {
        self.table.iter().filter(|&&id| id != SPILLED).count()
    }

    /// Seed a fresh cache with trie-shared blocks covering `rows`
    /// positions (references were taken by [`BlockPool::prefix_lookup`];
    /// this cache's Drop releases them).
    pub fn adopt_prefix(&mut self, blocks: Vec<u32>, rows: usize) -> Result<()> {
        ensure!(
            self.len == 0 && self.table.is_empty(),
            "adopt_prefix on a used cache"
        );
        ensure!(
            rows <= self.capacity && rows <= blocks.len() * self.pool.block_len,
            "adopted prefix of {rows} rows does not fit {} blocks / capacity {}",
            blocks.len(),
            self.capacity
        );
        self.table = blocks;
        self.len = rows;
        Ok(())
    }

    /// Map block-table slot `b`, COWing a shared block before it is ever
    /// written in place. Writes are append-only, so `b` is at most one
    /// past the mapped tail.
    fn ensure_writable(&mut self, b: usize) -> Result<()> {
        let mut guard = sync::lock(&self.pool.inner);
        let inner = &mut *guard;
        if b == self.table.len() {
            let id = self.pool.alloc_locked(inner)?;
            self.table.push(id);
            return Ok(());
        }
        ensure!(b < self.table.len(), "non-append block write");
        let id = self.table[b];
        ensure!(id != SPILLED, "write into a spilled block");
        if inner.blocks[id as usize].refs > 1 {
            // COW split: this session writes its own copy; the other
            // holders (trie, sibling sessions) keep the original intact.
            let nid = self.pool.alloc_locked(inner)?;
            let (src, dst) = two_blocks(&mut inner.blocks, id as usize, nid as usize);
            dst.data.copy_from(&src.data);
            self.pool.free_ref_locked(inner, id);
            self.table[b] = nid;
            self.pool.cow_splits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Write `n` fresh K/V rows for layer `l` at slots `[len, len + n)` —
    /// the [`KvCache::write`] contract, routed through the block table.
    pub fn write(&mut self, l: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        let (layers, bl, dkv) = (self.pool.layers, self.pool.block_len, self.pool.dkv);
        ensure!(l < layers, "layer {l} out of range ({layers})");
        ensure!(
            k_rows.len() == v_rows.len() && !k_rows.is_empty() && k_rows.len() % dkv == 0,
            "kv rows must be equal non-empty multiples of dkv={} (got {}/{})",
            dkv,
            k_rows.len(),
            v_rows.len()
        );
        let n = k_rows.len() / dkv;
        ensure!(
            self.len + n <= self.capacity,
            "session at capacity: {} cached + {n} new > {}",
            self.len,
            self.capacity
        );
        self.ensure_resident()?;
        // Map/COW every touched block up front (layer 0 pays; later
        // layers of the same step find them exclusively owned already).
        for b in self.len / bl..=(self.len + n - 1) / bl {
            self.ensure_writable(b)?;
        }
        let dt = self.pool.dtype;
        let mut inner = sync::lock(&self.pool.inner);
        for r in 0..n {
            let pos = self.len + r;
            let (b, o) = (pos / bl, pos % bl);
            let base = (l * bl + o) * dkv;
            let krow = &k_rows[r * dkv..(r + 1) * dkv];
            let vrow = &v_rows[r * dkv..(r + 1) * dkv];
            match &mut inner.blocks[self.table[b] as usize].data {
                BlockData::F32 { k, v } => {
                    k[base..base + dkv].copy_from_slice(krow);
                    v[base..base + dkv].copy_from_slice(vrow);
                }
                BlockData::Half { k, v } => {
                    for (dst, &x) in k[base..base + dkv].iter_mut().zip(krow) {
                        *dst = dt.narrow(x);
                    }
                    for (dst, &x) in v[base..base + dkv].iter_mut().zip(vrow) {
                        *dst = dt.narrow(x);
                    }
                }
            }
        }
        Ok(())
    }

    /// Commit `n` rows written to every layer ([`KvCache::advance`]).
    pub fn advance(&mut self, n: usize) -> Result<()> {
        ensure!(
            self.len + n <= self.capacity,
            "advance past capacity: {} + {n} > {}",
            self.len,
            self.capacity
        );
        self.len += n;
        Ok(())
    }

    /// Layer `l`'s first `rows` K/V rows gathered from the block table
    /// into the f32 scratch slabs — one layer's visible prefix at a time
    /// (never the whole multi-layer cache), exactly the
    /// [`KvCache::layer_upto`] access pattern the decode kernel expects.
    pub fn layer_upto(&mut self, l: usize, rows: usize) -> Result<(&[f32], &[f32])> {
        let (bl, dkv) = (self.pool.block_len, self.pool.dkv);
        ensure!(self.spill.is_none(), "layer_upto on a spilled session");
        ensure!(
            rows <= self.table.len() * bl,
            "read past mapped blocks: {rows} rows > {} mapped",
            self.table.len() * bl
        );
        let n = rows * dkv;
        if self.wide_k.len() < n {
            self.wide_k.resize(n, 0.0);
            self.wide_v.resize(n, 0.0);
        }
        let dt = self.pool.dtype;
        let inner = sync::lock(&self.pool.inner);
        let mut r0 = 0usize;
        for (b, &id) in self.table.iter().enumerate() {
            if r0 >= rows {
                break;
            }
            debug_assert_eq!(r0, b * bl);
            let rh = bl.min(rows - r0);
            let base = l * bl * dkv;
            let span = rh * dkv;
            match &inner.blocks[id as usize].data {
                BlockData::F32 { k, v } => {
                    self.wide_k[r0 * dkv..r0 * dkv + span]
                        .copy_from_slice(&k[base..base + span]);
                    self.wide_v[r0 * dkv..r0 * dkv + span]
                        .copy_from_slice(&v[base..base + span]);
                }
                BlockData::Half { k, v } => {
                    for (dst, &bits) in self.wide_k[r0 * dkv..r0 * dkv + span]
                        .iter_mut()
                        .zip(&k[base..base + span])
                    {
                        *dst = dt.widen(bits);
                    }
                    for (dst, &bits) in self.wide_v[r0 * dkv..r0 * dkv + span]
                        .iter_mut()
                        .zip(&v[base..base + span])
                    {
                        *dst = dt.widen(bits);
                    }
                }
            }
            r0 += rh;
        }
        drop(inner);
        Ok((&self.wide_k[..n], &self.wide_v[..n]))
    }

    /// Publish this session's complete, committed blocks into the prefix
    /// trie under namespace `ns` so later sessions with the same leading
    /// tokens share them (and skip that span's prefill compute).
    pub fn publish_prefix(&self, ns: u64, tokens: &[i32]) {
        let nfull = self.len / self.pool.block_len;
        let tok = tokens.len().min(nfull * self.pool.block_len);
        self.pool.prefix_insert(ns, &tokens[..tok], &self.table);
    }

    /// Same formula as [`KvCache::live_bytes`] — a pure function of `len`.
    pub fn live_bytes(&self) -> usize {
        2 * self.pool.layers * self.len * self.pool.dkv * self.pool.dtype.bytes()
    }

    /// Same formula as [`KvCache::step_bytes`] — paging changes where
    /// rows live, not how many a step streams.
    pub fn step_bytes(&self, window: Option<usize>) -> usize {
        let rows = match window {
            Some(w) => self.len.min(w),
            None => self.len,
        };
        2 * self.pool.layers * rows * self.pool.dkv * self.pool.dtype.bytes()
    }

    /// Resident footprint: mapped blocks × block bytes. Shared blocks
    /// count fully for each mapping session here; the deduplicated truth
    /// is the pool-level [`KvPoolStats::resident_bytes`].
    pub fn alloc_bytes(&self) -> usize {
        self.resident_blocks() * self.pool.block_bytes()
    }

    /// Evict this idle session's *exclusively owned* blocks to `path`
    /// (bit-exact stored payloads) and return them to the pool. Shared
    /// blocks stay resident — their other holders keep them hot. Returns
    /// the number of blocks spilled (0 = nothing exclusive to evict).
    pub fn spill(&mut self, path: PathBuf) -> Result<usize> {
        ensure!(self.spill.is_none(), "session already spilled");
        let elems = self.pool.elems();
        let dtype = self.pool.dtype;
        let mut ix = Vec::new();
        let mut buf: Vec<u8>;
        {
            let inner = sync::lock(&self.pool.inner);
            for (i, &id) in self.table.iter().enumerate() {
                if id != SPILLED && inner.blocks[id as usize].refs == 1 {
                    ix.push(i);
                }
            }
            if ix.is_empty() {
                return Ok(0);
            }
            buf = Vec::with_capacity(SPILL_HEADER + ix.len() * 2 * elems * dtype.bytes());
            buf.extend_from_slice(&SPILL_MAGIC.to_le_bytes());
            buf.push(dtype.tag());
            buf.extend_from_slice(&(ix.len() as u32).to_le_bytes());
            buf.extend_from_slice(&(self.pool.block_len as u32).to_le_bytes());
            buf.extend_from_slice(&(self.pool.layers as u32).to_le_bytes());
            buf.extend_from_slice(&(self.pool.dkv as u32).to_le_bytes());
            for &i in &ix {
                match &inner.blocks[self.table[i] as usize].data {
                    BlockData::F32 { k, v } => {
                        for &x in &k[..elems] {
                            buf.extend_from_slice(&x.to_le_bytes());
                        }
                        for &x in &v[..elems] {
                            buf.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                    BlockData::Half { k, v } => {
                        for &x in &k[..elems] {
                            buf.extend_from_slice(&x.to_le_bytes());
                        }
                        for &x in &v[..elems] {
                            buf.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                }
            }
        }
        std::fs::write(&path, &buf).with_context(|| format!("spill to {}", path.display()))?;
        {
            let mut inner = sync::lock(&self.pool.inner);
            for &i in &ix {
                let id = self.table[i];
                self.pool.free_ref_locked(&mut inner, id);
                self.table[i] = SPILLED;
            }
        }
        let n = ix.len();
        self.pool.evictions.fetch_add(n as u64, Ordering::Relaxed);
        self.pool.spilled_blocks.fetch_add(n as u64, Ordering::Relaxed);
        self.spill = Some(SpillState { path, ix });
        Ok(n)
    }

    /// Transparent restore: re-allocate the spilled blocks, read the
    /// payloads back bit-exactly, delete the file. No-op when resident.
    /// A truncated/corrupt file or an exhausted pool fails loudly and
    /// leaves the spill state intact (retryable).
    pub fn ensure_resident(&mut self) -> Result<()> {
        let Some(sp) = self.spill.take() else {
            return Ok(());
        };
        match self.restore(&sp) {
            Ok(()) => {
                let n = sp.ix.len() as u64;
                self.pool.restores.fetch_add(n, Ordering::Relaxed);
                self.pool.spilled_blocks.fetch_sub(n, Ordering::Relaxed);
                let _ = std::fs::remove_file(&sp.path);
                Ok(())
            }
            Err(e) => {
                self.spill = Some(sp);
                Err(e)
            }
        }
    }

    fn restore(&mut self, sp: &SpillState) -> Result<()> {
        let elems = self.pool.elems();
        let dtype = self.pool.dtype;
        let bytes = std::fs::read(&sp.path)
            .with_context(|| format!("restore from {}", sp.path.display()))?;
        let want = SPILL_HEADER + sp.ix.len() * 2 * elems * dtype.bytes();
        let header_ok = bytes.len() >= SPILL_HEADER
            && read_u32_le(&bytes, 0) == SPILL_MAGIC
            && bytes[4] == dtype.tag()
            && read_u32_le(&bytes, 5) as usize == sp.ix.len()
            && read_u32_le(&bytes, 9) as usize == self.pool.block_len
            && read_u32_le(&bytes, 13) as usize == self.pool.layers
            && read_u32_le(&bytes, 17) as usize == self.pool.dkv;
        ensure!(
            header_ok && bytes.len() == want,
            "spill file truncated or corrupt: {} ({} bytes, want {want})",
            sp.path.display(),
            bytes.len()
        );
        let mut guard = sync::lock(&self.pool.inner);
        let inner = &mut *guard;
        // All-or-nothing allocation so a mid-restore exhaustion cannot
        // strand half the session in the pool and half on disk.
        let mut fresh = Vec::with_capacity(sp.ix.len());
        for _ in &sp.ix {
            match self.pool.alloc_locked(inner) {
                Ok(id) => fresh.push(id),
                Err(e) => {
                    for id in fresh {
                        self.pool.free_ref_locked(inner, id);
                    }
                    return Err(e);
                }
            }
        }
        let mut off = SPILL_HEADER;
        for (&i, &id) in sp.ix.iter().zip(&fresh) {
            match &mut inner.blocks[id as usize].data {
                BlockData::F32 { k, v } => {
                    for x in k[..elems].iter_mut() {
                        *x = read_f32_le(&bytes, off);
                        off += 4;
                    }
                    for x in v[..elems].iter_mut() {
                        *x = read_f32_le(&bytes, off);
                        off += 4;
                    }
                }
                BlockData::Half { k, v } => {
                    for x in k[..elems].iter_mut() {
                        *x = read_u16_le(&bytes, off);
                        off += 2;
                    }
                    for x in v[..elems].iter_mut() {
                        *x = read_u16_le(&bytes, off);
                        off += 2;
                    }
                }
            }
            self.table[i] = id;
        }
        Ok(())
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        {
            let mut inner = sync::lock(&self.pool.inner);
            for &id in &self.table {
                if id != SPILLED {
                    self.pool.free_ref_locked(&mut inner, id);
                }
            }
        }
        // Close-while-spilled frees both the blocks (above — spilled
        // entries hold none) and the disk file.
        if let Some(sp) = self.spill.take() {
            self.pool
                .spilled_blocks
                .fetch_sub(sp.ix.len() as u64, Ordering::Relaxed);
            let _ = std::fs::remove_file(&sp.path);
        }
    }
}

/// The storage a decode session actually holds: the historical contiguous
/// slab or a paged block-table view. Every caller outside this file goes
/// through these delegating methods — the two tiers stay drop-in
/// interchangeable (pinned by the paged-vs-contiguous differential suite).
pub enum SessionCache {
    Contig(KvCache),
    Paged(PagedKvCache),
}

impl SessionCache {
    pub fn len(&self) -> usize {
        match self {
            Self::Contig(kv) => kv.len(),
            Self::Paged(kv) => kv.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        match self {
            Self::Contig(kv) => kv.capacity(),
            Self::Paged(kv) => kv.capacity(),
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            Self::Contig(kv) => kv.dtype(),
            Self::Paged(kv) => kv.dtype(),
        }
    }

    pub fn write(&mut self, l: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        match self {
            Self::Contig(kv) => kv.write(l, k_rows, v_rows),
            Self::Paged(kv) => kv.write(l, k_rows, v_rows),
        }
    }

    pub fn advance(&mut self, n: usize) -> Result<()> {
        match self {
            Self::Contig(kv) => kv.advance(n),
            Self::Paged(kv) => kv.advance(n),
        }
    }

    pub fn layer_upto(&mut self, l: usize, rows: usize) -> Result<(&[f32], &[f32])> {
        match self {
            Self::Contig(kv) => Ok(kv.layer_upto(l, rows)),
            Self::Paged(kv) => kv.layer_upto(l, rows),
        }
    }

    pub fn live_bytes(&self) -> usize {
        match self {
            Self::Contig(kv) => kv.live_bytes(),
            Self::Paged(kv) => kv.live_bytes(),
        }
    }

    pub fn step_bytes(&self, window: Option<usize>) -> usize {
        match self {
            Self::Contig(kv) => kv.step_bytes(window),
            Self::Paged(kv) => kv.step_bytes(window),
        }
    }

    pub fn alloc_bytes(&self) -> usize {
        match self {
            Self::Contig(kv) => kv.alloc_bytes(),
            Self::Paged(kv) => kv.alloc_bytes(),
        }
    }

    /// Restore a spilled paged session; no-op for contiguous caches.
    pub fn ensure_resident(&mut self) -> Result<()> {
        match self {
            Self::Contig(_) => Ok(()),
            Self::Paged(kv) => kv.ensure_resident(),
        }
    }

    pub fn as_paged_mut(&mut self) -> Option<&mut PagedKvCache> {
        match self {
            Self::Contig(_) => None,
            Self::Paged(kv) => Some(kv),
        }
    }

    pub fn as_paged(&self) -> Option<&PagedKvCache> {
        match self {
            Self::Contig(_) => None,
            Self::Paged(kv) => Some(kv),
        }
    }
}

// ---- session table ----------------------------------------------------------

/// Why [`SessionTable::take`] (or [`SessionTable::with`]) failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TakeError {
    /// No such session — never created, or already closed.
    Unknown,
    /// The session exists but a step is in flight (`Busy` marker in the
    /// slot): the caller raced another step on the same session.
    Busy,
}

/// Table slot. `Busy` marks a session whose step is in flight on some
/// worker with the table lock *released*; closing a busy session removes
/// the entry, and the step's put-back notices and drops the state instead
/// of resurrecting it.
enum Slot<S> {
    Ready(Box<S>),
    Busy,
}

/// Concurrent id → session map with a take/Busy/put-back step protocol.
///
/// The lock is held only for table lookups: a step [`SessionTable::take`]s
/// the session *out* (leaving a `Busy` marker), computes with the lock
/// released, then [`SessionTable::put_back`]s. Concurrently batched
/// sessions never serialize on the lock; two steps on the *same* id are
/// rejected (`TakeError::Busy`) instead of silently queued; a close during
/// a step wins — put-back sees the entry gone and drops the state.
///
/// This protocol is loom-model-checked (`rust/tests/loom_models.rs`,
/// `session_table_*`) via the [`crate::util::sync`] seam.
pub struct SessionTable<S> {
    slots: Mutex<HashMap<u64, Slot<S>>>,
    next: AtomicU64,
}

impl<S> Default for SessionTable<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> SessionTable<S> {
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(HashMap::new()),
            // Ids start at 1 so 0 is never a live session (callers use it
            // as a "no session" sentinel in logs and CLI plumbing).
            next: AtomicU64::new(1),
        }
    }

    /// Register a new session, returning its id.
    pub fn insert(&self, session: S) -> u64 {
        // Relaxed: the id is data, not a synchronization edge — the mutex
        // below publishes the slot itself, and uniqueness needs only the
        // RMW atomicity of fetch_add, not any ordering with other memory.
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        sync::lock(&self.slots).insert(id, Slot::Ready(Box::new(session)));
        id
    }

    /// Take the session out for a step, leaving a `Busy` marker.
    pub fn take(&self, id: u64) -> Result<Box<S>, TakeError> {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            None => Err(TakeError::Unknown),
            Some(Slot::Busy) => Err(TakeError::Busy),
            Some(slot) => match std::mem::replace(slot, Slot::Busy) {
                Slot::Ready(s) => Ok(s),
                Slot::Busy => unreachable!(),
            },
        }
    }

    /// Return a taken session. `false` means the session was closed while
    /// the step ran — the state is dropped, not resurrected.
    pub fn put_back(&self, id: u64, session: Box<S>) -> bool {
        let mut tab = sync::lock(&self.slots);
        match tab.get_mut(&id) {
            Some(slot) if matches!(slot, Slot::Busy) => {
                *slot = Slot::Ready(session);
                true
            }
            _ => false,
        }
    }

    /// Remove a session. `true` if an entry (ready *or* busy) was removed;
    /// removing a `Busy` marker is fine — the in-flight step's put-back
    /// sees the missing entry and drops the session state.
    pub fn close(&self, id: u64) -> bool {
        sync::lock(&self.slots).remove(&id).is_some()
    }

    /// Read-only peek at a resident session (stats paths). Fails `Busy`
    /// rather than blocking behind an in-flight step.
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&S) -> R) -> Result<R, TakeError> {
        let tab = sync::lock(&self.slots);
        match tab.get(&id) {
            Some(Slot::Ready(s)) => Ok(f(s)),
            Some(Slot::Busy) => Err(TakeError::Busy),
            None => Err(TakeError::Unknown),
        }
    }

    /// Number of live entries (ready + busy).
    pub fn len(&self) -> usize {
        sync::lock(&self.slots).len()
    }

    /// Snapshot of live session ids (ready + busy), ascending — the
    /// eviction policy's scan order input.
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = sync::lock(&self.slots).keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_advance_commits_rows() {
        let mut kv = KvCache::new(2, 4, 3);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.remaining(), 4);
        for l in 0..2 {
            kv.write(l, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).unwrap();
        }
        // Uncommitted rows are already readable with an explicit count.
        let (k0, v0) = kv.layer_upto(0, 1);
        assert_eq!(k0, &[1.0, 2.0, 3.0]);
        assert_eq!(v0, &[4.0, 5.0, 6.0]);
        kv.advance(1).unwrap();
        assert_eq!(kv.len(), 1);
        // Next write lands at row 1.
        kv.write(1, &[7.0; 3], &[8.0; 3]).unwrap();
        let (k1, _) = kv.layer_upto(1, 2);
        assert_eq!(&k1[3..], &[7.0; 3]);
    }

    #[test]
    fn capacity_is_enforced() {
        let mut kv = KvCache::new(1, 2, 2);
        kv.write(0, &[0.0; 4], &[0.0; 4]).unwrap(); // 2 rows at once
        kv.advance(2).unwrap();
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "cache is full");
        assert!(kv.advance(1).is_err());
        assert_eq!(kv.remaining(), 0);
    }

    #[test]
    fn byte_accounting_scales_with_hkv() {
        // Same context, 2x the kv heads -> exactly 2x the live bytes:
        // the sSQA-vs-GQA §5.2 difference as an actual buffer size.
        let mut small = KvCache::new(3, 8, 4); // Hkv*dh = 4
        let mut big = KvCache::new(3, 8, 8); // Hkv*dh = 8
        for kv in [&mut small, &mut big] {
            for l in 0..3 {
                let w = kv.dkv();
                kv.write(l, &vec![0.0; 5 * w], &vec![0.0; 5 * w]).unwrap();
            }
            kv.advance(5).unwrap();
        }
        assert_eq!(small.live_bytes(), 2 * 3 * 5 * 4 * 4);
        assert_eq!(big.live_bytes(), 2 * small.live_bytes());
        assert_eq!(big.alloc_bytes(), 2 * 3 * 8 * 8 * 4);
        // A sliding window caps the *streamed* rows, not the resident ones.
        assert_eq!(small.step_bytes(None), small.live_bytes());
        assert_eq!(small.step_bytes(Some(3)), 2 * 3 * 3 * 4 * 4);
        assert_eq!(small.step_bytes(Some(100)), small.live_bytes());
    }

    #[test]
    fn f16_conversion_is_ieee_round_to_nearest_even() {
        // Exactly representable values round-trip bit-perfectly.
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1035156e-5, 5.9604645e-8] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "round trip of {x}");
        }
        // Known bit patterns (cross-checked against numpy float16).
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        // Ties round to even mantissa: 1 + 2^-11 is exactly between
        // 1.0 (even) and 1 + 2^-10; 1 + 3*2^-11 between 1 + 2^-10 (odd)
        // and 1 + 2^-9 (even).
        assert_eq!(f32_to_f16_bits(1.0 + f32::powi(2.0, -11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * f32::powi(2.0, -11)), 0x3c02);
        // Overflow saturates to inf; tiny magnitudes flush to signed zero.
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000, "negative underflow keeps its sign");
        // NaN survives the round trip as NaN; infinities as infinities.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // Subnormal f16s widen exactly (man * 2^-24).
        assert_eq!(f16_bits_to_f32(0x0001), f32::powi(2.0, -24));
        assert_eq!(f16_bits_to_f32(0x8001), -f32::powi(2.0, -24));
    }

    #[test]
    fn bf16_conversion_truncates_with_round_to_nearest_even() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 3.0e38, 1.175e-38, 256.0] {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let rel = ((rt - x) / if x == 0.0 { 1.0 } else { x }).abs();
            assert!(rel <= f32::powi(2.0, -8), "bf16({x}) came back {rt}");
        }
        // bf16 is f32's top half: values with <= 7 mantissa bits are exact.
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.5)), 1.5);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        // Tie cases on the dropped 16 bits round to even.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f80_8000)), 0x3f80);
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f81_8000)), 0x3f82);
        // Full f32 exponent range survives (where f16 would saturate).
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(1e30)).is_finite());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn half_cache_reads_match_the_narrow_widen_mirror() {
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let mut kv = KvCache::new_with_dtype(2, 4, 3, dtype);
            let k_rows: Vec<f32> = (0..6).map(|i| 0.1 + i as f32 * 0.7).collect();
            let v_rows: Vec<f32> = k_rows.iter().map(|x| -x * 3.3).collect();
            for l in 0..2 {
                kv.write(l, &k_rows, &v_rows).unwrap();
            }
            kv.advance(2).unwrap();
            let mirror = |xs: &[f32]| -> Vec<f32> {
                xs.iter()
                    .map(|&x| match dtype {
                        KvDtype::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
                        KvDtype::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
                        KvDtype::F32 => x,
                    })
                    .collect()
            };
            let (want_k, want_v) = (mirror(&k_rows), mirror(&v_rows));
            for l in 0..2 {
                let (kc, vc) = kv.layer_upto(l, 2);
                assert_eq!(kc, &want_k[..], "{} keys, layer {l}", dtype.name());
                assert_eq!(vc, &want_v[..], "{} values, layer {l}", dtype.name());
            }
        }
    }

    #[test]
    fn half_dtypes_halve_every_byte_account() {
        let fill = |kv: &mut KvCache| {
            for l in 0..3 {
                let w = kv.dkv();
                kv.write(l, &vec![0.25; 5 * w], &vec![0.5; 5 * w]).unwrap();
            }
            kv.advance(5).unwrap();
        };
        let mut full = KvCache::new(3, 8, 4);
        fill(&mut full);
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let mut half = KvCache::new_with_dtype(3, 8, 4, dtype);
            fill(&mut half);
            assert_eq!(half.dtype(), dtype);
            assert_eq!(half.live_bytes() * 2, full.live_bytes());
            assert_eq!(half.live_bytes(), 2 * 3 * 5 * 4 * 2);
            assert_eq!(half.alloc_bytes() * 2, full.alloc_bytes());
            assert_eq!(half.step_bytes(None) * 2, full.step_bytes(None));
            assert_eq!(half.step_bytes(Some(3)) * 2, full.step_bytes(Some(3)));
        }
    }

    #[test]
    fn kv_dtype_parses_and_names_round_trip() {
        for dt in [KvDtype::F32, KvDtype::F16, KvDtype::Bf16] {
            assert_eq!(KvDtype::parse(dt.name()).unwrap(), dt);
        }
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.bytes(), 4);
        assert_eq!(KvDtype::F16.bytes(), 2);
        assert_eq!(KvDtype::Bf16.bytes(), 2);
        assert!(KvDtype::parse("f64").is_err());
        assert!(KvDtype::parse("half").is_err());
    }

    #[test]
    fn bad_writes_are_rejected() {
        let mut kv = KvCache::new(1, 4, 3);
        assert!(kv.write(1, &[0.0; 3], &[0.0; 3]).is_err(), "bad layer");
        assert!(kv.write(0, &[0.0; 2], &[0.0; 2]).is_err(), "not a row multiple");
        assert!(kv.write(0, &[0.0; 3], &[0.0; 6]).is_err(), "k/v mismatch");
        assert!(kv.write(0, &[], &[]).is_err(), "empty write");
    }

    #[test]
    fn table_take_put_back_roundtrip() {
        let tab = SessionTable::new();
        let id = tab.insert(41u64);
        assert_eq!(tab.with(id, |s| *s), Ok(41));
        let mut s = tab.take(id).unwrap();
        *s += 1;
        // Mid-step: a second take and a stats peek both see Busy.
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Busy);
        assert_eq!(tab.with(id, |s| *s).unwrap_err(), TakeError::Busy);
        assert!(tab.put_back(id, s));
        assert_eq!(tab.with(id, |s| *s), Ok(42));
        assert!(tab.close(id));
        assert_eq!(tab.take(id).unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn table_close_during_step_drops_state() {
        let tab = SessionTable::new();
        let id = tab.insert("state".to_string());
        let s = tab.take(id).unwrap();
        assert!(tab.close(id), "closing a busy session removes the marker");
        assert!(!tab.put_back(id, s), "put-back after close must drop, not resurrect");
        assert_eq!(tab.with(id, |s| s.clone()).unwrap_err(), TakeError::Unknown);
        assert!(tab.is_empty());
    }

    #[test]
    fn table_ids_are_unique_and_nonzero() {
        let tab = SessionTable::new();
        let a = tab.insert(0u8);
        let b = tab.insert(1u8);
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(tab.len(), 2);
    }

    #[test]
    fn table_ids_snapshot_is_sorted() {
        let tab = SessionTable::new();
        let a = tab.insert(0u8);
        let b = tab.insert(1u8);
        let c = tab.insert(2u8);
        assert_eq!(tab.ids(), vec![a, b, c]);
        tab.close(b);
        assert_eq!(tab.ids(), vec![a, c]);
    }

    // ---- paged allocator ----

    fn pool(block_len: usize, pool_blocks: usize, dtype: KvDtype) -> Arc<BlockPool> {
        let cfg = PagedConfig { block_len, pool_blocks, spill_dir: None };
        BlockPool::new(&cfg, 2, 3, dtype).unwrap()
    }

    /// Deterministic KV row for (layer, token, dim) — prefix sharing is
    /// sound exactly because equal tokens produce equal rows.
    fn row(l: usize, token: i32, dkv: usize, v_side: bool) -> Vec<f32> {
        (0..dkv)
            .map(|d| {
                let s = if v_side { -1.0 } else { 1.0 };
                s * (0.05 + l as f32 * 1.5 + token as f32 * 0.37 + d as f32 * 0.011)
            })
            .collect()
    }

    fn fill_paged(kv: &mut PagedKvCache, tokens: &[i32], from: usize) {
        let dkv = kv.dkv();
        for &t in &tokens[from..] {
            for l in 0..kv.n_layers() {
                kv.write(l, &row(l, t, dkv, false), &row(l, t, dkv, true)).unwrap();
            }
            kv.advance(1).unwrap();
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sqa-paged-{}-{name}.kv", std::process::id()))
    }

    #[test]
    fn paged_reads_match_contiguous_bitwise_per_dtype() {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Bf16] {
            // 7 rows over block_len 3: two full blocks + a partial tail.
            let tokens: Vec<i32> = (0..7).collect();
            let p = pool(3, 8, dtype);
            let mut paged = PagedKvCache::new(Arc::clone(&p), 10);
            let mut contig = KvCache::new_with_dtype(2, 10, 3, dtype);
            fill_paged(&mut paged, &tokens, 0);
            for &t in &tokens {
                for l in 0..2 {
                    contig.write(l, &row(l, t, 3, false), &row(l, t, 3, true)).unwrap();
                }
                contig.advance(1).unwrap();
            }
            assert_eq!(paged.len(), contig.len());
            assert_eq!(paged.live_bytes(), contig.live_bytes());
            assert_eq!(paged.step_bytes(Some(4)), contig.step_bytes(Some(4)));
            for l in 0..2 {
                for rows in [1, 3, 6, 7] {
                    let (pk, pv) = paged.layer_upto(l, rows).unwrap();
                    let (pk, pv) = (pk.to_vec(), pv.to_vec());
                    let (ck, cv) = contig.layer_upto(l, rows);
                    assert_eq!(pk, ck, "{} keys l={l} rows={rows}", dtype.name());
                    assert_eq!(pv, cv, "{} values l={l} rows={rows}", dtype.name());
                }
            }
            // 3 blocks mapped (ceil(7/3)); resident accounting is exact.
            assert_eq!(paged.resident_blocks(), 3);
            assert_eq!(paged.alloc_bytes(), 3 * p.block_bytes());
            let st = p.stats();
            assert_eq!(st.blocks_in_use(), 3);
            assert_eq!(st.resident_bytes(), 3 * st.block_bytes);
        }
    }

    #[test]
    fn paged_capacity_errors_match_contiguous_strings() {
        let p = pool(2, 8, KvDtype::F32);
        let mut kv = PagedKvCache::new(p, 3);
        fill_paged(&mut kv, &[0, 1, 2], 0);
        let e = kv.write(0, &[0.0; 3], &[0.0; 3]).unwrap_err().to_string();
        assert!(e.contains("session at capacity"), "got: {e}");
        let e = kv.advance(1).unwrap_err().to_string();
        assert!(e.contains("advance past capacity"), "got: {e}");
    }

    #[test]
    fn exhausted_pool_fails_loudly_then_recovers_on_free() {
        let p = pool(2, 2, KvDtype::F32);
        let mut a = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut a, &[0, 1, 2, 3], 0); // both blocks taken
        let mut b = PagedKvCache::new(Arc::clone(&p), 8);
        let e = b
            .write(0, &row(0, 9, 3, false), &row(0, 9, 3, true))
            .unwrap_err()
            .to_string();
        assert!(e.contains("block pool exhausted"), "got: {e}");
        drop(a); // returns both blocks
        assert_eq!(p.stats().blocks_free, 2);
        fill_paged(&mut b, &[9, 9], 0);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn prefix_sharing_cows_on_mid_block_divergence() {
        let p = pool(4, 16, KvDtype::F32);
        let ns = 7u64;
        let a_tokens: Vec<i32> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p), 16);
        fill_paged(&mut a, &a_tokens, 0);
        a.publish_prefix(ns, &a_tokens);
        assert_eq!(p.stats().blocks_in_use(), 2, "A's 2 blocks, trie shares them");

        // B agrees for 6 tokens, diverging mid-way through A's 2nd block.
        let b_tokens = vec![0, 1, 2, 3, 4, 5, 9, 9];
        let (blocks, hit) = p.prefix_lookup(ns, &b_tokens);
        assert_eq!(hit, 6, "one exact chunk + 2-token partial match");
        assert_eq!(blocks.len(), 2);
        let mut b = PagedKvCache::new(Arc::clone(&p), 16);
        b.adopt_prefix(blocks, hit).unwrap();
        assert_eq!(p.stats().cow_splits, 0);
        fill_paged(&mut b, &b_tokens, hit); // writes rows 6,7 -> COW block 1
        assert_eq!(p.stats().cow_splits, 1, "exactly one split, on first write");
        assert_eq!(p.stats().blocks_in_use(), 3, "block 0 still shared");

        // Both sessions now read exactly their own token streams; A's
        // shared block was never written in place.
        for (kv, toks) in [(&mut a, &a_tokens), (&mut b, &b_tokens)] {
            for l in 0..2 {
                let want_k: Vec<f32> = toks.iter().flat_map(|&t| row(l, t, 3, false)).collect();
                let want_v: Vec<f32> = toks.iter().flat_map(|&t| row(l, t, 3, true)).collect();
                let (k, v) = kv.layer_upto(l, 8).unwrap();
                assert_eq!(k, &want_k[..], "layer {l}");
                assert_eq!(v, &want_v[..], "layer {l}");
            }
        }
        let st = p.stats();
        assert!(st.prefix_hits >= 1 && st.prefix_hit_tokens >= 6);
        assert!(st.prefix_hit_rate() > 0.0);
    }

    #[test]
    fn trie_only_blocks_are_reclaimed_lru_under_pressure() {
        let p = pool(2, 2, KvDtype::F32);
        let ns = 1u64;
        let a_tokens = vec![10, 11, 12, 13];
        let mut a = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut a, &a_tokens, 0);
        a.publish_prefix(ns, &a_tokens);
        drop(a); // blocks now held only by the trie
        let st = p.stats();
        assert_eq!(st.blocks_free, 0);
        assert_eq!(st.blocks_reclaimable, 2);

        // A different prompt needs blocks: the trie leaf (deepest chunk)
        // is reclaimed first, then its parent.
        let mut b = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut b, &[50, 51, 52, 53], 0);
        assert_eq!(b.len(), 4);
        let (_, hit) = p.prefix_lookup(ns, &a_tokens);
        assert_eq!(hit, 0, "reclaimed prefixes are gone from the trie");
    }

    #[test]
    fn spill_restore_round_trips_bitwise_and_removes_file() {
        for dtype in [KvDtype::F32, KvDtype::F16] {
            let p = pool(2, 8, dtype);
            let mut kv = PagedKvCache::new(Arc::clone(&p), 8);
            fill_paged(&mut kv, &[3, 1, 4, 1, 5], 0);
            let mut want = Vec::new();
            for l in 0..2 {
                let (k, v) = kv.layer_upto(l, 5).unwrap();
                want.push((k.to_vec(), v.to_vec()));
            }
            let path = tmp_path(&format!("roundtrip-{}", dtype.name()));
            let n = kv.spill(path.clone()).unwrap();
            assert_eq!(n, 3, "all 3 exclusive blocks spill");
            assert!(kv.is_spilled() && path.exists());
            assert_eq!(kv.resident_blocks(), 0);
            assert_eq!(kv.alloc_bytes(), 0);
            let st = p.stats();
            assert_eq!((st.blocks_free, st.blocks_spilled, st.evictions), (8, 3, 3));
            assert!(kv.layer_upto(0, 5).is_err(), "no reads while spilled");

            kv.ensure_resident().unwrap();
            assert!(!kv.is_spilled() && !path.exists(), "restore consumes the file");
            for l in 0..2 {
                let (k, v) = kv.layer_upto(l, 5).unwrap();
                assert_eq!((k, v), (&want[l].0[..], &want[l].1[..]), "{}", dtype.name());
            }
            assert_eq!(p.stats().restores, 3);
            assert_eq!(p.stats().blocks_spilled, 0);
            kv.ensure_resident().unwrap(); // idempotent no-op
        }
    }

    #[test]
    fn truncated_spill_file_fails_loudly_and_stays_retryable() {
        let p = pool(2, 8, KvDtype::F32);
        let mut kv = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut kv, &[1, 2, 3], 0);
        let path = tmp_path("truncated");
        kv.spill(path.clone()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let e = kv.ensure_resident().unwrap_err().to_string();
        assert!(e.contains("spill file truncated"), "got: {e}");
        assert!(kv.is_spilled(), "failed restore keeps the spill state");
        // Repairing the file makes the same restore succeed.
        std::fs::write(&path, &bytes).unwrap();
        kv.ensure_resident().unwrap();
        assert_eq!(kv.layer_upto(0, 3).unwrap().0, &row(0, 1, 3, false)[..3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn restore_into_exhausted_pool_leaves_spill_intact() {
        let p = pool(2, 2, KvDtype::F32);
        let mut a = PagedKvCache::new(Arc::clone(&p), 4);
        fill_paged(&mut a, &[1, 2, 3], 0);
        let path = tmp_path("exhausted-restore");
        a.spill(path.clone()).unwrap();
        let mut b = PagedKvCache::new(Arc::clone(&p), 4);
        fill_paged(&mut b, &[7, 8, 9], 0); // takes both blocks
        let e = a.ensure_resident().unwrap_err().to_string();
        assert!(e.contains("block pool exhausted"), "got: {e}");
        assert!(a.is_spilled() && path.exists());
        drop(b);
        a.ensure_resident().unwrap();
        assert_eq!(a.layer_upto(1, 3).unwrap().1, &[
            row(1, 1, 3, true),
            row(1, 2, 3, true),
            row(1, 3, 3, true)
        ]
        .concat()[..]);
    }

    #[test]
    fn drop_while_spilled_frees_blocks_and_disk() {
        let p = pool(2, 4, KvDtype::F32);
        let mut kv = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut kv, &[1, 2, 3, 4], 0);
        let path = tmp_path("drop-spilled");
        kv.spill(path.clone()).unwrap();
        assert!(path.exists());
        drop(kv);
        assert!(!path.exists(), "close-while-spilled removes the spill file");
        let st = p.stats();
        assert_eq!((st.blocks_free, st.blocks_spilled), (4, 0));
    }

    #[test]
    fn shared_blocks_do_not_spill() {
        let p = pool(2, 8, KvDtype::F32);
        let ns = 3u64;
        let tokens = vec![1, 2, 3, 4];
        let mut kv = PagedKvCache::new(Arc::clone(&p), 8);
        fill_paged(&mut kv, &tokens, 0);
        kv.publish_prefix(ns, &tokens);
        let path = tmp_path("shared-nospill");
        // Every block is trie-shared: nothing exclusive, nothing spilled.
        assert_eq!(kv.spill(path.clone()).unwrap(), 0);
        assert!(!kv.is_spilled() && !path.exists());
    }

    #[test]
    fn pool_stats_absorb_sums_counters() {
        let a = pool(2, 4, KvDtype::F32);
        let b = pool(8, 2, KvDtype::F16);
        let mut kv = PagedKvCache::new(Arc::clone(&a), 4);
        fill_paged(&mut kv, &[1, 2, 3], 0);
        let mut merged = a.stats();
        merged.absorb(&b.stats());
        assert_eq!(merged.blocks_total, 6);
        assert_eq!(merged.blocks_free, 2 + 2);
        assert_eq!(merged.allocs, 2);
        assert_eq!(merged.block_len, 2, "first pool's geometry wins");
    }

    #[test]
    fn paged_config_parsing_gates_on_block_len() {
        assert!(PagedConfig::from_vars(None, None, None).is_none());
        assert!(PagedConfig::from_vars(Some("0"), Some("128"), None).is_none());
        assert!(PagedConfig::from_vars(Some("nope"), None, None).is_none());
        let cfg = PagedConfig::from_vars(Some("16"), Some("128"), Some("/tmp/sqa-spill")).unwrap();
        assert_eq!((cfg.block_len, cfg.pool_blocks), (16, 128));
        assert_eq!(cfg.spill_dir.as_deref(), Some(std::path::Path::new("/tmp/sqa-spill")));
        let cfg = PagedConfig::from_vars(Some("8"), None, Some("")).unwrap();
        assert_eq!((cfg.block_len, cfg.pool_blocks), (8, 4096));
        assert!(cfg.spill_dir.is_none());
    }

    #[test]
    fn session_cache_delegates_both_tiers() {
        let p = pool(2, 4, KvDtype::F32);
        let mut paged = SessionCache::Paged(PagedKvCache::new(p, 4));
        let mut contig = SessionCache::Contig(KvCache::new(2, 4, 3));
        for kv in [&mut paged, &mut contig] {
            for l in 0..2 {
                kv.write(l, &row(l, 5, 3, false), &row(l, 5, 3, true)).unwrap();
            }
            kv.advance(1).unwrap();
            kv.ensure_resident().unwrap();
            assert_eq!(kv.len(), 1);
            assert_eq!(kv.step_bytes(None), 2 * 2 * 3 * 4);
        }
        let (pk, _) = paged.layer_upto(0, 1).unwrap();
        let pk = pk.to_vec();
        let (ck, _) = contig.layer_upto(0, 1).unwrap();
        assert_eq!(pk, ck);
        assert!(paged.as_paged_mut().is_some());
        assert!(contig.as_paged_mut().is_none());
    }
}
