//! The execution-backend abstraction — the seam between the serving/training
//! layers and whatever actually runs the math.
//!
//! Two implementations:
//!   * [`crate::runtime::NativeBackend`] (default): pure Rust on top of the
//!     `attention` oracle; runs everywhere, no Python/XLA/artifacts.
//!   * `PjrtBackend` (`--features pjrt`): the AOT HLO artifact path through
//!     the PJRT C API.
//!
//! The contract is host-centric: parameters and the fused train state
//! (`[params | m | v | loss, acc]`) travel as flat `f32` slices, tokens as
//! row-major `[batch, seq]` `i32`, logits as `[batch, seq, vocab]` `f32`.
//! Backends are free to keep device-side caches internally.

use crate::runtime::manifest::{FamilyEntry, VariantEntry};
pub use crate::runtime::session::KvPoolStats;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// KV-cache accounting for one live decode session.
///
/// `kv_bytes` is the cache traffic of one decode step at the current
/// length — the §5.2 memory-bound cost, directly comparable to
/// [`crate::flops::decode::DecodeStep::kv_bytes`]; `alloc_bytes` is the
/// session's allocated footprint (capacity, what it costs in RSS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionStats {
    /// Cached token rows (prompt + generated so far).
    pub len: usize,
    /// Max token rows the session can hold.
    pub capacity: usize,
    /// K/V bytes one decode step streams:
    /// `2·layers·rows·Hkv·dh·dtype_bytes` (4 for f32 caches, 2 for
    /// f16/bf16 — [`crate::runtime::session::KvDtype::bytes`]), where a
    /// sliding window caps `rows` at `min(len, window)` exactly like the
    /// roofline's `eff_s` (mask-aware tile skipping never reads older
    /// tiles).
    pub kv_bytes: u64,
    /// Allocated K/V bytes: `2·layers·capacity·Hkv·dh·dtype_bytes`.
    pub alloc_bytes: u64,
}

/// An engine capable of running the SQA model zoo.
pub trait Backend: Send + Sync {
    /// Short backend id ("native", "pjrt") for logs and reports.
    fn name(&self) -> &'static str;

    /// Model catalog: family geometry + variant head configs + param layout.
    fn families(&self) -> &BTreeMap<String, FamilyEntry>;

    /// Sequence buckets with a forward entry point for (family, variant).
    fn fwd_buckets(&self, family: &str, variant: &str) -> Vec<usize>;

    /// Max batch rows of the fwd entry point for a sequence bucket.
    fn fwd_batch(&self, family: &str, variant: &str, seq: usize) -> Result<usize>;

    /// Whether fwd batches must be padded to exactly [`Backend::fwd_batch`]
    /// rows (fixed-shape compiled artifacts) or may be ragged (native).
    fn fixed_fwd_batch(&self) -> bool {
        false
    }

    /// (batch, seq) of the training entry point.
    fn train_shape(&self, family: &str, variant: &str) -> Result<(usize, usize)>;

    /// Deterministically initialize the flat parameter vector from a seed.
    fn init_params(&self, family: &str, variant: &str, seed: i32) -> Result<Vec<f32>>;

    /// Forward pass: `tokens [batch, seq]` -> logits `[batch, seq, vocab]`.
    fn forward(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<Vec<f32>>;

    /// One fused AdamW step over `state = [params | m | v | loss, acc]`
    /// (updated in place); returns the step's (loss, accuracy), which are
    /// also written into the 2-float metrics tail.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        family: &str,
        variant: &str,
        state: &mut [f32],
        step: i32,
        lr: f32,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)>;

    /// [`Backend::train_step`] through a specific attention lowering — the
    /// same `kernel[+linalg]` strings as [`Backend::forward_impl`]. Both
    /// halves of the fused step run on the selected pair: the forward
    /// streams (or materializes) attention with that kernel, and the
    /// backward runs the matching gradient path (flash-style streaming
    /// backward for `tiled`, the scalar row-loop oracle for `naive`).
    /// Backends without switchable training lowerings reject.
    #[allow(clippy::too_many_arguments)]
    fn train_step_impl(
        &self,
        impl_: &str,
        _family: &str,
        _variant: &str,
        _state: &mut [f32],
        _step: i32,
        _lr: f32,
        _tokens: &[i32],
        _targets: &[i32],
        _batch: usize,
        _seq: usize,
    ) -> Result<(f32, f32)> {
        bail!("backend {:?} has no train impl {impl_:?}", self.name())
    }

    /// Mean (loss, accuracy) of `params` on one batch.
    #[allow(clippy::too_many_arguments)]
    fn eval(
        &self,
        family: &str,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<(f32, f32)>;

    /// Attention lowerings this backend can ablate over (bench harness).
    fn impls(&self) -> Vec<&'static str> {
        Vec::new()
    }

    /// Forward pass through a specific attention lowering.
    #[allow(clippy::too_many_arguments)]
    fn forward_impl(
        &self,
        impl_: &str,
        _family: &str,
        _variant: &str,
        _params: &[f32],
        _tokens: &[i32],
        _batch: usize,
        _seq: usize,
    ) -> Result<Vec<f32>> {
        bail!("backend {:?} has no attention impl {impl_:?}", self.name())
    }

    // ---- stateful generation (prefill + incremental decode) -------------

    /// Whether [`Backend::prefill`] / [`Backend::decode_step`] work.
    fn supports_decode(&self) -> bool {
        false
    }

    /// Create a generation session: run the prompt through the model once
    /// (the compute-bound prefill phase), filling per-layer KV caches sized
    /// `capacity` tokens. Returns the session id and the last prompt
    /// position's logits `[vocab]` (what the first generated token is
    /// sampled from). Fails if the prompt is longer than `capacity`.
    fn prefill(
        &self,
        _family: &str,
        _variant: &str,
        _params: &[f32],
        _tokens: &[i32],
        _capacity: usize,
    ) -> Result<(u64, Vec<f32>)> {
        bail!("backend {:?} has no incremental decode path", self.name())
    }

    /// [`Backend::prefill`] through a specific attention lowering — the same
    /// `kernel[+linalg][@pattern]` strings as [`Backend::forward_impl`]. The
    /// session remembers the selection: every subsequent
    /// [`Backend::decode_step`] masks its cached positions by the same
    /// pattern rules the prefill ran with.
    fn prefill_impl(
        &self,
        impl_: &str,
        _family: &str,
        _variant: &str,
        _params: &[f32],
        _tokens: &[i32],
        _capacity: usize,
    ) -> Result<(u64, Vec<f32>)> {
        bail!(
            "backend {:?} has no incremental decode path for impl {impl_:?}",
            self.name()
        )
    }

    /// Append a further chunk of *prompt* tokens to an existing session's
    /// KV cache (chunked prefill): runs the chunk through the model at the
    /// session's current length and returns the chunk's last position's
    /// logits `[vocab]`. The scheduler uses this to interleave long
    /// prompts with other sessions' decode steps; only the final chunk's
    /// logits are ever sampled. Fails — leaving the session alive — when
    /// the chunk would overflow the cache capacity.
    fn prefill_extend(&self, _session: u64, _params: &[f32], _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!("backend {:?} has no chunked prefill path", self.name())
    }

    /// One incremental decode step: append `token` to the session's cache
    /// and return the new position's logits `[vocab]` (memory-bound: the
    /// step streams the whole cache but computes only one query row).
    /// Fails — leaving the session alive — when the cache is at capacity.
    fn decode_step(&self, _session: u64, _params: &[f32], _token: i32) -> Result<Vec<f32>> {
        bail!("backend {:?} has no incremental decode path", self.name())
    }

    /// Close a session and free its KV cache; `false` if unknown. Safe to
    /// call while a step is in flight (the state is dropped when the step
    /// completes).
    fn close_session(&self, _session: u64) -> bool {
        false
    }

    /// KV-cache accounting for a live session.
    fn session_stats(&self, session: u64) -> Result<SessionStats> {
        bail!("backend {:?} has no decode session {session}", self.name())
    }

    /// Merged paged-KV block-pool view (free/used/spilled blocks plus the
    /// allocator's lifetime counters), or `None` when the backend serves
    /// contiguous per-session caches. Admission control uses the
    /// block-granular headroom here; `/metrics` and the decode bench
    /// surface the counters.
    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        None
    }

    // ---- provided lookups ----------------------------------------------

    fn family(&self, name: &str) -> Result<&FamilyEntry> {
        self.families().get(name).with_context(|| {
            format!(
                "family {name:?} unknown to the {} backend (have: {:?})",
                self.name(),
                self.families().keys().collect::<Vec<_>>()
            )
        })
    }

    fn variant(&self, family: &str, variant: &str) -> Result<&VariantEntry> {
        self.family(family)?
            .variants
            .get(variant)
            .with_context(|| format!("variant {variant:?} not in family {family:?}"))
    }
}

/// Open the default backend for this build.
///
/// Native unless the `pjrt` feature is enabled *and* `<dir>/manifest.json`
/// exists (i.e. `make artifacts` ran). `SQA_BACKEND=native|pjrt` overrides
/// the choice explicitly.
pub fn open_backend(artifact_dir: impl AsRef<Path>) -> Result<Arc<dyn Backend>> {
    let dir = artifact_dir.as_ref();
    let want = std::env::var("SQA_BACKEND").unwrap_or_default();

    #[cfg(feature = "pjrt")]
    {
        let has_manifest = dir.join("manifest.json").exists();
        if want == "pjrt" || (want.is_empty() && has_manifest) {
            let backend = crate::runtime::pjrt::PjrtBackend::new(dir)?;
            log::info!("backend: pjrt (artifacts in {})", dir.display());
            return Ok(Arc::new(backend));
        }
    }
    #[cfg(not(feature = "pjrt"))]
    if want == "pjrt" {
        bail!("SQA_BACKEND=pjrt but this binary was built without `--features pjrt`");
    }

    if !want.is_empty() && want != "native" {
        bail!("unknown SQA_BACKEND {want:?} (native|pjrt)");
    }
    let _ = dir;
    log::debug!("backend: native");
    Ok(Arc::new(crate::runtime::native::NativeBackend::new()))
}
