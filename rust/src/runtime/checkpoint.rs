//! Backend-agnostic checkpoints: raw little-endian f32 parameters plus a
//! JSON sidecar (`<path>.meta.json`) recording family/variant/step, so a
//! restore is validated against the catalog before it is served or trained.
//!
//! Both backends share this one on-disk format (the PJRT `ModelState`
//! delegates here), but a checkpoint is only loadable by a backend whose
//! parameter layout for that (family, variant) matches the producer's —
//! the native catalog model and the PJRT manifest model differ (e.g. no
//! MLP natively), and the size/ids validation below rejects mismatches.

use crate::runtime::backend::Backend;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

fn meta_path(path: &Path) -> PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".meta.json");
    PathBuf::from(p)
}

/// Write `params` (+ sidecar) to `path`.
pub fn save(path: &Path, family: &str, variant: &str, step: usize, params: &[f32]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let bytes: Vec<u8> = params.iter().flat_map(|x| x.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    let meta = Json::obj(vec![
        ("family", Json::str(family)),
        ("variant", Json::str(variant)),
        ("n_params", Json::num(params.len() as f64)),
        ("step", Json::num(step as f64)),
    ]);
    std::fs::write(meta_path(path), meta.to_string())?;
    Ok(())
}

/// Load a checkpoint, validating ids and size against the backend catalog.
/// Returns the parameter vector and the recorded step.
pub fn load(
    backend: &dyn Backend,
    family: &str,
    variant: &str,
    path: &Path,
) -> Result<(Vec<f32>, usize)> {
    let entry = backend.variant(family, variant)?;
    load_file(path, family, variant, entry.n_params)
}

/// Catalog-free core of [`load`]: validate the sidecar against the expected
/// ids and parameter count, then read the raw f32 vector. The PJRT
/// `ModelState` path reuses this so both backends share one on-disk format.
pub fn load_file(
    path: &Path,
    family: &str,
    variant: &str,
    n_params: usize,
) -> Result<(Vec<f32>, usize)> {
    let meta_text = std::fs::read_to_string(meta_path(path))
        .with_context(|| format!("reading {}", meta_path(path).display()))?;
    let meta = Json::parse(&meta_text)?;
    let m_family = meta.req("family")?.as_str().unwrap_or_default();
    let m_variant = meta.req("variant")?.as_str().unwrap_or_default();
    if m_family != family || m_variant != variant {
        bail!("checkpoint is for {m_family}/{m_variant}, wanted {family}/{variant}");
    }
    let step = meta.req("step")?.as_usize().context("step")?;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != n_params * 4 {
        bail!(
            "checkpoint has {} bytes, expected {} ({n_params} params)",
            bytes.len(),
            n_params * 4
        );
    }
    let params: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((params, step))
}
