//! Autoregressive-decode model — the paper's *other* bottleneck (§2.2, §5).
//!
//! Token-by-token generation is memory-bandwidth bound: each step must
//! stream the whole KV cache from HBM. SQA does not help here (its win is
//! compute), and the paper is explicit about the trade-off (§5.1–5.2):
//!
//!   * sSQA (Hkv = H/2) carries a *larger* KV cache than GQA (Hkv = H/4) —
//!     slower decode, a deliberate quality choice;
//!   * xSQA (Hq = Hkv = H/4) matches GQA's cache exactly — identical
//!     decode, while still 4x cheaper in prefill compute.
//!
//! This module is a roofline-style simulator of one decode step: time =
//! max(bytes_moved / bandwidth, flops / compute). It reproduces the
//! paper's §5.2 comparisons quantitatively and powers
//! `sqa flops --decode` and the decode unit tests.

use crate::config::{ModelDims, VariantCfg};

/// Hardware envelope for the roofline (defaults ≈ one A100-40GB,
/// the paper's benchmark card).
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    pub hbm_bytes_per_s: f64,
    pub flops_per_s: f64,
}

impl Default for Hardware {
    fn default() -> Self {
        Self {
            hbm_bytes_per_s: 1.555e12, // A100 40GB HBM2e
            flops_per_s: 19.5e12,      // A100 f32 tensor-core sustained
        }
    }
}

/// Breakdown of one decode step at context length `s`.
#[derive(Debug, Clone, Copy)]
pub struct DecodeStep {
    /// Bytes streamed from HBM: KV cache + parameters.
    pub kv_bytes: u64,
    pub param_bytes: u64,
    /// FLOPs of the step (attention over cache + projections/MLP).
    pub flops: u64,
    /// Roofline times (seconds).
    pub mem_time: f64,
    pub compute_time: f64,
}

impl DecodeStep {
    pub fn time(&self) -> f64 {
        self.mem_time.max(self.compute_time)
    }

    /// True when the step is memory-bandwidth bound (the paper's premise
    /// for long contexts).
    pub fn memory_bound(&self) -> bool {
        self.mem_time >= self.compute_time
    }
}

/// Model one autoregressive decode step at context length `s`.
///
/// Parameter count is approximated from dims (tied embeddings); f32 cache.
/// [`decode_step_dtype`] models narrower cache storage.
pub fn decode_step(dims: &ModelDims, var: &VariantCfg, s: u64, hw: Hardware) -> DecodeStep {
    decode_step_dtype(dims, var, s, hw, 4)
}

/// [`decode_step`] with the cache term at `kv_elem_bytes` per element
/// (4 = f32, 2 = f16/bf16 — [`crate::runtime::session::KvDtype::bytes`]).
/// Only the KV traffic scales: weights stay f32 and the FLOPs are
/// unchanged, so halving the element width compresses exactly the §5.2
/// memory-bound term that separates the variants.
pub fn decode_step_dtype(
    dims: &ModelDims,
    var: &VariantCfg,
    s: u64,
    hw: Hardware,
    kv_elem_bytes: u64,
) -> DecodeStep {
    let d = dims.d_model as u64;
    let dh = dims.d_head as u64;
    let layers = dims.n_layers as u64;
    let ff = dims.d_ff as u64;

    // KV cache streamed once per step (window caps the live cache).
    let eff_s = match var.window {
        Some(w) => s.min(w as u64),
        None => s,
    };
    let kv_bytes = 2 * eff_s * var.hkv as u64 * dh * kv_elem_bytes * layers;

    // Parameters streamed once per step (batch 1: no amortization).
    let attn_params = layers * d * dh * (2 * var.hq as u64 + 2 * var.hkv as u64);
    let mlp_params = layers * 3 * d * ff * if dims.n_experts > 0 { dims.n_experts as u64 } else { 1 };
    let embed_params = dims.vocab as u64 * d;
    let param_bytes = (attn_params + mlp_params + embed_params) * 4;

    // FLOPs: attention over the cache (Hq heads x eff_s keys, scores+agg)
    // plus the dense projections/MLP/LM-head for one token.
    let attn_flops = layers * var.hq as u64 * 2 * 2 * eff_s * dh;
    let dense_flops = 2 * (attn_params + mlp_params + embed_params);
    let flops = attn_flops + dense_flops;

    let bytes = kv_bytes + param_bytes;
    DecodeStep {
        kv_bytes,
        param_bytes,
        flops,
        mem_time: bytes as f64 / hw.hbm_bytes_per_s,
        compute_time: flops as f64 / hw.flops_per_s,
    }
}

/// Decode-throughput comparison row (tokens/second at context `s`).
#[derive(Debug, Clone)]
pub struct DecodeRow {
    pub variant: String,
    pub hq: usize,
    pub hkv: usize,
    pub kv_mib: f64,
    pub tok_per_s: f64,
    pub vs_first: f64,
}

/// Build the §5.2 decode comparison across a variant set.
pub fn decode_table(
    dims: &ModelDims,
    variants: &[(String, VariantCfg)],
    s: u64,
    hw: Hardware,
) -> Vec<DecodeRow> {
    let mut rows: Vec<DecodeRow> = Vec::new();
    let mut first_tps = None;
    for (name, v) in variants {
        let step = decode_step(dims, v, s, hw);
        let tps = 1.0 / step.time();
        let base = *first_tps.get_or_insert(tps);
        rows.push(DecodeRow {
            variant: name.clone(),
            hq: v.hq,
            hkv: v.hkv,
            kv_mib: step.kv_bytes as f64 / (1 << 20) as f64,
            tok_per_s: tps,
            vs_first: tps / base,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        // Llama-7B-ish so the memory-bound regime is realistic.
        ModelDims {
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            h_total: 32,
            d_head: 128,
            d_ff: 11008,
            n_experts: 0,
        }
    }

    fn var(hq: usize, hkv: usize) -> VariantCfg {
        VariantCfg { hq, hkv, window: None }
    }

    #[test]
    fn long_context_decode_is_memory_bound() {
        let step = decode_step(&dims(), &var(32, 32), 32_768, Hardware::default());
        assert!(step.memory_bound());
        // MHA cache at 32k: 2*32768*32*128*4*32 = 32 GiB-ish/4 … sanity > params
        assert!(step.kv_bytes > step.param_bytes);
    }

    #[test]
    fn xsqa_matches_gqa_decode_exactly() {
        // §5.2: xSQA(8,8) has the same cache as GQA(32,8) -> same decode
        // time in the memory-bound regime (flops differ but don't matter).
        let hw = Hardware::default();
        let gqa = decode_step(&dims(), &var(32, 8), 262_144, hw);
        let xsqa = decode_step(&dims(), &var(8, 8), 262_144, hw);
        assert_eq!(gqa.kv_bytes, xsqa.kv_bytes);
        // Deep in the cache-bound regime the times converge (xSQA also
        // carries slightly fewer attention weights, so it is never slower).
        assert!(xsqa.time() <= gqa.time());
        assert!((gqa.time() - xsqa.time()) / gqa.time() < 0.05);
    }

    #[test]
    fn ssqa_decodes_slower_than_gqa() {
        // §5.1: sSQA(16,16) carries 2x GQA(32,8)'s cache -> slower decode.
        let hw = Hardware::default();
        let gqa = decode_step(&dims(), &var(32, 8), 65_536, hw);
        let ssqa = decode_step(&dims(), &var(16, 16), 65_536, hw);
        assert_eq!(ssqa.kv_bytes, 2 * gqa.kv_bytes);
        assert!(ssqa.time() > gqa.time());
    }

    #[test]
    fn mqa_is_fastest_decoder() {
        let hw = Hardware::default();
        let rows = decode_table(
            &dims(),
            &[
                ("mha".into(), var(32, 32)),
                ("gqa".into(), var(32, 8)),
                ("mqa".into(), var(32, 1)),
                ("ssqa".into(), var(16, 16)),
            ],
            131_072,
            hw,
        );
        let tps: std::collections::HashMap<_, _> =
            rows.iter().map(|r| (r.variant.clone(), r.tok_per_s)).collect();
        assert!(tps["mqa"] > tps["gqa"]);
        assert!(tps["gqa"] > tps["ssqa"]);
        assert!(tps["ssqa"] > tps["mha"]);
    }

    #[test]
    fn half_precision_cache_halves_the_kv_term_only() {
        let hw = Hardware::default();
        let f32_step = decode_step(&dims(), &var(32, 8), 131_072, hw);
        let f16_step = decode_step_dtype(&dims(), &var(32, 8), 131_072, hw, 2);
        assert_eq!(2 * f16_step.kv_bytes, f32_step.kv_bytes);
        assert_eq!(f16_step.param_bytes, f32_step.param_bytes);
        assert_eq!(f16_step.flops, f32_step.flops);
        assert!(f16_step.time() < f32_step.time(), "less traffic, faster step");
        // The §5 ordering is a ratio of Hkv, so it survives the dtype
        // change: xSQA == GQA < sSQA bytes at 2 bytes/elem too.
        let gqa = decode_step_dtype(&dims(), &var(32, 8), 131_072, hw, 2);
        let xsqa = decode_step_dtype(&dims(), &var(8, 8), 131_072, hw, 2);
        let ssqa = decode_step_dtype(&dims(), &var(16, 16), 131_072, hw, 2);
        assert_eq!(gqa.kv_bytes, xsqa.kv_bytes);
        assert_eq!(ssqa.kv_bytes, 2 * gqa.kv_bytes);
    }

    #[test]
    fn window_caps_cache_growth() {
        let hw = Hardware::default();
        let swa = VariantCfg {
            hq: 32,
            hkv: 32,
            window: Some(4096),
        };
        let short = decode_step(&dims(), &swa, 8_192, hw);
        let long = decode_step(&dims(), &swa, 1_000_000, hw);
        assert_eq!(short.kv_bytes, long.kv_bytes);
    }

    #[test]
    fn short_context_decode_is_param_bound() {
        // At tiny context the weights dominate the traffic (the paper's
        // "SQA is about prefill" — decode differences shrink to the small
        // attention-weight delta, not the cache).
        let hw = Hardware::default();
        let a = decode_step(&dims(), &var(32, 32), 128, hw);
        let b = decode_step(&dims(), &var(8, 8), 128, hw);
        assert!(a.param_bytes > a.kv_bytes);
        assert!(b.param_bytes > b.kv_bytes);
        // xSQA streams fewer attention weights, so it is (mildly) faster
        // even here — but far less than its 4x prefill advantage.
        assert!(b.time() <= a.time());
        assert!(a.time() / b.time() < 1.5);
    }
}
