//! Analytic complexity model — the paper's §3.2.1, §2.2 and §5.2 math.
//!
//! Computes, for any (variant, model, sequence) combination:
//!   * attention-core FLOPs (scores `QKᵀ` + aggregation `PV`),
//!   * projection FLOPs (Wq/Wk/Wv/Wo, which *shrink* with Hq/Hkv),
//!   * MLP/MoE and LM-head FLOPs (variant-independent),
//!   * KV-cache bytes (the MQA/GQA memory-bandwidth axis),
//!   * the theoretical speed-up `H/Hq` of eq. (9),
//! and renders the comparative table of DESIGN.md §6. The bench harness
//! prints model-predicted ratios next to measured ones so the "shape"
//! claim (who wins, by what factor) is checkable at a glance.

pub mod decode;

use crate::config::{ModelDims, VariantCfg};

/// FLOPs breakdown of one forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlopsBreakdown {
    pub attn_core: u64,
    pub attn_proj: u64,
    pub mlp: u64,
    pub lm_head: u64,
}

impl FlopsBreakdown {
    pub fn total(&self) -> u64 {
        self.attn_core + self.attn_proj + self.mlp + self.lm_head
    }

    /// Fraction of total FLOPs spent in the attention core — the regime
    /// indicator: Table 3's speed-ups appear once this dominates.
    pub fn attn_fraction(&self) -> f64 {
        self.attn_core as f64 / self.total() as f64
    }
}

/// Forward-pass FLOPs for a full model at batch `b`, sequence `s`.
///
/// Matmul of [m,k]x[k,n] counts 2*m*k*n FLOPs.
pub fn forward_flops(dims: &ModelDims, var: &VariantCfg, b: u64, s: u64) -> FlopsBreakdown {
    let d = dims.d_model as u64;
    let dh = dims.d_head as u64;
    let hq = var.hq as u64;
    let hkv = var.hkv as u64;
    let layers = dims.n_layers as u64;
    let ff = dims.d_ff as u64;
    let vocab = dims.vocab as u64;

    // Attention core per layer: Hq heads, scores + aggregation.
    // A sliding window caps the effective key count per query.
    let eff_k = match var.window {
        Some(w) => s.min(w as u64),
        None => s,
    };
    let attn_core = layers * b * hq * (2 * s * eff_k * dh) * 2;

    // Projections: Wq [d, hq*dh], Wk/Wv [d, hkv*dh], Wo [hq*dh, d].
    let proj_cols = (hq * dh) + 2 * (hkv * dh) + (hq * dh);
    let attn_proj = layers * b * s * 2 * d * proj_cols;

    // SwiGLU: gate + up [d, ff] and down [ff, d] = 3 matmuls. MoE (top-k
    // routed, dense-dispatch at our scale) multiplies by active experts.
    let mlp_mults = if dims.n_experts > 0 {
        dims.n_experts as u64 // dense dispatch computes all experts
    } else {
        1
    };
    let mlp = layers * b * s * 2 * (3 * d * ff) * mlp_mults;

    let lm_head = b * s * 2 * d * vocab;

    FlopsBreakdown {
        attn_core,
        attn_proj,
        mlp,
        lm_head,
    }
}

/// Training-step FLOPs ≈ 3x forward (fwd + bwd-activations + bwd-weights).
pub fn train_flops(dims: &ModelDims, var: &VariantCfg, b: u64, s: u64) -> u64 {
    3 * forward_flops(dims, var, b, s).total()
}

/// KV-cache bytes for autoregressive decoding (§2.2): 2 * S * Hkv * dh * 4.
pub fn kv_cache_bytes(dims: &ModelDims, var: &VariantCfg, s: u64) -> u64 {
    2 * s * var.hkv as u64 * dims.d_head as u64 * 4 * dims.n_layers as u64
}

/// Paper eq. (9): theoretical attention-core speed-up over the MHA baseline.
pub fn theoretical_speedup(h_total: usize, hq: usize) -> f64 {
    h_total as f64 / hq as f64
}

/// One row of the comparative table (DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct ComplexityRow {
    pub variant: String,
    pub hq: usize,
    pub hkv: usize,
    pub attn_flops_factor: f64,
    pub kv_cache_factor: f64,
    pub theoretical_speedup: f64,
}

/// Build the complexity-comparison table for a variant set.
pub fn complexity_table(
    dims: &ModelDims,
    variants: &[(String, VariantCfg)],
    s: u64,
) -> Vec<ComplexityRow> {
    let mha = VariantCfg {
        hq: dims.h_total,
        hkv: dims.h_total,
        window: None,
    };
    let base_core = forward_flops(dims, &mha, 1, s).attn_core as f64;
    let base_kv = kv_cache_bytes(dims, &mha, s) as f64;
    variants
        .iter()
        .map(|(name, v)| ComplexityRow {
            variant: name.clone(),
            hq: v.hq,
            hkv: v.hkv,
            attn_flops_factor: forward_flops(dims, v, 1, s).attn_core as f64 / base_core,
            kv_cache_factor: kv_cache_bytes(dims, v, s) as f64 / base_kv,
            theoretical_speedup: theoretical_speedup(dims.h_total, v.hq),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 4096,
            d_model: 256,
            n_layers: 8,
            h_total: 16,
            d_head: 16,
            d_ff: 683,
            n_experts: 0,
        }
    }

    fn var(hq: usize, hkv: usize) -> VariantCfg {
        VariantCfg {
            hq,
            hkv,
            window: None,
        }
    }

    #[test]
    fn eq9_speedup_is_h_over_hq() {
        let d = dims();
        let base = forward_flops(&d, &var(16, 16), 1, 4096).attn_core;
        assert_eq!(base / forward_flops(&d, &var(8, 4), 1, 4096).attn_core, 2);
        assert_eq!(base / forward_flops(&d, &var(4, 4), 1, 4096).attn_core, 4);
        assert_eq!(theoretical_speedup(16, 4), 4.0);
    }

    #[test]
    fn gqa_mqa_do_not_reduce_core_flops() {
        // The paper's central observation (§1.3): KV-head reduction leaves
        // the attention-core FLOPs unchanged.
        let d = dims();
        let mha = forward_flops(&d, &var(16, 16), 1, 2048).attn_core;
        let gqa = forward_flops(&d, &var(16, 4), 1, 2048).attn_core;
        let mqa = forward_flops(&d, &var(16, 1), 1, 2048).attn_core;
        assert_eq!(mha, gqa);
        assert_eq!(mha, mqa);
    }

    #[test]
    fn gqa_mqa_do_reduce_kv_cache() {
        let d = dims();
        let mha = kv_cache_bytes(&d, &var(16, 16), 2048);
        assert_eq!(mha / kv_cache_bytes(&d, &var(16, 4), 2048), 4);
        assert_eq!(mha / kv_cache_bytes(&d, &var(16, 1), 2048), 16);
    }

    #[test]
    fn xsqa_matches_gqa_memory_at_quarter_flops() {
        // §5.2: xSQA(Hq=4, Hkv=4) matches GQA(16,4) KV cache but 4x fewer
        // core FLOPs.
        let d = dims();
        assert_eq!(
            kv_cache_bytes(&d, &var(4, 4), 1024),
            kv_cache_bytes(&d, &var(16, 4), 1024)
        );
        let gqa = forward_flops(&d, &var(16, 4), 1, 1024).attn_core;
        let xsqa = forward_flops(&d, &var(4, 4), 1, 1024).attn_core;
        assert_eq!(gqa / xsqa, 4);
    }

    #[test]
    fn window_caps_core_flops() {
        let d = dims();
        let swa = VariantCfg {
            hq: 16,
            hkv: 16,
            window: Some(128),
        };
        let full = forward_flops(&d, &var(16, 16), 1, 4096).attn_core;
        let windowed = forward_flops(&d, &swa, 1, 4096).attn_core;
        assert_eq!(full / windowed, 4096 / 128);
        // Window larger than seq = no-op.
        let big = VariantCfg {
            hq: 16,
            hkv: 16,
            window: Some(100_000),
        };
        assert_eq!(forward_flops(&d, &big, 1, 512).attn_core, forward_flops(&d, &var(16, 16), 1, 512).attn_core);
    }

    #[test]
    fn attn_fraction_grows_with_seq() {
        let d = dims();
        let short = forward_flops(&d, &var(16, 16), 1, 256).attn_fraction();
        let long = forward_flops(&d, &var(16, 16), 1, 8192).attn_fraction();
        assert!(long > short);
        assert!(long > 0.8, "N^2 term must dominate at 8k: {long}");
    }

    #[test]
    fn complexity_table_factors() {
        let d = dims();
        let rows = complexity_table(
            &d,
            &[
                ("mha".into(), var(16, 16)),
                ("ssqa".into(), var(8, 8)),
                ("xsqa".into(), var(4, 4)),
            ],
            4096,
        );
        assert_eq!(rows[0].attn_flops_factor, 1.0);
        assert_eq!(rows[1].attn_flops_factor, 0.5);
        assert_eq!(rows[2].attn_flops_factor, 0.25);
        assert_eq!(rows[2].kv_cache_factor, 0.25);
    }
}
