//! Deterministic PRNGs (the image lacks the `rand` crate).
//!
//! `SplitMix64` for seeding, `Pcg64` (PCG-XSL-RR 128/64) as the workhorse
//! generator, plus the distributions the data pipeline and property tests
//! need: uniform ints/floats, normals (Box–Muller), Zipf sampling (the
//! synthetic corpus's token distribution), shuffling and choice.

/// SplitMix64 — tiny, solid stream for seeding other generators.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64 — fast 64-bit output, 128-bit state.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state, inc };
        rng.next_u64(); // advance past the seed-correlated first output
        rng
    }

    /// Independent stream `i` of a base seed (for per-worker RNGs).
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        Self::new(seed ^ SplitMix64::new(stream).next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }
}

/// Zipf(α) sampler over `{0, .., n-1}` via precomputed inverse CDF —
/// O(log n) per sample. Natural-language token frequencies are ~Zipf(1),
/// which is what the synthetic Wikipedia-stand-in corpus uses.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(alpha);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Pcg64::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Pcg64::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new_stream(1, 0);
        let mut b = Pcg64::new_stream(1, 1);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Pcg64::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(100, 1.0);
        let mut r = Pcg64::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[9] && counts[9] > counts[49]);
        // Zipf(1): count(0)/count(9) ~= 10.
        let ratio = counts[0] as f64 / counts[9].max(1) as f64;
        assert!(ratio > 5.0 && ratio < 20.0, "{ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
