//! Minimal `log` backend (env_logger/tracing-subscriber are unavailable).
//!
//! `init()` installs a stderr logger filtered by `SQA_LOG`
//! (error|warn|info|debug|trace, default info). Timestamps are
//! seconds-since-start — enough to read training/serving logs.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    max_level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.max_level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger; safe to call multiple times.
pub fn init() {
    let level = match std::env::var("SQA_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        max_level: level,
    });
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
