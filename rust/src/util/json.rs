//! Minimal JSON parser/serializer (serde is unavailable in the offline image).
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number edge cases:
//! used for `artifacts/manifest.json`, checkpoints metadata, the serving
//! wire protocol and bench reports. Keys keep insertion order (Vec-backed
//! map) so serialized output is stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                // JSON has no NaN/Infinity literals — `{n}` would emit
                // `NaN`/`inf` and corrupt the wire/report. Serialize
                // non-finite as null (what serde_json does by default).
                if !n.is_finite() {
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow::anyhow!("bad surrogate"))?;
                                    let low =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    anyhow::bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                            );
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escaped_output_reparses() {
        let v = Json::Str("quote \" slash \\ nl \n tab \t".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // `{n}` on an f64 renders `NaN`/`inf`, which no JSON parser (ours
        // included) accepts — non-finite must degrade to null on the wire.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let v = Json::obj(vec![
            ("p50", Json::Num(f64::NAN)),
            ("p99", Json::num(2.5)),
        ]);
        let out = v.to_string();
        let back = Json::parse(&out).expect("snapshot with NaN must stay valid JSON");
        assert!(back.get("p50").unwrap().is_null());
        assert_eq!(back.get("p99").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
    }
}
