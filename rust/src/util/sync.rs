//! Concurrency substrate: one import seam for every synchronization
//! primitive the runtime's concurrent code uses.
//!
//! Three jobs, one module:
//!
//! 1. **Model-checking seam.** Under `--cfg loom` every re-export swaps to
//!    the [`loom`](https://docs.rs/loom) equivalents, so the thread pool's
//!    submit/`wait_idle` handshake, the `run_borrowed` completion latch and
//!    the session table's take/Busy/put-back protocol run under loom's
//!    exhaustive interleaving explorer (`rust/tests/loom_models.rs`).
//!    Tier-1 builds never set the cfg, never resolve the `loom` crate, and
//!    compile the std paths only — the CI `loom` job adds the dev-dependency
//!    in its own workspace (see `rust/README.md`, "Correctness tooling").
//!
//! 2. **Poison policy.** [`lock`] and [`wait`] are the *only* sanctioned
//!    ways to acquire a mutex or block on a condvar in `server`,
//!    `coordinator` and `runtime` (the in-tree invariant linter,
//!    `cargo run -p xtask -- lint`, rejects `.lock().unwrap()` there).
//!    They recover from poisoning instead of cascading the panic: every
//!    critical section in this crate leaves its guarded state consistent
//!    at each statement boundary (counters are single increments, queues
//!    are structurally valid between push/pop), so the last state a
//!    panicking thread published is safe to keep serving. One crashed
//!    connection handler or worker must not take down every later locker.
//!
//! 3. **Completion latch.** [`Latch`] is the join primitive behind
//!    `ThreadPool::run_borrowed`: one guard per job, distinguishing
//!    *completed* (job body returned) from merely *terminated* (guard
//!    dropped — job panicked, or was dropped unrun at pool shutdown). It
//!    replaces the old `mpsc` channel latch with shim-native Mutex+Condvar
//!    so the panic and drop paths of the `run_borrowed` SAFETY argument
//!    are themselves loom-explorable.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Thread spawning through the same std/loom seam. Only the thread pool
/// routes through this (loom models must own every thread they explore);
/// service threads with names and lifecycles of their own (dispatcher,
/// scheduler, server accept loop) stay on `std::thread` directly.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::JoinHandle;

    #[cfg(loom)]
    pub use loom::thread::JoinHandle;

    /// Spawn a named thread (loom ignores the name — its scheduler
    /// identifies threads by spawn order).
    #[cfg(not(loom))]
    pub fn spawn_named<F: FnOnce() + Send + 'static>(name: String, f: F) -> JoinHandle<()> {
        std::thread::Builder::new()
            .name(name)
            .spawn(f)
            .expect("spawn thread")
    }

    #[cfg(loom)]
    pub fn spawn_named<F: FnOnce() + Send + 'static>(_name: String, f: F) -> JoinHandle<()> {
        loom::thread::spawn(f)
    }
}

/// Acquire a mutex, recovering from poisoning (see the module docs for why
/// recovery is sound here). This is the poison-tolerant helper the
/// invariant linter requires in place of `.lock().unwrap()`.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Block on a condvar, recovering from poisoning on wake. Spurious wakeups
/// are possible (std and loom both model them) — always re-check the
/// predicate in a loop.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---- completion latch -------------------------------------------------------

struct LatchState {
    /// Guards handed out so far (must not exceed `n`).
    minted: usize,
    /// Guards whose job body returned normally.
    completed: usize,
    /// Guards dropped for any reason — completion, panic unwind, or the
    /// boxed job being dropped unrun at pool shutdown.
    terminated: usize,
}

struct LatchInner {
    state: Mutex<LatchState>,
    done: Condvar,
}

/// Counts `n` jobs to termination, separately tracking how many actually
/// completed. [`Latch::wait`] blocks until every guard is gone — which is
/// exactly the property `ThreadPool::run_borrowed`'s lifetime-erasure
/// SAFETY argument needs: no guard left means no job closure left alive,
/// means no outstanding borrow of the caller's stack.
pub struct Latch {
    inner: Arc<LatchInner>,
    n: usize,
}

/// One job's handle on a [`Latch`]. Call [`LatchGuard::complete`] as the
/// last statement of the job body; dropping the guard any other way (panic
/// unwind, job dropped unrun) still counts the job as terminated, so the
/// waiter can never hang — it just observes `completed < n`.
pub struct LatchGuard {
    inner: Arc<LatchInner>,
    completed: bool,
}

impl Latch {
    pub fn new(n: usize) -> Self {
        Self {
            inner: Arc::new(LatchInner {
                state: Mutex::new(LatchState {
                    minted: 0,
                    completed: 0,
                    terminated: 0,
                }),
                done: Condvar::new(),
            }),
            n,
        }
    }

    /// Mint the guard for one of the `n` jobs.
    pub fn guard(&self) -> LatchGuard {
        let mut st = lock(&self.inner.state);
        st.minted += 1;
        assert!(st.minted <= self.n, "latch over-minted: {} > {}", st.minted, self.n);
        LatchGuard {
            inner: Arc::clone(&self.inner),
            completed: false,
        }
    }

    /// Block until all `n` guards have terminated; returns how many
    /// completed normally. `completed < n` means at least one job panicked
    /// or was dropped unrun.
    pub fn wait(&self) -> usize {
        let mut st = lock(&self.inner.state);
        while st.terminated < self.n {
            st = wait(&self.inner.done, st);
        }
        st.completed
    }
}

impl LatchGuard {
    /// Mark the job as completed (consumes the guard; the drop below
    /// publishes both counts under one lock acquisition).
    pub fn complete(mut self) {
        self.completed = true;
    }
}

impl Drop for LatchGuard {
    fn drop(&mut self) {
        let mut st = lock(&self.inner.state);
        st.terminated += 1;
        if self.completed {
            st.completed += 1;
        }
        // notify_all: several run_borrowed batches never share a latch,
        // but the waiter and a concurrent guard drop can race the condvar.
        self.inner.done.notify_all();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // The helper still returns the last consistent state.
        assert_eq!(*lock(&m), 7);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn latch_counts_completions() {
        let latch = Latch::new(3);
        let guards: Vec<LatchGuard> = (0..3).map(|_| latch.guard()).collect();
        let mut handles = Vec::new();
        for g in guards {
            handles.push(std::thread::spawn(move || g.complete()));
        }
        assert_eq!(latch.wait(), 3);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn latch_counts_dropped_guards_as_terminated_not_completed() {
        let latch = Latch::new(2);
        let g1 = latch.guard();
        let g2 = latch.guard();
        g1.complete();
        drop(g2); // the panic-unwind / dropped-unrun path
        assert_eq!(latch.wait(), 1, "one completed, one merely terminated");
    }

    #[test]
    fn empty_latch_returns_immediately() {
        let latch = Latch::new(0);
        assert_eq!(latch.wait(), 0);
    }

    #[test]
    #[should_panic(expected = "over-minted")]
    fn latch_rejects_extra_guards() {
        let latch = Latch::new(1);
        let _a = latch.guard();
        let _b = latch.guard();
    }

    #[test]
    fn wait_blocks_until_last_guard() {
        let latch = Latch::new(1);
        let g = latch.guard();
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&flag);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            f2.store(true, Ordering::SeqCst);
            g.complete();
        });
        assert_eq!(latch.wait(), 1);
        assert!(flag.load(Ordering::SeqCst), "wait returned before the guard dropped");
        h.join().unwrap();
    }
}
