//! Property-based testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomized inputs drawn from a
//! generator; on failure it performs greedy shrinking via the generator's
//! `shrink` and reports the minimal counterexample plus the reproducing
//! seed. Deliberately small: enough for the coordinator-invariant and
//! attention-oracle properties this repo needs.

use crate::util::rng::Pcg64;

/// A generator of random values with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Candidate smaller values; default = no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize in [lo, hi] with halving shrink toward lo.
pub struct UsizeRange {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        rng.range_usize(self.lo, self.hi + 1)
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *value > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*value - self.lo) / 2);
            out.push(*value - 1);
        }
        out.dedup();
        out
    }
}

/// f32 in [lo, hi) with shrink toward 0 / lo.
pub struct F32Range {
    pub lo: f32,
    pub hi: f32,
}

impl Gen for F32Range {
    type Value = f32;

    fn generate(&self, rng: &mut Pcg64) -> f32 {
        self.lo + rng.f32() * (self.hi - self.lo)
    }

    fn shrink(&self, value: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *value != 0.0 && self.lo <= 0.0 && self.hi > 0.0 {
            out.push(0.0);
            out.push(value / 2.0);
        } else if *value > self.lo {
            out.push(self.lo);
            out.push(self.lo + (value - self.lo) / 2.0);
        }
        out
    }
}

/// Fixed-length Vec<f32> of standard normals (no shrinking).
pub struct NormalVec {
    pub len: usize,
}

impl Gen for NormalVec {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        (0..self.len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }
}

/// Pair combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&value.0)
            .into_iter()
            .map(|a| (a, value.1.clone()))
            .collect();
        out.extend(self.1.shrink(&value.1).into_iter().map(|b| (value.0.clone(), b)));
        out
    }
}

/// One of a fixed set of choices.
pub struct Choice<T: Clone + std::fmt::Debug>(pub Vec<T>);

impl<T: Clone + std::fmt::Debug> Gen for Choice<T> {
    type Value = T;

    fn generate(&self, rng: &mut Pcg64) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
}

/// Run `prop` over `cases` random inputs. Panics with the minimal shrunk
/// counterexample and the seed that reproduces it.
pub fn check<G: Gen, F>(seed: u64, cases: usize, gen: &G, mut prop: F)
where
    F: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 100, &UsizeRange { lo: 0, hi: 50 }, |v| {
            if *v <= 50 {
                Ok(())
            } else {
                Err(format!("{v} > 50"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_reports() {
        check(2, 100, &UsizeRange { lo: 0, hi: 100 }, |v| {
            if *v < 30 {
                Ok(())
            } else {
                Err("too big".to_string())
            }
        });
    }

    #[test]
    fn shrinks_toward_minimum() {
        let result = std::panic::catch_unwind(|| {
            check(3, 200, &UsizeRange { lo: 0, hi: 1000 }, |v| {
                if *v < 17 {
                    Ok(())
                } else {
                    Err("x".into())
                }
            })
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on exactly the boundary (17).
        assert!(msg.contains("input: 17"), "{msg}");
    }

    #[test]
    fn pair_generates_both() {
        check(
            4,
            50,
            &Pair(UsizeRange { lo: 1, hi: 8 }, F32Range { lo: -1.0, hi: 1.0 }),
            |(n, x)| {
                if *n >= 1 && *x >= -1.0 && *x < 1.0 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let collect = |seed| {
            let mut vals = Vec::new();
            check(seed, 10, &UsizeRange { lo: 0, hi: 1000 }, |v| {
                vals.push(*v);
                Ok(())
            });
            vals
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
