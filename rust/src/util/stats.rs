//! Timing and summary statistics for the bench harness and serving metrics.

use std::time::{Duration, Instant};

/// Online summary of a stream of samples (latencies, losses, …).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation; `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = q / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Measure a closure `reps` times after `warmup` runs; returns per-rep secs.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    s
}

/// A scope timer: `let _t = ScopeTimer::new("phase");` logs on drop.
pub struct ScopeTimer {
    label: String,
    start: Instant,
}

impl ScopeTimer {
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            label: label.into(),
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        log::info!("{}: {:.3}s", self.label, self.start.elapsed().as_secs_f64());
    }
}

/// Simple EWMA throughput/latency tracker for the serving metrics endpoint.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.p50(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for x in 0..101 {
            s.add(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Summary::new().mean().is_nan());
        assert!(Summary::new().percentile(50.0).is_nan());
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..20 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let s = time_reps(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }
}
