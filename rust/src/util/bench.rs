//! Benchmark runner (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this runner: it
//! warms up, measures wall-clock per iteration until a time or rep budget
//! is hit, and prints mean ± std plus throughput. Also renders the
//! markdown tables the paper-reproduction benches emit, and owns
//! [`write_bench_json`] — the single gate through which every bench
//! persists its JSON report.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::path::Path;
use std::time::{Duration, Instant};

/// Write a bench report to disk through the one schema gate all benches
/// share.
///
/// A report is a top-level JSON **object** carrying a `"bench"` string key
/// that names the bench — the handle `xtask bench-check` uses to pair a
/// fresh report with its committed `BENCH_*.json` baseline, and the reason
/// raw `fs::write` is banned in `rust/benches/` by the invariant linter
/// (`cargo run -p xtask -- lint`, rule `bench-writer`). Parent directories
/// are created; output ends with a newline so baselines diff cleanly.
pub fn write_bench_json(path: impl AsRef<Path>, doc: &Json) -> anyhow::Result<()> {
    let path = path.as_ref();
    let name = doc.get("bench").and_then(Json::as_str).unwrap_or("");
    anyhow::ensure!(
        !name.is_empty(),
        "bench report must be a JSON object with a top-level \"bench\" string key \
         naming the bench (writing {})",
        path.display()
    );
    if let Some(at) = find_non_finite(doc, name) {
        anyhow::bail!(
            "bench report {} carries a non-finite number at {at} — a NaN/inf \
             measurement is a bench bug (empty summary? zero-division?), not a \
             baseline candidate",
            path.display()
        );
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        }
    }
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(path, text)
        .map_err(|e| anyhow::anyhow!("writing bench report {}: {e}", path.display()))
}

/// Depth-first search for a non-finite `Json::Num`; returns the JSON path
/// of the first offender. The serializer degrades non-finite to `null`
/// (valid JSON on the wire), but a *report* with a silent null where a
/// timing belongs would defeat bench-check's finiteness guard — reject it
/// at the writer instead.
fn find_non_finite(v: &Json, path: &str) -> Option<String> {
    match v {
        Json::Num(n) if !n.is_finite() => Some(path.to_string()),
        Json::Arr(a) => a
            .iter()
            .enumerate()
            .find_map(|(i, x)| find_non_finite(x, &format!("{path}[{i}]"))),
        Json::Obj(m) => m
            .iter()
            .find_map(|(k, x)| find_non_finite(x, &format!("{path}.{k}"))),
        _ => None,
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
    /// Optional work units per iteration (e.g. tokens) for throughput.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.secs.mean()
    }

    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:40} {:>10.4}s ± {:>8.4}s (n={})",
            self.name,
            self.secs.mean(),
            self.secs.std(),
            self.secs.len()
        );
        if let Some(u) = self.units_per_iter {
            s.push_str(&format!("  [{:>10.1} units/s]", u / self.secs.mean()));
        }
        s
    }
}

/// Bench configuration: bounded by both reps and wall-clock budget.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: usize,
    pub min_reps: usize,
    pub max_reps: usize,
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            min_reps: 3,
            max_reps: 20,
            budget: Duration::from_secs(20),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_reps: 2,
            max_reps: 5,
            budget: Duration::from_secs(8),
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, units_per_iter: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut secs = Summary::new();
        let start = Instant::now();
        for rep in 0..self.max_reps {
            let t0 = Instant::now();
            f();
            secs.add(t0.elapsed().as_secs_f64());
            if rep + 1 >= self.min_reps && start.elapsed() > self.budget {
                break;
            }
        }
        let result = BenchResult {
            name: name.to_string(),
            secs,
            units_per_iter,
        };
        println!("{}", result.report_line());
        result
    }
}

/// Render a markdown table (paper-style): rows x columns of cells.
pub fn markdown_table(header: &[String], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:>w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
        }
        s
    };
    let mut out = fmt_row(header);
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_within_budget() {
        let b = Bench {
            warmup: 1,
            min_reps: 2,
            max_reps: 100,
            budget: Duration::from_millis(50),
        };
        let r = b.run("sleepy", None, || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.secs.len() >= 2);
        assert!(r.secs.len() < 100);
        assert!(r.mean() >= 0.004);
    }

    #[test]
    fn write_bench_json_roundtrips_and_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("sqa-bench-{}", std::process::id()));
        let path = dir.join("nested").join("report.json");
        let doc = Json::obj(vec![
            ("bench", Json::str("unit")),
            ("rows", Json::arr([Json::num(1.0)])),
        ]);
        write_bench_json(&path, &doc).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(Json::parse(&text).unwrap(), doc);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_bench_json_rejects_reports_outside_the_schema() {
        let path = std::env::temp_dir().join("sqa-bench-rejected.json");
        // A bare array (the old table1/2/3 shape) and an object missing the
        // "bench" key must both be refused before touching the filesystem.
        let arr = Json::arr([Json::num(1.0)]);
        assert!(write_bench_json(&path, &arr).is_err());
        let keyless = Json::obj(vec![("rows", Json::arr(Vec::new()))]);
        assert!(write_bench_json(&path, &keyless).is_err());
        assert!(!path.exists());
    }

    #[test]
    fn write_bench_json_rejects_non_finite_measurements() {
        let path = std::env::temp_dir().join("sqa-bench-nan.json");
        std::fs::remove_file(&path).ok();
        let doc = Json::obj(vec![
            ("bench", Json::str("unit")),
            (
                "rows",
                Json::arr([Json::obj(vec![("p50_ms", Json::Num(f64::NAN))])]),
            ),
        ]);
        let err = write_bench_json(&path, &doc).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "{err}");
        assert!(err.contains("rows[0].p50_ms"), "error names the path: {err}");
        assert!(!path.exists());
        let inf = Json::obj(vec![("bench", Json::str("unit")), ("t", Json::Num(f64::INFINITY))]);
        assert!(write_bench_json(&path, &inf).is_err());
    }

    #[test]
    fn markdown_is_aligned() {
        let t = markdown_table(
            &["Seq".into(), "MHA".into()],
            &[vec!["1024".into(), "0.0869".into()], vec!["200000".into(), "2.8734".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Seq") && lines[2].contains("1024"));
        assert_eq!(lines[0].len(), lines[3].len());
    }
}
