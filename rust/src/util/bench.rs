//! Benchmark runner (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this runner: it
//! warms up, measures wall-clock per iteration until a time or rep budget
//! is hit, and prints mean ± std plus throughput. Also renders the
//! markdown tables the paper-reproduction benches emit.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
    /// Optional work units per iteration (e.g. tokens) for throughput.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.secs.mean()
    }

    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:40} {:>10.4}s ± {:>8.4}s (n={})",
            self.name,
            self.secs.mean(),
            self.secs.std(),
            self.secs.len()
        );
        if let Some(u) = self.units_per_iter {
            s.push_str(&format!("  [{:>10.1} units/s]", u / self.secs.mean()));
        }
        s
    }
}

/// Bench configuration: bounded by both reps and wall-clock budget.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: usize,
    pub min_reps: usize,
    pub max_reps: usize,
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            min_reps: 3,
            max_reps: 20,
            budget: Duration::from_secs(20),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_reps: 2,
            max_reps: 5,
            budget: Duration::from_secs(8),
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, units_per_iter: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut secs = Summary::new();
        let start = Instant::now();
        for rep in 0..self.max_reps {
            let t0 = Instant::now();
            f();
            secs.add(t0.elapsed().as_secs_f64());
            if rep + 1 >= self.min_reps && start.elapsed() > self.budget {
                break;
            }
        }
        let result = BenchResult {
            name: name.to_string(),
            secs,
            units_per_iter,
        };
        println!("{}", result.report_line());
        result
    }
}

/// Render a markdown table (paper-style): rows x columns of cells.
pub fn markdown_table(header: &[String], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:>w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
        }
        s
    };
    let mut out = fmt_row(header);
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_within_budget() {
        let b = Bench {
            warmup: 1,
            min_reps: 2,
            max_reps: 100,
            budget: Duration::from_millis(50),
        };
        let r = b.run("sleepy", None, || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.secs.len() >= 2);
        assert!(r.secs.len() < 100);
        assert!(r.mean() >= 0.004);
    }

    #[test]
    fn markdown_is_aligned() {
        let t = markdown_table(
            &["Seq".into(), "MHA".into()],
            &[vec!["1024".into(), "0.0869".into()], vec!["200000".into(), "2.8734".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Seq") && lines[2].contains("1024"));
        assert_eq!(lines[0].len(), lines[3].len());
    }
}
