//! Substrate modules the offline image has no crates for.
//!
//! Each module replaces a crate a networked build would pull from
//! crates.io (see DESIGN.md §3 substitution table):
//!
//! | module       | replaces            |
//! |--------------|---------------------|
//! | [`json`]     | serde + serde_json  |
//! | [`cli`]      | clap                |
//! | [`rng`]      | rand + rand_distr   |
//! | [`threadpool`] | tokio task pool   |
//! | [`stats`]    | hdrhistogram-lite   |
//! | [`prop`]     | proptest            |
//! | [`bench`]    | criterion           |
//! | [`logging`]  | env_logger          |
//! | [`sync`]     | std ⇄ loom seam (+ poison-tolerant lock helpers) |
//! | [`simd`]     | wide / pulp (vectorized softmax primitives) |

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod sync;
pub mod threadpool;
