//! Vectorized online-softmax primitives with scalar fallbacks.
//!
//! The tiled forward ([`crate::attention::tiled`]) and the streaming
//! backward ([`crate::attention::backward`]) spend their non-GEMM time in
//! three per-row loops: the block row max, the `exp(score − m)`
//! exponentiation (+ normalizer sum), and the rescale-accumulate of the
//! running output. Under `Impl::Simd` those loops route through this
//! module: AVX2+FMA eight-lane bodies on x86-64 (runtime-detected via
//! [`have_avx2_fma`], the same cached guard the GEMM micro-kernel tier
//! uses), and scalar mirrors everywhere else — same polynomial, same
//! per-element operation order, so a host without AVX2 degrades silently
//! without changing semantics.
//!
//! Determinism: every helper reduces in a fixed lane-then-tail order that
//! depends only on the slice length — never on thread count — so the
//! parallel tiled kernels stay bitwise identical to their serial runs (the
//! property `parallel_matches_serial` pins). There is no fast-math
//! reassociation beyond the documented fixed split into eight lane partial
//! sums plus a scalar tail.
//!
//! `exp` is a Cephes-style degree-5 polynomial over the reduced argument
//! (`x = n·ln2 + r`, `|r| ≤ ln2/2`), exact at 0 (`exp_approx(0) == 1.0`),
//! flushed to `0.0` below [`EXP_LO`] (true `exp` is subnormal there), and
//! within ~3e-7 relative error of `f64` exp for `|x| ≤ 5` (≤ 4e-6 out to
//! the clamp range, where the probabilities are already vanishing) —
//! orders below the 1e-4 differential tolerance. Inputs are expected
//! finite — or finite-or-`-inf` for masked rows; callers gate dense rows
//! through [`row_max_finite`] and pattern-masked rows through
//! [`row_max_masked`] first.
//!
//! Intrinsics are confined to this module and `linalg/simd` by the
//! invariant linter (`cargo run -p xtask -- lint`, rule
//! `simd-confinement`).

/// Below this the polynomial's `2^n` scaling would go subnormal; real
/// `exp` is < 1.2e-38 there, so softmax weight is indistinguishable from 0.
pub const EXP_LO: f32 = -87.336_54;
/// Above this `2^n` construction would overflow the exponent field; inputs
/// are clamped (softmax arguments are ≤ 0, so this is never hit in anger).
const EXP_HI: f32 = 88.02;
/// 1.5·2²³ — adding and subtracting forces round-to-nearest-even to an
/// integer for |z| < 2²², the branch-free `rint` both paths share.
const MAGIC: f32 = 12_582_912.0;
const LOG2E: f32 = std::f32::consts::LOG2_E;
/// Cody–Waite split of ln 2 (Cephes constants): `n·LN2_HI` is exact for
/// the n range above, `LN2_LO` restores the dropped bits.
const LN2_HI: f32 = 0.693_359_375;
const LN2_LO: f32 = -2.121_944_4e-4;
/// Cephes single-precision exp polynomial coefficients (P0 is the leading
/// term): `exp(r) ≈ ((((((P0·r+P1)·r+P2)·r+P3)·r+P4)·r+P5)·r²) + r + 1`.
const P0: f32 = 1.987_569_2e-4;
const P1: f32 = 1.398_199_9e-3;
const P2: f32 = 8.333_452e-3;
const P3: f32 = 4.166_579_6e-2;
const P4: f32 = 1.666_666_5e-1;
const P5: f32 = 5.000_000_2e-1;

/// Cached AVX2+FMA runtime detection — the single guard every intrinsic
/// call site in this module and in `linalg::simd` names in its SAFETY
/// comment. Always false on non-x86-64 targets and under Miri (which
/// cannot interpret vendor intrinsics).
pub(crate) fn have_avx2_fma() -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        use std::sync::OnceLock;
        static DETECTED: OnceLock<bool> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    {
        false
    }
}

/// Scalar mirror of the vector `exp` pipeline: same constants, same
/// operation order, single-rounding `mul_add` where the vector body uses
/// FMA — so lane and tail elements of one row agree bit-for-bit. Finite
/// inputs only (`-inf` maps to 0, which covers `exp(m_old − m_new)` on the
/// first block of an online-softmax row).
#[inline]
pub fn exp_approx(x: f32) -> f32 {
    if x < EXP_LO {
        return 0.0;
    }
    let x = x.min(EXP_HI);
    let z = x * LOG2E;
    let n = (z + MAGIC) - MAGIC;
    let r = n.mul_add(-LN2_HI, x);
    let r = n.mul_add(-LN2_LO, r);
    let mut p = P0;
    p = p.mul_add(r, P1);
    p = p.mul_add(r, P2);
    p = p.mul_add(r, P3);
    p = p.mul_add(r, P4);
    p = p.mul_add(r, P5);
    let y = (p * r).mul_add(r, r) + 1.0;
    y * f32::from_bits((((n as i32) + 127) << 23) as u32)
}

/// Max over `xs` when every element is finite, `None` otherwise — the gate
/// for the vectorized row fast path. A `None` sends the row to the exact
/// scalar masking/poisoning path, so `±inf`/NaN semantics never depend on
/// which tier ran. Returns `Some(-inf)` on an empty slice.
pub fn row_max_finite(xs: &[f32]) -> Option<f32> {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if have_avx2_fma() {
        // SAFETY: AVX2 availability just confirmed by the cached
        // `have_avx2_fma` detection guard.
        return unsafe { avx2::row_max_finite(xs) };
    }
    let mut m = f32::NEG_INFINITY;
    for &x in xs {
        if !x.is_finite() {
            return None;
        }
        m = m.max(x);
    }
    Some(m)
}

/// Max over `xs` treating `-inf` as a legitimate *masked-out* score:
/// returns `None` only on NaN or `+inf` (poison — the row must take the
/// exact scalar path), `Some(max)` otherwise, where an all-masked row
/// yields `Some(-inf)`. This is the gate for the vectorized
/// windowed/pattern-masked softmax rows: masked slots carry `-inf`, which
/// [`exp_approx`]/`exp_ps` flush to exactly `0.0` (both paths share the
/// [`EXP_LO`] cutoff), so the masked SIMD row stays bitwise identical to
/// the scalar masking loop. Contrast [`row_max_finite`], which bails on
/// *any* non-finite value and serves the dense fast path.
pub fn row_max_masked(xs: &[f32]) -> Option<f32> {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if have_avx2_fma() {
        // SAFETY: AVX2 availability just confirmed by the cached
        // `have_avx2_fma` detection guard.
        return unsafe { avx2::row_max_masked(xs) };
    }
    let mut m = f32::NEG_INFINITY;
    for &x in xs {
        // `!(x < inf)` is true exactly for NaN and +inf; -inf passes.
        if !(x < f32::INFINITY) {
            return None;
        }
        m = m.max(x);
    }
    Some(m)
}

/// `xs[i] *= alpha` — the online-softmax rescale of the running output
/// row. A single IEEE multiply per element on either path, so the result
/// is bitwise identical to the scalar loop it replaces.
pub fn scale(xs: &mut [f32], alpha: f32) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if have_avx2_fma() {
        // SAFETY: AVX2 availability just confirmed by the cached
        // `have_avx2_fma` detection guard.
        unsafe { avx2::scale(xs, alpha) };
        return;
    }
    for x in xs {
        *x *= alpha;
    }
}

/// `dst[i] = exp_approx(src[i] - m)`, returning the sum of the written
/// probabilities in the fixed lane-then-tail order. The forward's
/// exponentiation + normalizer-accumulation step for one visible row
/// segment; `src` must be all-finite (gate with [`row_max_finite`]).
pub fn exp_sub_into(src: &[f32], m: f32, dst: &mut [f32]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA availability just confirmed by the cached
        // `have_avx2_fma` detection guard.
        return unsafe { avx2::exp_sub_into(src, m, dst) };
    }
    let mut sum = 0.0f32;
    for (d, &x) in dst.iter_mut().zip(src) {
        let p = exp_approx(x - m);
        *d = p;
        sum += p;
    }
    sum
}

/// The streaming backward's recompute step for one visible row segment:
/// `ps[j] = exp_approx(ss[j] - lse)` and
/// `ds[j] = ps[j] · ((dps[j] − delta) · scale)`, with `ds[j]` forced to
/// exactly 0 where the probability underflowed to 0 (matching the scalar
/// path's `p == 0.0` guard). `ss` must be all-finite.
pub fn probs_dscores(
    ss: &[f32],
    dps: &[f32],
    lse: f32,
    delta: f32,
    scale: f32,
    ps: &mut [f32],
    ds: &mut [f32],
) {
    debug_assert!(ss.len() == dps.len() && ss.len() == ps.len() && ss.len() == ds.len());
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if have_avx2_fma() {
        // SAFETY: AVX2+FMA availability just confirmed by the cached
        // `have_avx2_fma` detection guard.
        unsafe { avx2::probs_dscores(ss, dps, lse, delta, scale, ps, ds) };
        return;
    }
    for jj in 0..ss.len() {
        let p = exp_approx(ss[jj] - lse);
        ps[jj] = p;
        ds[jj] = if p == 0.0 {
            0.0
        } else {
            p * ((dps[jj] - delta) * scale)
        };
    }
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
mod avx2 {
    use super::{EXP_HI, EXP_LO, LN2_HI, LN2_LO, LOG2E, MAGIC, P0, P1, P2, P3, P4, P5};
    use core::arch::x86_64::*;

    /// Eight-lane twin of [`super::exp_approx`]: identical constants and
    /// operation order, FMA where the scalar mirror uses `mul_add`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — every
    // caller in this module is gated on `have_avx2_fma`.
    unsafe fn exp_ps(x: __m256) -> __m256 {
        // SAFETY: pure register arithmetic — no memory access; AVX2+FMA is
        // the `#[target_feature]` contract discharged by the callers in
        // this module (all gated on `have_avx2_fma`).
        unsafe {
            let under = _mm256_cmp_ps::<_CMP_LT_OQ>(x, _mm256_set1_ps(EXP_LO));
            let x = _mm256_min_ps(x, _mm256_set1_ps(EXP_HI));
            let z = _mm256_mul_ps(x, _mm256_set1_ps(LOG2E));
            let magic = _mm256_set1_ps(MAGIC);
            let n = _mm256_sub_ps(_mm256_add_ps(z, magic), magic);
            let r = _mm256_fmadd_ps(n, _mm256_set1_ps(-LN2_HI), x);
            let r = _mm256_fmadd_ps(n, _mm256_set1_ps(-LN2_LO), r);
            let mut p = _mm256_set1_ps(P0);
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P1));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P2));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P3));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P4));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(P5));
            let y = _mm256_add_ps(
                _mm256_fmadd_ps(_mm256_mul_ps(p, r), r, r),
                _mm256_set1_ps(1.0),
            );
            let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
                _mm256_cvttps_epi32(n),
                _mm256_set1_epi32(127),
            )));
            // Underflow lanes computed garbage above; force them to 0.
            _mm256_andnot_ps(under, _mm256_mul_ps(y, pow2))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — the
    // dispatchers in the parent module call in only when `have_avx2_fma`.
    pub(super) unsafe fn row_max_finite(xs: &[f32]) -> Option<f32> {
        // SAFETY: every load below reads 8 lanes inside `xs` (the chunk
        // loop stops at `len - len % 8`); AVX2 is the `#[target_feature]`
        // contract discharged at the `have_avx2_fma`-gated call site.
        unsafe {
            let abs = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
            let inf = _mm256_set1_ps(f32::INFINITY);
            let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut finite = _mm256_castsi256_ps(_mm256_set1_epi32(-1));
            let chunks = xs.len() / 8;
            for c in 0..chunks {
                let v = _mm256_loadu_ps(xs.as_ptr().add(c * 8));
                finite =
                    _mm256_and_ps(finite, _mm256_cmp_ps::<_CMP_LT_OQ>(_mm256_and_ps(v, abs), inf));
                vmax = _mm256_max_ps(vmax, v);
            }
            if _mm256_movemask_ps(finite) != 0xff {
                return None;
            }
            // Max is order-independent over finite lanes: fold the lanes
            // and the tail with the same scalar max the fallback uses.
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
            let mut m = lanes.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            for &x in &xs[chunks * 8..] {
                if !x.is_finite() {
                    return None;
                }
                m = m.max(x);
            }
            Some(m)
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — the
    // dispatchers in the parent module call in only when `have_avx2_fma`.
    pub(super) unsafe fn row_max_masked(xs: &[f32]) -> Option<f32> {
        // SAFETY: every load below reads 8 lanes inside `xs` (the chunk
        // loop stops at `len - len % 8`); AVX2 is the `#[target_feature]`
        // contract discharged at the `have_avx2_fma`-gated call site.
        unsafe {
            let inf = _mm256_set1_ps(f32::INFINITY);
            let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut ok = _mm256_castsi256_ps(_mm256_set1_epi32(-1));
            let chunks = xs.len() / 8;
            for c in 0..chunks {
                let v = _mm256_loadu_ps(xs.as_ptr().add(c * 8));
                // `v < +inf` (ordered) is false exactly for NaN and +inf
                // lanes; -inf-masked lanes pass and fold into the max.
                ok = _mm256_and_ps(ok, _mm256_cmp_ps::<_CMP_LT_OQ>(v, inf));
                vmax = _mm256_max_ps(vmax, v);
            }
            if _mm256_movemask_ps(ok) != 0xff {
                return None;
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
            let mut m = lanes.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            for &x in &xs[chunks * 8..] {
                if !(x < f32::INFINITY) {
                    return None;
                }
                m = m.max(x);
            }
            Some(m)
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — the
    // dispatchers in the parent module call in only when `have_avx2_fma`.
    pub(super) unsafe fn scale(xs: &mut [f32], alpha: f32) {
        // SAFETY: loads/stores cover 8 in-bounds lanes per chunk as above.
        unsafe {
            let va = _mm256_set1_ps(alpha);
            let chunks = xs.len() / 8;
            for c in 0..chunks {
                let p = xs.as_mut_ptr().add(c * 8);
                _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), va));
            }
            for x in &mut xs[chunks * 8..] {
                *x *= alpha;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — the
    // dispatchers in the parent module call in only when `have_avx2_fma`.
    pub(super) unsafe fn exp_sub_into(src: &[f32], m: f32, dst: &mut [f32]) -> f32 {
        // SAFETY: `src.len() == dst.len()` (debug_assert'd by the caller);
        // chunked loads/stores stay inside both slices.
        unsafe {
            let vm = _mm256_set1_ps(m);
            let mut vsum = _mm256_setzero_ps();
            let chunks = src.len() / 8;
            for c in 0..chunks {
                let p = super::avx2::exp_ps(_mm256_sub_ps(
                    _mm256_loadu_ps(src.as_ptr().add(c * 8)),
                    vm,
                ));
                _mm256_storeu_ps(dst.as_mut_ptr().add(c * 8), p);
                vsum = _mm256_add_ps(vsum, p);
            }
            // Fixed reduction order: lane partials in lane order, then the
            // scalar tail — a function of the slice length only.
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), vsum);
            let mut sum = lanes.iter().sum::<f32>();
            for (d, &x) in dst[chunks * 8..].iter_mut().zip(&src[chunks * 8..]) {
                let p = super::exp_approx(x - m);
                *d = p;
                sum += p;
            }
            sum
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: `unsafe fn` purely because of `#[target_feature]` — the
    // dispatchers in the parent module call in only when `have_avx2_fma`.
    pub(super) unsafe fn probs_dscores(
        ss: &[f32],
        dps: &[f32],
        lse: f32,
        delta: f32,
        scale: f32,
        ps: &mut [f32],
        ds: &mut [f32],
    ) {
        // SAFETY: all four slices have equal length (debug_assert'd by the
        // caller); chunked loads/stores stay inside them.
        unsafe {
            let vl = _mm256_set1_ps(lse);
            let vd = _mm256_set1_ps(delta);
            let vs = _mm256_set1_ps(scale);
            let zero = _mm256_setzero_ps();
            let chunks = ss.len() / 8;
            for c in 0..chunks {
                let p = super::avx2::exp_ps(_mm256_sub_ps(
                    _mm256_loadu_ps(ss.as_ptr().add(c * 8)),
                    vl,
                ));
                _mm256_storeu_ps(ps.as_mut_ptr().add(c * 8), p);
                let t = _mm256_mul_ps(
                    _mm256_sub_ps(_mm256_loadu_ps(dps.as_ptr().add(c * 8)), vd),
                    vs,
                );
                let d = _mm256_mul_ps(p, t);
                // p == 0 lanes emit exactly 0 like the scalar guard.
                let dead = _mm256_cmp_ps::<_CMP_EQ_OQ>(p, zero);
                _mm256_storeu_ps(ds.as_mut_ptr().add(c * 8), _mm256_andnot_ps(dead, d));
            }
            for jj in chunks * 8..ss.len() {
                let p = super::exp_approx(ss[jj] - lse);
                ps[jj] = p;
                ds[jj] = if p == 0.0 {
                    0.0
                } else {
                    p * ((dps[jj] - delta) * scale)
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy(len: usize, seed: u32, spread: f32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let x = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
                ((x >> 8) as f32 / (1u32 << 23) as f32 - 1.0) * spread
            })
            .collect()
    }

    #[test]
    fn exp_approx_matches_f64_exp() {
        assert_eq!(exp_approx(0.0), 1.0, "exp(0) must be exact");
        assert_eq!(exp_approx(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp_approx(-1.0e30), 0.0);
        assert_eq!(exp_approx(EXP_LO - 1.0), 0.0);
        let mut x = -87.0f32;
        while x < 10.0 {
            let got = exp_approx(x) as f64;
            let want = (x as f64).exp();
            let rel = (got - want).abs() / want;
            // Reduction error grows with |x|; the core softmax range is
            // an order tighter than the far tail (whose absolute
            // probabilities are vanishing anyway).
            let tol = if x.abs() <= 5.0 { 5e-7 } else { 5e-6 };
            assert!(rel < tol, "exp({x}): {got} vs {want} (rel {rel:.3e})");
            x += 0.0371;
        }
    }

    #[test]
    fn vector_paths_match_scalar_mirrors_exactly() {
        // On AVX2 hosts the dispatchers take the vector path; compare each
        // against a hand-run scalar mirror bit-for-bit, tails included.
        for &len in &[1usize, 7, 8, 9, 16, 23, 64, 101] {
            let src = noisy(len, 3, 20.0);
            let m = 4.0f32;
            let mut dst = vec![0.0f32; len];
            let sum = exp_sub_into(&src, m, &mut dst);
            let mirror: Vec<f32> = src.iter().map(|&x| exp_approx(x - m)).collect();
            for (g, w) in dst.iter().zip(&mirror) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
            assert!(sum.is_finite() && sum >= 0.0);

            let mut xs = noisy(len, 5, 2.0);
            let want: Vec<f32> = xs.iter().map(|&x| x * 0.37f32).collect();
            scale(&mut xs, 0.37);
            for (g, w) in xs.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits());
            }

            let ss = noisy(len, 7, 30.0);
            let dps = noisy(len, 9, 3.0);
            let (lse, delta, sc) = (2.5f32, 0.125f32, 0.3f32);
            let mut ps = vec![0.0f32; len];
            let mut ds = vec![0.0f32; len];
            probs_dscores(&ss, &dps, lse, delta, sc, &mut ps, &mut ds);
            for jj in 0..len {
                let p = exp_approx(ss[jj] - lse);
                let d = if p == 0.0 {
                    0.0
                } else {
                    p * ((dps[jj] - delta) * sc)
                };
                assert_eq!(ps[jj].to_bits(), p.to_bits());
                assert_eq!(ds[jj].to_bits(), d.to_bits());
            }
        }
    }

    #[test]
    fn row_max_gates_on_finiteness() {
        for &len in &[1usize, 8, 13, 40] {
            let xs = noisy(len, 11, 5.0);
            let want = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            assert_eq!(row_max_finite(&xs), Some(want));
            for bad in [f32::INFINITY, f32::NEG_INFINITY, f32::NAN] {
                let mut poisoned = xs.clone();
                poisoned[len / 2] = bad;
                assert_eq!(row_max_finite(&poisoned), None, "len {len}, bad {bad}");
            }
        }
        assert_eq!(row_max_finite(&[]), Some(f32::NEG_INFINITY));
    }

    #[test]
    fn masked_row_max_admits_neg_inf_but_rejects_poison() {
        for &len in &[1usize, 8, 13, 40] {
            let mut xs = noisy(len, 17, 5.0);
            let want = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            assert_eq!(row_max_masked(&xs), Some(want), "dense row, len {len}");
            // Masked slots carry -inf and must NOT disable the fast path.
            xs[len / 2] = f32::NEG_INFINITY;
            let want = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            assert_eq!(row_max_masked(&xs), Some(want), "masked row, len {len}");
            for bad in [f32::INFINITY, f32::NAN] {
                let mut poisoned = xs.clone();
                poisoned[len - 1] = bad;
                assert_eq!(row_max_masked(&poisoned), None, "len {len}, bad {bad}");
            }
        }
        // A fully-masked row reduces to -inf (caller emits all-zero probs).
        assert_eq!(
            row_max_masked(&[f32::NEG_INFINITY; 11]),
            Some(f32::NEG_INFINITY)
        );
        assert_eq!(row_max_masked(&[]), Some(f32::NEG_INFINITY));
    }

    #[test]
    fn exp_sub_sum_is_length_deterministic() {
        // Same slice, repeated calls: bitwise-identical sums (the fixed
        // lane-then-tail reduction order does not depend on anything else).
        let src = noisy(77, 13, 15.0);
        let mut a = vec![0.0f32; 77];
        let mut b = vec![0.0f32; 77];
        let s1 = exp_sub_into(&src, 1.5, &mut a);
        let s2 = exp_sub_into(&src, 1.5, &mut b);
        assert_eq!(s1.to_bits(), s2.to_bits());
        assert_eq!(a, b);
    }
}
