//! Fixed-size thread pool with a bounded queue (tokio is unavailable).
//!
//! Used by the serving coordinator's worker pool and the bench harness's
//! client load generators. The bounded queue is the backpressure primitive:
//! `submit` blocks when the queue is full, `try_submit` fails fast —
//! the serving path uses the latter to shed load explicitly.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<Job>,
    shutdown: bool,
}

/// A fixed pool of worker threads over a bounded FIFO queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_capacity: usize) -> Self {
        assert!(n_workers > 0 && queue_capacity > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState {
                items: VecDeque::new(),
                shutdown: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: queue_capacity,
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n_workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                let inflight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || worker_loop(q, inflight))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            queue,
            workers,
            in_flight,
        }
    }

    /// Enqueue a job, blocking while the queue is full.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut state = self.queue.jobs.lock().unwrap();
        while state.items.len() >= self.queue.capacity && !state.shutdown {
            state = self.queue.not_full.wait(state).unwrap();
        }
        if state.shutdown {
            return;
        }
        state.items.push_back(Box::new(f));
        self.queue.not_empty.notify_one();
    }

    /// Enqueue without blocking; `Err` means the queue is full (shed load).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), F> {
        let mut state = self.queue.jobs.lock().unwrap();
        if state.shutdown || state.items.len() >= self.queue.capacity {
            return Err(f);
        }
        state.items.push_back(Box::new(f));
        self.queue.not_empty.notify_one();
        Ok(())
    }

    /// Jobs queued but not yet started plus jobs currently running.
    pub fn pending(&self) -> usize {
        self.queue.jobs.lock().unwrap().items.len() + self.in_flight.load(Ordering::Relaxed)
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        loop {
            if self.pending() == 0 {
                return;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

fn worker_loop(queue: Arc<Queue>, in_flight: Arc<AtomicUsize>) {
    loop {
        let job = {
            let mut state = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = state.items.pop_front() {
                    in_flight.fetch_add(1, Ordering::Relaxed);
                    queue.not_full.notify_one();
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = queue.not_empty.wait(state).unwrap();
            }
        };
        job();
        in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.jobs.lock().unwrap();
            state.shutdown = true;
        }
        self.queue.not_empty.notify_all();
        self.queue.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new(Mutex::new(()));
        let hold = gate.lock().unwrap();
        let g1 = Arc::clone(&gate);
        pool.submit(move || {
            drop(g1.lock().unwrap()); // blocks until test releases
        });
        // Wait for the worker to pick up the blocking job.
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.submit(|| {}); // fills the queue (capacity 1)
        let mut shed = 0;
        for _ in 0..3 {
            if pool.try_submit(|| {}).is_err() {
                shed += 1;
            }
        }
        assert!(shed >= 2, "expected shedding, got {shed}");
        drop(hold);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
