//! Fixed-size thread pool with a bounded queue (tokio is unavailable).
//!
//! Used by the serving coordinator's worker pool, the native backend's
//! row/tile fan-outs, the `linalg` GEMM row-block fan-out, and the bench
//! harness's client load generators. The bounded queue is the backpressure
//! primitive: `submit` blocks when the queue is full, `try_submit` fails
//! fast — the serving path uses the latter to shed load explicitly.
//!
//! Two joining primitives:
//! * [`ThreadPool::wait_idle`] blocks on a condvar signalled when the last
//!   running job of an empty queue finishes (it used to poll `pending()` in
//!   a 200 µs sleep loop — hot forward paths joining on the pool paid that
//!   latency on every call);
//! * [`ThreadPool::run_borrowed`] runs a batch of *borrowing* jobs and
//!   blocks until all of them complete, which is what lets the compute
//!   paths fan out over slices of caller-owned buffers without cloning
//!   them into `Arc`s.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Signalled when the queue drains and the last running job finishes.
    idle: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<Job>,
    /// Jobs popped but still running (owned by the queue mutex so `idle`
    /// can be signalled without racing `pending`).
    active: usize,
    shutdown: bool,
}

impl QueueState {
    fn is_idle(&self) -> bool {
        self.items.is_empty() && self.active == 0
    }
}

/// A fixed pool of worker threads over a bounded FIFO queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_capacity: usize) -> Self {
        assert!(n_workers > 0 && queue_capacity > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState {
                items: VecDeque::new(),
                active: 0,
                shutdown: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            idle: Condvar::new(),
            capacity: queue_capacity,
        });
        let workers = (0..n_workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        Self { queue, workers }
    }

    /// Number of worker threads (fan-out sizing hint).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job, blocking while the queue is full.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, f: Job) {
        let mut state = self.queue.jobs.lock().unwrap();
        while state.items.len() >= self.queue.capacity && !state.shutdown {
            state = self.queue.not_full.wait(state).unwrap();
        }
        if state.shutdown {
            return;
        }
        state.items.push_back(f);
        self.queue.not_empty.notify_one();
    }

    /// Enqueue without blocking; `Err` means the queue is full (shed load).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), F> {
        let mut state = self.queue.jobs.lock().unwrap();
        if state.shutdown || state.items.len() >= self.queue.capacity {
            return Err(f);
        }
        state.items.push_back(Box::new(f));
        self.queue.not_empty.notify_one();
        Ok(())
    }

    /// Jobs queued but not yet started plus jobs currently running.
    pub fn pending(&self) -> usize {
        let state = self.queue.jobs.lock().unwrap();
        state.items.len() + state.active
    }

    /// Block until every queued job has finished — condvar wait, no
    /// busy-polling: the last worker to finish with the queue empty
    /// signals `idle`.
    pub fn wait_idle(&self) {
        let mut state = self.queue.jobs.lock().unwrap();
        while !state.is_idle() {
            state = self.queue.idle.wait(state).unwrap();
        }
    }

    /// Run a batch of jobs that may **borrow** from the caller's stack and
    /// block until every one of them has completed.
    ///
    /// This is the scoped-fan-out primitive behind the linalg row-block
    /// parallelism and the native backend's per-row batch fan: jobs get
    /// `&`/`&mut` slices of caller-owned buffers directly — no `Arc`
    /// clones, no per-request allocation. A completion latch (one channel
    /// message per job, sent after the job body returns or unwinds) makes
    /// the early-return-while-borrowed case impossible: we do not return
    /// until every job has stopped touching the borrows.
    ///
    /// Panics if a job panicked (its latch message never arrives). Do not
    /// call from *inside* a pool job — the bounded queue can deadlock on
    /// nested submission, same as [`ThreadPool::submit`].
    pub fn run_borrowed<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel::<()>();
        for job in jobs {
            let tx = tx.clone();
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                // On unwind `tx` drops unsent; the latch then comes up
                // short and we panic below instead of hanging.
                job();
                let _ = tx.send(());
            });
            // SAFETY: lifetime erasure only. The closure (and everything it
            // borrows) is guaranteed to be done before this function
            // returns: we block on one latch message per job, and a message
            // is only missing if the job unwound — in which case its borrows
            // were released during the unwind. Jobs dropped unrun (pool
            // shutdown) drop their `tx` immediately, which also releases
            // the borrows before the latch loop ends.
            #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
            let wrapped: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
            };
            self.submit_boxed(wrapped);
        }
        drop(tx);
        let mut done = 0usize;
        while rx.recv().is_ok() {
            done += 1;
        }
        assert!(done == n, "pool job failed while running borrowed batch ({done}/{n})");
    }
}

fn worker_loop(queue: Arc<Queue>) {
    loop {
        let job = {
            let mut state = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = state.items.pop_front() {
                    state.active += 1;
                    queue.not_full.notify_one();
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = queue.not_empty.wait(state).unwrap();
            }
        };
        // A panicking job must not kill the worker (a shrinking pool turns
        // into missed latches and stuck queues) nor leak `active`.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if result.is_err() {
            log::error!("thread pool job panicked");
        }
        let mut state = queue.jobs.lock().unwrap();
        state.active -= 1;
        if state.is_idle() {
            queue.idle.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.jobs.lock().unwrap();
            state.shutdown = true;
        }
        self.queue.not_empty.notify_all();
        self.queue.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.n_workers(), 4);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new(Mutex::new(()));
        let hold = gate.lock().unwrap();
        let g1 = Arc::clone(&gate);
        pool.submit(move || {
            drop(g1.lock().unwrap()); // blocks until test releases
        });
        // Wait for the worker to pick up the blocking job.
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.submit(|| {}); // fills the queue (capacity 1)
        let mut shed = 0;
        for _ in 0..3 {
            if pool.try_submit(|| {}).is_err() {
                shed += 1;
            }
        }
        assert!(shed >= 2, "expected shedding, got {shed}");
        drop(hold);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn wait_idle_blocks_until_running_job_finishes() {
        // The queue is empty the moment the worker pops the job; wait_idle
        // must still block on the *running* job, not return early.
        let pool = ThreadPool::new(1, 4);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            d.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_borrowed_sees_stack_data_and_joins() {
        let pool = ThreadPool::new(3, 8);
        let input: Vec<u64> = (0..64).collect();
        let mut out = vec![0u64; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(16).enumerate() {
                let src = &input[i * 16..(i + 1) * 16];
                jobs.push(Box::new(move || {
                    for (o, &s) in chunk.iter_mut().zip(src) {
                        *o = s * 2;
                    }
                }));
            }
            pool.run_borrowed(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &x)| x == 2 * i as u64));
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = ThreadPool::new(1, 4);
        pool.submit(|| panic!("boom"));
        pool.wait_idle(); // must not hang or leak `active`
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        pool.submit(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 1, "worker died on panic");
    }
}
