//! Fixed-size thread pool with a bounded queue (tokio is unavailable).
//!
//! Used by the serving coordinator's worker pool, the native backend's
//! row/tile fan-outs, the `linalg` GEMM row-block fan-out, and the bench
//! harness's client load generators. The bounded queue is the backpressure
//! primitive: `submit` blocks when the queue is full, `try_submit` fails
//! fast — the serving path uses the latter to shed load explicitly.
//!
//! All synchronization goes through [`crate::util::sync`], so the whole
//! pool — queue handshake, idle condvar, `run_borrowed` latch — runs under
//! loom's exhaustive interleaving explorer (`rust/tests/loom_models.rs`).
//!
//! Two joining primitives:
//! * [`ThreadPool::wait_idle`] blocks on a condvar signalled when the last
//!   running job of an empty queue finishes (it used to poll `pending()` in
//!   a 200 µs sleep loop — hot forward paths joining on the pool paid that
//!   latency on every call);
//! * [`ThreadPool::run_borrowed`] runs a batch of *borrowing* jobs and
//!   blocks until all of them complete, which is what lets the compute
//!   paths fan out over slices of caller-owned buffers without cloning
//!   them into `Arc`s.

use crate::util::sync::{self, thread::JoinHandle, Arc, Condvar, Latch, Mutex};
use std::collections::VecDeque;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Signalled when the queue drains and the last running job finishes.
    idle: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<Job>,
    /// Jobs popped but still running (owned by the queue mutex so `idle`
    /// can be signalled without racing `pending`).
    active: usize,
    shutdown: bool,
}

impl QueueState {
    fn is_idle(&self) -> bool {
        self.items.is_empty() && self.active == 0
    }
}

/// A fixed pool of worker threads over a bounded FIFO queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_capacity: usize) -> Self {
        assert!(n_workers > 0 && queue_capacity > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState {
                items: VecDeque::new(),
                active: 0,
                shutdown: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            idle: Condvar::new(),
            capacity: queue_capacity,
        });
        let workers = (0..n_workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                sync::thread::spawn_named(format!("pool-{i}"), move || worker_loop(q))
            })
            .collect();
        Self { queue, workers }
    }

    /// Number of worker threads (fan-out sizing hint).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job, blocking while the queue is full.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, f: Job) {
        let mut state = sync::lock(&self.queue.jobs);
        while state.items.len() >= self.queue.capacity && !state.shutdown {
            state = sync::wait(&self.queue.not_full, state);
        }
        if state.shutdown {
            // Dropping `f` here is load-bearing for run_borrowed: the job's
            // latch guard drops with it, so the batch waiter observes the
            // job as terminated-but-not-completed instead of hanging.
            return;
        }
        state.items.push_back(f);
        self.queue.not_empty.notify_one();
    }

    /// Enqueue without blocking; `Err` means the queue is full (shed load).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), F> {
        let mut state = sync::lock(&self.queue.jobs);
        if state.shutdown || state.items.len() >= self.queue.capacity {
            return Err(f);
        }
        state.items.push_back(Box::new(f));
        self.queue.not_empty.notify_one();
        Ok(())
    }

    /// Jobs queued but not yet started plus jobs currently running.
    pub fn pending(&self) -> usize {
        let state = sync::lock(&self.queue.jobs);
        state.items.len() + state.active
    }

    /// Block until every queued job has finished — condvar wait, no
    /// busy-polling: the last worker to finish with the queue empty
    /// signals `idle`.
    pub fn wait_idle(&self) {
        let mut state = sync::lock(&self.queue.jobs);
        while !state.is_idle() {
            state = sync::wait(&self.queue.idle, state);
        }
    }

    /// Run a batch of jobs that may **borrow** from the caller's stack and
    /// block until every one of them has completed.
    ///
    /// This is the scoped-fan-out primitive behind the linalg row-block
    /// parallelism and the native backend's per-row batch fan: jobs get
    /// `&`/`&mut` slices of caller-owned buffers directly — no `Arc`
    /// clones, no per-request allocation. A completion [`Latch`] (one
    /// guard per job, dropped when the job returns, unwinds, or is dropped
    /// unrun) makes the early-return-while-borrowed case impossible: we do
    /// not return until every job has stopped touching the borrows.
    ///
    /// Panics if a job panicked (its guard terminated without completing).
    /// Do not call from *inside* a pool job — the bounded queue can
    /// deadlock on nested submission, same as [`ThreadPool::submit`].
    pub fn run_borrowed<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = jobs.len();
        let latch = Latch::new(n);
        for job in jobs {
            let guard = latch.guard();
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                // On unwind `guard` drops un-completed; the latch then
                // comes up short and we panic below instead of hanging.
                job();
                guard.complete();
            });
            // SAFETY: lifetime erasure only. The closure (and everything it
            // borrows) is guaranteed to be done before this function
            // returns: `latch.wait()` blocks until all `n` guards have
            // dropped, and a guard drops only when its job completed,
            // unwound (borrows released during the unwind), or was dropped
            // unrun at pool shutdown (closure dropped, borrows released).
            // No path leaks a live closure past the wait below.
            #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
            let wrapped: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
            };
            self.submit_boxed(wrapped);
        }
        let done = latch.wait();
        assert!(done == n, "pool job failed while running borrowed batch ({done}/{n})");
    }
}

fn worker_loop(queue: Arc<Queue>) {
    loop {
        let job = {
            let mut state = sync::lock(&queue.jobs);
            loop {
                if let Some(job) = state.items.pop_front() {
                    state.active += 1;
                    queue.not_full.notify_one();
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = sync::wait(&queue.not_empty, state);
            }
        };
        // A panicking job must not kill the worker (a shrinking pool turns
        // into missed latches and stuck queues) nor leak `active`.
        run_job(job);
        let mut state = sync::lock(&queue.jobs);
        state.active -= 1;
        if state.is_idle() {
            queue.idle.notify_all();
        }
    }
}

#[cfg(not(loom))]
fn run_job(job: Job) {
    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
        log::error!("thread pool job panicked");
    }
}

/// loom's model has no unwinding — a panic inside a model aborts the
/// exploration anyway, so the catch_unwind wrapper (not implemented for
/// loom's types) is simply omitted.
#[cfg(loom)]
fn run_job(job: Job) {
    job();
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut state = sync::lock(&self.queue.jobs);
            state.shutdown = true;
        }
        // Workers pop items *before* checking shutdown, so already-queued
        // jobs drain before the join — Drop is graceful. The only
        // dropped-unrun path is a submitter blocked on `not_full` when
        // shutdown lands (see submit_boxed); run_borrowed's latch turns
        // that into a loud done!=n assertion instead of a hang.
        self.queue.not_empty.notify_all();
        self.queue.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.n_workers(), 4);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new(Mutex::new(()));
        let hold = sync::lock(&gate);
        let g1 = Arc::clone(&gate);
        pool.submit(move || {
            drop(sync::lock(&g1)); // blocks until test releases
        });
        // Wait for the worker to pick up the blocking job.
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.submit(|| {}); // fills the queue (capacity 1)
        let mut shed = 0;
        for _ in 0..3 {
            if pool.try_submit(|| {}).is_err() {
                shed += 1;
            }
        }
        assert!(shed >= 2, "expected shedding, got {shed}");
        drop(hold);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn wait_idle_blocks_until_running_job_finishes() {
        // The queue is empty the moment the worker pops the job; wait_idle
        // must still block on the *running* job, not return early.
        let pool = ThreadPool::new(1, 4);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            d.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_borrowed_sees_stack_data_and_joins() {
        let pool = ThreadPool::new(3, 8);
        let input: Vec<u64> = (0..64).collect();
        let mut out = vec![0u64; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(16).enumerate() {
                let src = &input[i * 16..(i + 1) * 16];
                jobs.push(Box::new(move || {
                    for (o, &s) in chunk.iter_mut().zip(src) {
                        *o = s * 2;
                    }
                }));
            }
            pool.run_borrowed(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &x)| x == 2 * i as u64));
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = ThreadPool::new(1, 4);
        pool.submit(|| panic!("boom"));
        pool.wait_idle(); // must not hang or leak `active`
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        pool.submit(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 1, "worker died on panic");
    }

    // ---- edge cases behind the run_borrowed SAFETY argument (ISSUE 6) ----

    #[test]
    fn run_borrowed_empty_batch_returns_immediately() {
        let pool = ThreadPool::new(2, 4);
        pool.run_borrowed(Vec::new());
        // And again — no latch state leaks across batches.
        pool.run_borrowed(Vec::new());
    }

    #[test]
    fn run_borrowed_panicking_job_asserts_instead_of_hanging() {
        let pool = ThreadPool::new(2, 8);
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(move || {
                    f.fetch_add(1, Ordering::SeqCst);
                }),
                Box::new(|| panic!("job blew up")),
            ];
            pool.run_borrowed(jobs);
        }));
        let err = result.expect_err("run_borrowed must panic when a job panicked");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(
            msg.contains("pool job failed while running borrowed batch (1/2)"),
            "wrong panic: {msg}"
        );
        assert_eq!(flag.load(Ordering::SeqCst), 1, "healthy job should still have run");
    }

    #[test]
    fn drop_with_queued_jobs_drains_then_joins() {
        // One worker wedged on a gate, several jobs stuck in the queue:
        // Drop must wait out the gate job, drain the queue, and join —
        // without hanging and without losing queued work.
        let gate = Arc::new(Mutex::new(false));
        let cv = Arc::new(Condvar::new());
        let ran = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(1, 8);
            let (g, c) = (Arc::clone(&gate), Arc::clone(&cv));
            pool.submit(move || {
                let mut open = sync::lock(&g);
                while !*open {
                    open = sync::wait(&c, open);
                }
            });
            for _ in 0..4 {
                let r = Arc::clone(&ran);
                pool.submit(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Open the gate from a helper thread *after* Drop has begun so
            // Drop really does wait on a busy worker with a loaded queue.
            let (g, c) = (Arc::clone(&gate), Arc::clone(&cv));
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(30));
                *sync::lock(&g) = true;
                c.notify_all();
            });
            drop(pool); // must not hang
        }
        assert_eq!(ran.load(Ordering::SeqCst), 4, "queued jobs drain before the join");
    }

    #[test]
    fn submit_after_shutdown_drops_job_silently() {
        // try_submit on a shut-down pool must shed, not enqueue.
        let pool = ThreadPool::new(1, 2);
        {
            let mut st = sync::lock(&pool.queue.jobs);
            st.shutdown = true;
        }
        assert!(pool.try_submit(|| {}).is_err());
        pool.submit(|| unreachable!("job must be dropped, not run"));
        // Un-wedge shutdown so Drop's join completes normally.
        {
            let mut st = sync::lock(&pool.queue.jobs);
            assert!(st.items.is_empty());
        }
    }
}
