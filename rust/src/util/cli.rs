//! Tiny CLI argument parser (clap is unavailable in the offline image).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and typed
//! accessors with defaults. Unknown flags are an error — catches typos in
//! bench invocations early.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> anyhow::Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&mut self, key: &str) {
        if !self.known.iter().any(|k| k == key) {
            self.known.push(key.to_string());
        }
    }

    pub fn str_opt(&mut self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str(&mut self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&mut self, key: &str, default: usize) -> anyhow::Result<usize> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&mut self, key: &str, default: f64) -> anyhow::Result<f64> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a float, got {v:?}")),
        }
    }

    pub fn bool(&mut self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list flag.
    pub fn list(&mut self, key: &str, default: &[&str]) -> Vec<String> {
        self.mark(key);
        match self.flags.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        }
    }

    /// Call after consuming all flags; errors on unrecognized ones.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.flags.keys() {
            if !self.known.iter().any(|known| known == k) {
                anyhow::bail!(
                    "unknown flag --{k} (known: {})",
                    self.known.join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = parse("train --steps 100 --lr=3e-4 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert_eq!(a.f64("lr", 0.0).unwrap(), 3e-4);
        assert!(a.bool("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let mut a = parse("bench");
        assert_eq!(a.usize("steps", 7).unwrap(), 7);
        assert_eq!(a.str("variant", "sqa"), "sqa");
        assert!(!a.bool("force"));
    }

    #[test]
    fn lists() {
        let mut a = parse("bench --variants mha,sqa,xsqa");
        assert_eq!(a.list("variants", &[]), vec!["mha", "sqa", "xsqa"]);
        let mut b = parse("bench");
        assert_eq!(b.list("variants", &["gqa"]), vec!["gqa"]);
    }

    #[test]
    fn unknown_flag_errors() {
        let mut a = parse("train --oops 1");
        let _ = a.usize("steps", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn type_errors() {
        let mut a = parse("x --n abc");
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn positional() {
        let a = parse("encode file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
