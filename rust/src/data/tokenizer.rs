//! Word-level tokenizer with reserved specials and byte-ish fallback.
//!
//! Used by the story pipeline (Table 2) and the serving example (text in,
//! embeddings/logits out). Vocabulary is fixed at construction —
//! deterministic, no training pass needed — with specials:
//!   0 = <pad>, 1 = <bos>, 2 = <eos>, 3 = <unk>.

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const UNK: u32 = 3;
pub const N_SPECIALS: u32 = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>,
}

impl Tokenizer {
    /// Build from a word list; ids are assigned in the given order after
    /// the specials.
    pub fn from_words<I: IntoIterator<Item = String>>(words: I) -> Self {
        let mut id_to_word = vec![
            "<pad>".to_string(),
            "<bos>".to_string(),
            "<eos>".to_string(),
            "<unk>".to_string(),
        ];
        let mut word_to_id = HashMap::new();
        for w in words {
            if !word_to_id.contains_key(&w) {
                word_to_id.insert(w.clone(), id_to_word.len() as u32);
                id_to_word.push(w);
            }
        }
        Self {
            word_to_id,
            id_to_word,
        }
    }

    /// The Table-2 tokenizer: story lexicon vocabulary.
    pub fn for_stories() -> Self {
        Self::from_words(
            crate::data::stories::lexicon()
                .into_iter()
                .map(String::from),
        )
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn encode_word(&self, w: &str) -> u32 {
        self.word_to_id.get(w).copied().unwrap_or(UNK)
    }

    /// Whitespace-split encode, no specials added.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| self.encode_word(&w.to_lowercase()))
            .collect()
    }

    /// Encode with `<bos>`/`<eos>` wrapping.
    pub fn encode_wrapped(&self, text: &str) -> Vec<u32> {
        let mut v = vec![BOS];
        v.extend(self.encode(text));
        v.push(EOS);
        v
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| {
                self.id_to_word
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<oov>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known_words() {
        let t = Tokenizer::for_stories();
        let ids = t.encode("tom found a red ball .");
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(t.decode(&ids), "tom found a red ball .");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = Tokenizer::for_stories();
        assert_eq!(t.encode("xylophone")[0], UNK);
    }

    #[test]
    fn wrapped_has_bos_eos() {
        let t = Tokenizer::for_stories();
        let ids = t.encode_wrapped("lily smiled");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
    }

    #[test]
    fn specials_reserved() {
        let t = Tokenizer::for_stories();
        assert_eq!(t.decode(&[PAD, BOS, EOS, UNK]), "<pad> <bos> <eos> <unk>");
        // No lexicon word maps onto a special id.
        for w in crate::data::stories::lexicon() {
            assert!(t.encode_word(w) >= N_SPECIALS);
        }
    }

    #[test]
    fn dedup_in_construction() {
        let t = Tokenizer::from_words(vec!["a".into(), "b".into(), "a".into()]);
        assert_eq!(t.vocab_size(), 6); // 4 specials + a + b
    }

    #[test]
    fn case_insensitive_encode() {
        let t = Tokenizer::for_stories();
        assert_eq!(t.encode("TOM"), t.encode("tom"));
    }
}
