//! Deterministic synthetic corpus — the Wikipedia-subset stand-in (Table 1).
//!
//! The paper's quality experiments compare attention variants *against each
//! other on identical data*; what matters is that every variant sees the
//! same token stream with learnable structure. This generator produces a
//! Zipf-distributed token stream layered over a hidden Markov skeleton:
//!
//!   * K hidden "topic" states, sticky transitions (p_stay) — documents
//!     have local coherence;
//!   * each state owns a contiguous vocabulary band sampled Zipf(α) —
//!     mirrors natural-language unigram statistics;
//!   * within a state, with probability `p_bigram` the next token is a
//!     deterministic function of the previous one — gives the model a
//!     learnable bigram signal so losses drop well below the unigram
//!     entropy floor.
//!
//! Fixed seed → byte-identical corpus across runs and variants.

use crate::util::rng::{Pcg64, Zipf};

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Reserve the first `reserved` ids (pad/bos/eos/unk).
    pub reserved: usize,
    pub n_states: usize,
    pub p_stay: f64,
    pub p_bigram: f64,
    pub zipf_alpha: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            vocab: 4096,
            reserved: 4,
            n_states: 8,
            p_stay: 0.98,
            p_bigram: 0.65,
            zipf_alpha: 1.1,
        }
    }
}

/// Streaming token generator; `next_token()` is O(log band).
pub struct ZipfCorpus {
    cfg: CorpusConfig,
    rng: Pcg64,
    zipf: Zipf,
    state: usize,
    prev: usize,
    band: usize,
}

impl ZipfCorpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        assert!(cfg.vocab > cfg.reserved + cfg.n_states);
        let band = (cfg.vocab - cfg.reserved) / cfg.n_states;
        let zipf = Zipf::new(band, cfg.zipf_alpha);
        Self {
            cfg,
            rng: Pcg64::new_stream(seed, 0xC0FFEE),
            zipf,
            state: 0,
            prev: 0,
            band,
        }
    }

    #[inline]
    fn band_start(&self, state: usize) -> usize {
        self.cfg.reserved + state * self.band
    }

    pub fn next_token(&mut self) -> u32 {
        // Topic transition.
        if !self.rng.bool(self.cfg.p_stay) {
            self.state = self.rng.below(self.cfg.n_states as u64) as usize;
        }
        let start = self.band_start(self.state);
        let tok = if self.prev >= start
            && self.prev < start + self.band
            && self.rng.bool(self.cfg.p_bigram)
        {
            // Deterministic successor within the band: the learnable signal.
            let rel = self.prev - start;
            start + (rel * 31 + 17) % self.band
        } else {
            start + self.zipf.sample(&mut self.rng)
        };
        self.prev = tok;
        tok as u32
    }

    /// Generate `n` tokens.
    pub fn tokens(&mut self, n: usize) -> Vec<u32> {
        (0..n).map(|_| self.next_token()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(seed: u64, n: usize) -> Vec<u32> {
        ZipfCorpus::new(CorpusConfig::default(), seed).tokens(n)
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(gen(1, 500), gen(1, 500));
        assert_ne!(gen(1, 500), gen(2, 500));
    }

    #[test]
    fn tokens_in_range_and_no_reserved() {
        let cfg = CorpusConfig::default();
        for &t in &gen(3, 5000) {
            assert!((t as usize) >= cfg.reserved && (t as usize) < cfg.vocab);
        }
    }

    #[test]
    fn has_learnable_bigram_structure() {
        // The deterministic successor must make the modal next-token far
        // more likely than chance.
        let toks = gen(4, 200_000);
        use std::collections::HashMap;
        let mut follows: HashMap<u32, HashMap<u32, usize>> = HashMap::new();
        for w in toks.windows(2) {
            *follows.entry(w[0]).or_default().entry(w[1]).or_default() += 1;
        }
        // Average max-follow probability over frequent tokens.
        let mut probs = Vec::new();
        for (_, nexts) in follows.iter() {
            let total: usize = nexts.values().sum();
            if total >= 50 {
                let max = *nexts.values().max().unwrap();
                probs.push(max as f64 / total as f64);
            }
        }
        let avg = probs.iter().sum::<f64>() / probs.len() as f64;
        assert!(avg > 0.4, "bigram signal too weak: {avg}");
    }

    #[test]
    fn topics_make_local_bands() {
        // Consecutive tokens should usually be in the same vocab band.
        let cfg = CorpusConfig::default();
        let band = (cfg.vocab - cfg.reserved) / cfg.n_states;
        let toks = gen(5, 20_000);
        let same: usize = toks
            .windows(2)
            .filter(|w| {
                (w[0] as usize - cfg.reserved) / band == (w[1] as usize - cfg.reserved) / band
            })
            .count();
        assert!(same as f64 / (toks.len() - 1) as f64 > 0.9);
    }
}
