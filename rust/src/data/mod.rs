//! Synthetic-data pipeline: corpora, tokenizer, batching.
//!
//! Substitutions for the paper's datasets (DESIGN.md §3):
//!   * [`corpus`] — Zipf/Markov token stream ↔ wikimedia/wikipedia subset
//!     (Table 1 dense models);
//!   * [`stories`] — procedural story grammar ↔ roneneldan/TinyStories
//!     (Table 2 MoE models);
//!   * [`tokenizer`] — word-level vocab with pad/bos/eos/unk specials;
//!   * [`batcher`] — fixed-shape next-token batches + train/val split.

pub mod batcher;
pub mod corpus;
pub mod stories;
pub mod tokenizer;

pub use batcher::{pad_to, Batch, Batcher, Split};
pub use corpus::{CorpusConfig, ZipfCorpus};
pub use stories::StoryGen;
pub use tokenizer::Tokenizer;

/// Build the training token stream for a model family.
///
/// * dense families draw from the Zipf/Markov corpus clamped to `vocab`;
/// * MoE families tokenize the story grammar (its lexicon is far smaller
///   than the model vocab — the rest of the ids stay unused, as with any
///   tokenizer whose vocab exceeds a small dataset's support).
pub fn tokens_for_family(
    family: &str,
    vocab: usize,
    n_tokens: usize,
    seed: u64,
) -> Vec<u32> {
    if family.starts_with("moe") {
        let tok = Tokenizer::for_stories();
        assert!(tok.vocab_size() <= vocab, "story lexicon exceeds model vocab");
        let mut sg = StoryGen::new(seed);
        let words = sg.words(n_tokens);
        words.iter().map(|w| tok.encode_word(w)).collect()
    } else {
        let cfg = CorpusConfig {
            vocab,
            ..CorpusConfig::default()
        };
        ZipfCorpus::new(cfg, seed).tokens(n_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_streams_fit_vocab() {
        for (fam, vocab) in [("dense_sm", 4096), ("moe_sm", 2048), ("tiny", 2048)] {
            let toks = tokens_for_family(fam, vocab, 2000, 1);
            assert_eq!(toks.len(), 2000);
            assert!(toks.iter().all(|&t| (t as usize) < vocab), "{fam}");
        }
    }

    #[test]
    fn moe_stream_uses_story_tokens() {
        let toks = tokens_for_family("moe_sm", 2048, 1000, 2);
        let tok = Tokenizer::for_stories();
        // All ids fall inside the story vocab.
        assert!(toks.iter().all(|&t| (t as usize) < tok.vocab_size()));
    }
}
