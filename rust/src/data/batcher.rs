//! Batching: turn a token stream into fixed-shape (tokens, targets) pairs.
//!
//! Deterministic train/val split: the stream is cut into contiguous
//! `seq+1`-token windows; every `val_every`-th window goes to the val
//! split. Targets are tokens shifted left by one (next-token prediction),
//! matching the L2 loss (`python/compile/model.py::loss_and_acc`).

/// One batch in the artifact's expected layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    /// [batch * seq] row-major i32.
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

/// Iterator of batches over a finite token buffer (epochs wrap around).
pub struct Batcher {
    data: Vec<u32>,
    batch: usize,
    seq: usize,
    split: Split,
    val_every: usize,
    /// Next window index (pre-split-filter).
    cursor: usize,
}

impl Batcher {
    pub fn new(data: Vec<u32>, batch: usize, seq: usize, split: Split) -> Self {
        assert!(data.len() >= (seq + 1) * batch, "token buffer too small");
        Self {
            data,
            batch,
            seq,
            split,
            val_every: 10,
            cursor: 0,
        }
    }

    fn n_windows(&self) -> usize {
        self.data.len() / (self.seq + 1)
    }

    fn window_in_split(&self, w: usize) -> bool {
        let is_val = w % self.val_every == self.val_every - 1;
        match self.split {
            Split::Val => is_val,
            Split::Train => !is_val,
        }
    }

    fn next_window(&mut self) -> usize {
        loop {
            let w = self.cursor % self.n_windows();
            self.cursor += 1;
            if self.window_in_split(w) {
                return w;
            }
        }
    }

    /// Produce the next batch (wraps around the buffer indefinitely).
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let w = self.next_window();
            let start = w * (self.seq + 1);
            let window = &self.data[start..start + self.seq + 1];
            tokens.extend(window[..self.seq].iter().map(|&t| t as i32));
            targets.extend(window[1..].iter().map(|&t| t as i32));
        }
        Batch {
            batch: self.batch,
            seq: self.seq,
            tokens,
            targets,
        }
    }
}

/// Right-pad (or truncate) a token sequence to `seq`, returning the padded
/// vector and the original length — used by the serving router.
pub fn pad_to(tokens: &[u32], seq: usize, pad_id: u32) -> (Vec<i32>, usize) {
    let n = tokens.len().min(seq);
    let mut out = Vec::with_capacity(seq);
    out.extend(tokens[..n].iter().map(|&t| t as i32));
    out.resize(seq, pad_id as i32);
    (out, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut b = Batcher::new(stream(1000), 2, 8, Split::Train);
        let batch = b.next_batch();
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(
                    batch.targets[row * 8 + i],
                    batch.tokens[row * 8 + i + 1]
                );
            }
        }
    }

    #[test]
    fn train_and_val_windows_disjoint() {
        let data = stream(11 * 9); // 11 windows of seq+1=9
        let mut tr = Batcher::new(data.clone(), 1, 8, Split::Train);
        let mut va = Batcher::new(data, 1, 8, Split::Val);
        let mut train_starts = std::collections::HashSet::new();
        for _ in 0..30 {
            train_starts.insert(tr.next_batch().tokens[0]);
        }
        for _ in 0..5 {
            let v = va.next_batch().tokens[0];
            assert!(!train_starts.contains(&v), "val window leaked into train");
        }
    }

    #[test]
    fn wraps_around() {
        let mut b = Batcher::new(stream(64), 2, 7, Split::Train);
        let first = b.next_batch();
        for _ in 0..20 {
            b.next_batch();
        }
        // Still produces valid batches after wrapping.
        let later = b.next_batch();
        assert_eq!(later.tokens.len(), first.tokens.len());
    }

    #[test]
    fn deterministic() {
        let mk = || {
            let mut b = Batcher::new(stream(500), 2, 8, Split::Train);
            (0..5).map(|_| b.next_batch()).collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn pad_to_works() {
        let (p, n) = pad_to(&[5, 6, 7], 6, 0);
        assert_eq!(p, vec![5, 6, 7, 0, 0, 0]);
        assert_eq!(n, 3);
        let (p, n) = pad_to(&[1, 2, 3, 4], 2, 0);
        assert_eq!(p, vec![1, 2]);
        assert_eq!(n, 2);
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn rejects_tiny_buffer() {
        Batcher::new(stream(10), 4, 8, Split::Train);
    }
}
