//! Procedural story generator — the TinyStories stand-in (Table 2).
//!
//! TinyStories (Eldan & Li, 2023) is low-entropy, template-heavy children's
//! prose; small models learn it quickly. This generator produces the same
//! *statistical* character with a context-free grammar over a small
//! lexicon: simple SVO sentences, recurring characters, connective tissue,
//! and a closing moral. Deterministic per seed.

use crate::util::rng::Pcg64;

const NAMES: &[&str] = &[
    "tom", "lily", "max", "anna", "ben", "mia", "sam", "zoe",
];
const ANIMALS: &[&str] = &[
    "cat", "dog", "bird", "bunny", "fox", "frog", "duck", "bear",
];
const OBJECTS: &[&str] = &[
    "ball", "kite", "book", "cake", "hat", "boat", "star", "drum", "apple", "box",
];
const ADJS: &[&str] = &[
    "big", "small", "red", "happy", "shiny", "soft", "funny", "brave", "little", "kind",
];
const VERBS_T: &[&str] = &[
    "found", "saw", "liked", "made", "took", "gave", "lost", "hid", "shared", "painted",
];
const VERBS_I: &[&str] = &[
    "smiled", "laughed", "jumped", "ran", "sang", "danced", "slept", "played",
];
const PLACES: &[&str] = &[
    "park", "garden", "house", "forest", "beach", "hill", "room", "yard",
];
const CONNECT: &[&str] = &["then", "so", "but", "and"];
const MORALS: &[&str] = &[
    "they were happy",
    "it was a good day",
    "they became friends",
    "everyone smiled",
];

/// Full lexicon (for vocabulary construction) — every word the grammar emits.
pub fn lexicon() -> Vec<&'static str> {
    let mut v = Vec::new();
    v.extend_from_slice(NAMES);
    v.extend_from_slice(ANIMALS);
    v.extend_from_slice(OBJECTS);
    v.extend_from_slice(ADJS);
    v.extend_from_slice(VERBS_T);
    v.extend_from_slice(VERBS_I);
    v.extend_from_slice(PLACES);
    v.extend_from_slice(CONNECT);
    for m in MORALS {
        v.extend(m.split(' '));
    }
    v.extend_from_slice(&[
        "a", "the", "in", "one", "day", "was", "there", "with", "it", "very", ".", ",",
    ]);
    v.sort_unstable();
    v.dedup();
    v
}

/// Generates stories as whitespace-separated word streams.
pub struct StoryGen {
    rng: Pcg64,
}

impl StoryGen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new_stream(seed, 0x57012),
        }
    }

    fn sentence(&mut self, hero: &str, words: &mut Vec<String>) {
        let r = &mut self.rng;
        match r.below(4) {
            0 => {
                // hero found a adj object .
                for w in [
                    hero,
                    *r.choice(VERBS_T),
                    "a",
                    *r.choice(ADJS),
                    *r.choice(OBJECTS),
                    ".",
                ] {
                    words.push(w.to_string());
                }
            }
            1 => {
                // the animal verb_i in the place .
                for w in [
                    "the",
                    *r.choice(ANIMALS),
                    *r.choice(VERBS_I),
                    "in",
                    "the",
                    *r.choice(PLACES),
                    ".",
                ] {
                    words.push(w.to_string());
                }
            }
            2 => {
                // connective hero verb_i with the animal .
                for w in [
                    *r.choice(CONNECT),
                    hero,
                    *r.choice(VERBS_I),
                    "with",
                    "the",
                    *r.choice(ANIMALS),
                    ".",
                ] {
                    words.push(w.to_string());
                }
            }
            _ => {
                // it was very adj .
                for w in ["it", "was", "very", *r.choice(ADJS), "."] {
                    words.push(w.to_string());
                }
            }
        }
    }

    /// One story of `n_sentences`, as a flat word vector.
    pub fn story(&mut self, n_sentences: usize) -> Vec<String> {
        let hero = *self.rng.choice(NAMES);
        let mut words = Vec::new();
        // "one day there was a adj name ."
        for w in ["one", "day", "there", "was", "a"] {
            words.push(w.to_string());
        }
        words.push(self.rng.choice(ADJS).to_string());
        words.push(hero.to_string());
        words.push(".".to_string());
        for _ in 0..n_sentences {
            self.sentence(hero, &mut words);
        }
        for w in MORALS[self.rng.below(MORALS.len() as u64) as usize].split(' ') {
            words.push(w.to_string());
        }
        words.push(".".to_string());
        words
    }

    /// Stream `n_words` of story text (stories concatenated).
    pub fn words(&mut self, n_words: usize) -> Vec<String> {
        let mut out = Vec::with_capacity(n_words);
        while out.len() < n_words {
            let n = self.rng.range_usize(3, 8);
            out.extend(self.story(n));
        }
        out.truncate(n_words);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(StoryGen::new(1).words(200), StoryGen::new(1).words(200));
        assert_ne!(StoryGen::new(1).words(200), StoryGen::new(2).words(200));
    }

    #[test]
    fn all_words_in_lexicon() {
        let lex: std::collections::HashSet<_> = lexicon().into_iter().collect();
        for w in StoryGen::new(3).words(5000) {
            assert!(lex.contains(w.as_str()), "{w} not in lexicon");
        }
    }

    #[test]
    fn stories_have_structure() {
        let words = StoryGen::new(4).words(10_000);
        let periods = words.iter().filter(|w| *w == ".").count();
        // Sentences average ~6 words.
        assert!(periods > 1000, "{periods}");
        assert!(words.iter().any(|w| w == "one"));
    }

    #[test]
    fn lexicon_is_small_and_stable() {
        let lex = lexicon();
        assert!(lex.len() < 120, "{}", lex.len());
        assert_eq!(lex, lexicon());
    }
}
