//! Minimal dense f32 tensor for the native attention oracle.
//!
//! Row-major, shape-checked, no broadcasting cleverness — this exists to be
//! *obviously correct* (it is the differential-testing oracle against the
//! XLA artifacts) and fast enough for bench baselines.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Self {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat index of a 4-d coordinate (the oracle's tensors are all 4-d).
    #[inline]
    pub fn idx4(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((a * self.shape[1] + b) * self.shape[2] + c) * self.shape[3] + d
    }

    #[inline]
    pub fn get4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        self.data[self.idx4(a, b, c, d)]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let i = self.idx4(a, b, c, d);
        self.data[i] = v;
    }

    /// Contiguous row `[.., .., row, :]` of a 4-d tensor.
    #[inline]
    pub fn row4(&self, a: usize, b: usize, c: usize) -> &[f32] {
        let d = self.shape[3];
        let start = self.idx4(a, b, c, 0);
        &self.data[start..start + d]
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// `out[m, n] += a[m, k] * b[n, k]` (b transposed) over contiguous slices.
#[inline]
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let br = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += ar[p] * br[p];
            }
            out[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trips() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7.5);
        assert_eq!(t.get4(1, 2, 3, 4), 7.5);
        assert_eq!(t.data[t.len() - 1], 7.5);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn row4_is_contiguous() {
        let t = Tensor::from_vec(&[1, 1, 2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row4(0, 0, 1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn matmul_nt_small() {
        // a = [[1,2],[3,4]], b = [[5,6],[7,8]] (b rows are the transposed cols)
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0; 4];
        matmul_nt(&a, &b, &mut out, 2, 2, 2);
        // out[i,j] = dot(a[i,:], b[j,:])
        assert_eq!(out, [17.0, 23.0, 39.0, 53.0]);
    }
}
