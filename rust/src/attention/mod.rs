//! Attention kernels over the SQA head geometry: a naive oracle and a
//! tiled streaming production kernel, differentially tested against each
//! other.
//!
//! Two implementations of the same math (§3.2 of the paper):
//!   * [`attention`] — the **naive oracle**: materializes the `[S, S]`
//!     score matrix per head. Deliberately simple; it is the reference the
//!     differential suites ([`tiled`] vs oracle, native backend vs
//!     independent re-implementation, golden files from
//!     `python/tests/test_golden.py`) all compare against.
//!   * [`tiled`] — the **default execution path**: flash-style streaming
//!     kernel (online softmax, fixed query/key tiles, mask-aware key-tile
//!     skipping, never an S×S buffer) that reaches paper-scale sequence
//!     lengths the oracle cannot.
//!
//! [`Kernel`] selects between them on the public entry points
//! ([`attention_with`], [`sqa_layer_with`]); the naive path stays available
//! everywhere purely as the testing oracle.
//!
//! Semantics match `python/compile/kernels/ref.py` exactly: Hq query heads,
//! Hkv key/value heads, head `h` reads kv head `h / (Hq/Hkv)`, optional
//! causal and sliding-window masks, f32 throughout. On top of those, the
//! [`pattern`] module adds block-sparse [`MaskPattern`]s (strided, dilated,
//! sink+local, block bitmaps, per-head tables) that AND with the
//! causal/window mask through one visibility seam shared by the oracle,
//! the tiled forward/backward and decode.

pub mod backward;
pub mod decode;
pub mod pattern;
pub mod tensor;
pub mod tiled;

use crate::linalg;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
pub use pattern::{BitmapId, BlockBitmap, HeadTableId, MaskPattern, ResolvedMask};
use tensor::{matmul_nt, Tensor};

/// Attention variant hyper-parameters — mirrors `AttentionSpec` in L2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Spec {
    pub hq: usize,
    pub hkv: usize,
    pub causal: bool,
    /// Sliding window (SWA / SW-SQA). With `causal: true`, query i sees keys
    /// j with `0 <= i - j < window` (the usual causal sliding window). With
    /// `causal: false`, the window is symmetric: `|i - j| < window`.
    pub window: Option<usize>,
    /// Block-sparse pattern AND-ed with the causal/window mask;
    /// [`MaskPattern::Dense`] reproduces the plain causal/window kernels.
    pub pattern: MaskPattern,
}

impl Spec {
    pub fn full(hq: usize, hkv: usize) -> Self {
        Self {
            hq,
            hkv,
            causal: false,
            window: None,
            pattern: MaskPattern::Dense,
        }
    }

    pub fn causal(hq: usize, hkv: usize) -> Self {
        Self {
            hq,
            hkv,
            causal: true,
            window: None,
            pattern: MaskPattern::Dense,
        }
    }

    /// Builder: this spec with a different mask pattern.
    pub fn with_pattern(mut self, pattern: MaskPattern) -> Self {
        self.pattern = pattern;
        self
    }

    /// Resolve a per-head pattern table to head `h`'s concrete pattern
    /// (`table[h % len]`); concrete patterns pass through unchanged. Every
    /// head-dispatch site calls this before entering a kernel.
    pub fn for_head(mut self, h: usize) -> Self {
        if let MaskPattern::PerHead(id) = self.pattern {
            let table = pattern::head_table(id)
                .expect("per-head pattern table not registered (validate the Spec first)");
            self.pattern = table[h % table.len()];
        }
        self
    }

    /// Materialize this (concrete) spec's visibility rule — one registry
    /// lookup, then lock-free queries. See [`ResolvedMask`].
    pub fn resolved(&self) -> ResolvedMask {
        ResolvedMask::new(*self)
    }

    pub fn validate(&self) -> Result<()> {
        if self.hkv == 0 || self.hq == 0 {
            bail!("head counts must be positive: {self:?}");
        }
        if self.hq % self.hkv != 0 {
            bail!("Hq={} must be a multiple of Hkv={}", self.hq, self.hkv);
        }
        if self.window == Some(0) {
            bail!("window must be positive");
        }
        self.pattern.validate()?;
        Ok(())
    }
}

/// Which attention lowering to run.
///
/// `Naive` is the S×S-materializing oracle; `Tiled` is the streaming
/// flash-style kernel and the default everywhere outside differential
/// tests. Parse from CLI/env strings with [`Kernel::parse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// Full score-matrix oracle — the differential-testing reference.
    Naive,
    /// Tiled online-softmax streaming kernel (no S×S buffer).
    #[default]
    Tiled,
}

impl Kernel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "naive" => Ok(Self::Naive),
            "tiled" => Ok(Self::Tiled),
            other => bail!("unknown attention kernel {other:?} (naive|tiled)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Naive => "naive",
            Self::Tiled => "tiled",
        }
    }

    /// Kernel selected by `SQA_KERNEL` (default: tiled).
    ///
    /// Panics on an unknown value: a differential run that silently fell
    /// back to the kernel under test would be worse than no run at all
    /// (`SQA_BACKEND` hard-fails the same way in `open_backend`).
    pub fn from_env() -> Self {
        match std::env::var("SQA_KERNEL").ok().as_deref() {
            Some(s) if !s.is_empty() => {
                Self::parse(s).unwrap_or_else(|e| panic!("SQA_KERNEL: {e:#}"))
            }
            _ => Self::default(),
        }
    }
}

/// Validate shapes against the spec; returns `(batch, hq, s, d)`.
pub(crate) fn check_shapes(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
) -> Result<(usize, usize, usize, usize)> {
    spec.validate()?;
    let (b, hq, s, d) = dims4(q)?;
    let (bk, hkv, sk, dk) = dims4(k)?;
    if (bk, hkv, sk, dk) != (b, spec.hkv, s, d) || k.shape != v.shape {
        bail!(
            "shape mismatch: q{:?} k{:?} v{:?} spec {:?}",
            q.shape,
            k.shape,
            v.shape,
            spec
        );
    }
    if hq != spec.hq {
        bail!("q has {hq} heads, spec says {}", spec.hq);
    }
    Ok((b, hq, s, d))
}

/// Dispatch to the selected attention kernel.
pub fn attention_with(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
    kernel: Kernel,
) -> Result<Tensor> {
    match kernel {
        Kernel::Naive => attention(q, k, v, spec),
        Kernel::Tiled => tiled::attention_tiled(q, k, v, spec),
    }
}

/// Scaled-dot-product attention over the SQA head geometry — the **naive
/// oracle** (materializes the S×S score matrix; see [`tiled`] for the
/// streaming production kernel).
///
/// q: [batch, Hq, S, d]; k, v: [batch, Hkv, S, d] -> [batch, Hq, S, d].
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, spec: Spec) -> Result<Tensor> {
    let (b, hq, s, d) = check_shapes(q, k, v, spec)?;
    let hkv = spec.hkv;
    let group = hq / hkv;
    let scale = 1.0 / (d as f32).sqrt();

    let mut out = Tensor::zeros(&[b, hq, s, d]);
    let mut scores = vec![0.0f32; s * s];

    for ib in 0..b {
        for h in 0..hq {
            let hk = h / group; // the paper's zero-copy K'/V' sharing
            // Per-head visibility: per-head tables resolve here, so the
            // oracle stays the exact reference for every pattern.
            let rm = spec.for_head(h).resolved();
            let q_base = q.idx4(ib, h, 0, 0);
            let k_base = k.idx4(ib, hk, 0, 0);
            let q_slab = &q.data[q_base..q_base + s * d];
            let k_slab = &k.data[k_base..k_base + s * d];
            matmul_nt(q_slab, k_slab, &mut scores, s, s, d);

            for i in 0..s {
                let row = &mut scores[i * s..(i + 1) * s];
                // Masking window for row i.
                let (lo, hi) = visible_range(i, s, spec);
                let mut maxv = f32::NEG_INFINITY;
                for (j, r) in row.iter_mut().enumerate() {
                    if j < lo || j >= hi || !rm.pattern_visible(i, j) {
                        *r = f32::NEG_INFINITY;
                    } else {
                        *r *= scale;
                        maxv = maxv.max(*r);
                    }
                }
                let mut denom = 0.0f32;
                for r in row.iter_mut() {
                    if r.is_finite() {
                        *r = (*r - maxv).exp();
                        denom += *r;
                    } else {
                        *r = 0.0;
                    }
                }
                let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
                // out[i, :] = sum_j p[j] * v[j, :]
                let o_base = out.idx4(ib, h, i, 0);
                let v_base = v.idx4(ib, hk, 0, 0);
                for j in lo..hi {
                    let p = row[j] * inv;
                    if p == 0.0 {
                        continue;
                    }
                    let vr = &v.data[v_base + j * d..v_base + (j + 1) * d];
                    for (o, vv) in out.data[o_base..o_base + d].iter_mut().zip(vr) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Half-open `[lo, hi)` range of keys visible to query row `i`.
///
/// Causal masking caps `hi` at `i + 1`; a window additionally bounds the
/// range to `window` keys behind (and, when non-causal, ahead of) `i`.
/// Historical bug: a window used to force `hi = i + 1` even with
/// `causal: false`, silently computing *causal* sliding-window attention —
/// the non-causal window is now symmetric (`|i - j| < window`).
pub fn visible_range(i: usize, s: usize, spec: Spec) -> (usize, usize) {
    let hi = if spec.causal {
        i + 1
    } else {
        match spec.window {
            Some(w) => (i + w).min(s),
            None => s,
        }
    };
    let lo = match spec.window {
        Some(w) => (i + 1).saturating_sub(w),
        None => 0,
    };
    (lo, hi)
}

fn dims4(t: &Tensor) -> Result<(usize, usize, usize, usize)> {
    if t.rank() != 4 {
        bail!("expected rank-4 tensor, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1], t.shape[2], t.shape[3]))
}

/// Full SQA layer (paper eqs. 4-8) on the default kernel (tiled).
///
/// x: [batch, seq, d_model] (given as rank-4 [batch, 1, seq, d_model]);
/// weights row-major: wq [d_model, hq*dh], wk/wv [d_model, hkv*dh],
/// wo [hq*dh, d_model].
pub fn sqa_layer(
    x: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    d_head: usize,
    spec: Spec,
) -> Result<Tensor> {
    sqa_layer_with(x, wq, wk, wv, wo, d_head, spec, Kernel::default(), None)
}

/// [`sqa_layer`] with an explicit kernel choice and, for the tiled path, an
/// optional thread pool to fan the attention out across
/// `(head, query-tile)` jobs. Shape-checks the weight tensors, then
/// delegates to [`sqa_layer_slices`] with the `SQA_LINALG` GEMM lowering.
#[allow(clippy::too_many_arguments)]
pub fn sqa_layer_with(
    x: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    d_head: usize,
    spec: Spec,
    kernel: Kernel,
    pool: Option<&ThreadPool>,
) -> Result<Tensor> {
    spec.validate()?;
    let (_, _, _, dm) = dims4(x)?;
    let (dq, dkv) = (spec.hq * d_head, spec.hkv * d_head);
    if wq.shape != vec![dm, dq] {
        bail!("wq shape {:?} != [{dm}, {dq}]", wq.shape);
    }
    if wk.shape != vec![dm, dkv] || wv.shape != vec![dm, dkv] {
        bail!("wk/wv shapes {:?}/{:?} != [{dm}, {dkv}]", wk.shape, wv.shape);
    }
    if wo.shape != vec![dq, dm] {
        bail!("wo shape {:?} != [{dq}, {dm}]", wo.shape);
    }
    sqa_layer_slices(
        x,
        &wq.data,
        &wk.data,
        &wv.data,
        &wo.data,
        d_head,
        spec,
        kernel,
        linalg::Impl::from_env(),
        pool,
    )
}

/// Split a head-interleaved `[s, heads*d_head]` projection into the
/// kernels' `[1, heads, s, d_head]` layout (naive-oracle path only).
fn split_heads(flat: &[f32], heads: usize, s: usize, d_head: usize) -> Tensor {
    let cols = heads * d_head;
    let mut t = Tensor::zeros(&[1, heads, s, d_head]);
    for h in 0..heads {
        for i in 0..s {
            let base = t.idx4(0, h, i, 0);
            t.data[base..base + d_head]
                .copy_from_slice(&flat[i * cols + h * d_head..][..d_head]);
        }
    }
    t
}

/// [`sqa_layer_with`] over raw weight *slices* — the native backend's entry
/// point: weights stay borrowed views into the flat parameter vector (no
/// per-layer copies), all projections and the output projection run as
/// [`crate::linalg`] GEMMs under the given [`linalg::Impl`], and the tiled
/// kernel streams directly over the head-interleaved `[s, H·dh]` slabs.
///
/// `pool` fans both the projection row blocks and the tiled attention's
/// `(head, query-tile)` jobs out across workers; pass `None` when already
/// running on a pool worker (nested submission can deadlock the bounded
/// queue).
#[allow(clippy::too_many_arguments)]
pub fn sqa_layer_slices(
    x: &Tensor,
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    d_head: usize,
    spec: Spec,
    kernel: Kernel,
    imp: linalg::Impl,
    pool: Option<&ThreadPool>,
) -> Result<Tensor> {
    spec.validate()?;
    let (b, one, s, dm) = dims4(x)?;
    if one != 1 {
        bail!("x must be [batch, 1, seq, d_model]");
    }
    let (dq, dkv) = (spec.hq * d_head, spec.hkv * d_head);
    if wq.len() != dm * dq || wk.len() != dm * dkv || wv.len() != dm * dkv {
        bail!(
            "projection weight lengths {}/{}/{} != {dm}x{dq} / {dm}x{dkv} / {dm}x{dkv}",
            wq.len(),
            wk.len(),
            wv.len()
        );
    }
    if wo.len() != dq * dm {
        bail!("wo length {} != {dq}x{dm}", wo.len());
    }
    let scale = 1.0 / (d_head as f32).sqrt();
    let cfg = tiled::TileConfig::default().with_linalg(imp);
    let group = spec.hq / spec.hkv;
    let mut y = Tensor::zeros(&[b, 1, s, dm]);
    for ib in 0..b {
        let xb = &x.data[ib * s * dm..][..s * dm];
        let qf = linalg::matmul(imp, xb, wq, s, dm, dq, pool);
        let kf = linalg::matmul(imp, xb, wk, s, dm, dkv, pool);
        let vf = linalg::matmul(imp, xb, wv, s, dm, dkv, pool);
        let mut of = vec![0.0f32; s * dq];
        match kernel {
            Kernel::Naive => {
                // The oracle wants per-head [1, H, s, dh] tensors; the
                // split/merge copies are O(s·dq), negligible next to it.
                let qt = split_heads(&qf, spec.hq, s, d_head);
                let kt = split_heads(&kf, spec.hkv, s, d_head);
                let vt = split_heads(&vf, spec.hkv, s, d_head);
                let ot = attention(&qt, &kt, &vt, spec)?;
                for h in 0..spec.hq {
                    for i in 0..s {
                        of[i * dq + h * d_head..][..d_head].copy_from_slice(ot.row4(0, h, i));
                    }
                }
            }
            Kernel::Tiled => match pool {
                Some(pool) if spec.hq * s.div_ceil(cfg.q_tile) > 1 => {
                    tiled::stream_slabs_parallel(
                        &qf, &kf, &vf, &mut of, s, d_head, spec, cfg, scale, pool,
                    )
                }
                _ => {
                    for h in 0..spec.hq {
                        let hk = h / group;
                        tiled::stream_head(
                            &qf,
                            dq,
                            h * d_head,
                            &kf,
                            dkv,
                            hk * d_head,
                            &vf,
                            &mut of,
                            dq,
                            h * d_head,
                            s,
                            d_head,
                            spec.for_head(h),
                            cfg,
                            scale,
                        );
                    }
                }
            },
        }
        let yb = linalg::matmul(imp, &of, wo, s, dq, dm, pool);
        y.data[ib * s * dm..][..s * dm].copy_from_slice(&yb);
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
    }

    #[test]
    fn uniform_scores_average_values() {
        let b = 1;
        let (hq, hkv, s, d) = (2, 1, 8, 4);
        let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; b * hq * s * d]).unwrap();
        let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; b * hkv * s * d]).unwrap();
        let v = randn(&[b, hkv, s, d], 1);
        let out = attention(&q, &k, &v, Spec::full(hq, hkv)).unwrap();
        // mean of v rows
        for h in 0..hq {
            for dd in 0..d {
                let mean: f32 = (0..s).map(|j| v.get4(0, 0, j, dd)).sum::<f32>() / s as f32;
                for i in 0..s {
                    assert!((out.get4(0, h, i, dd) - mean).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn causal_first_row_is_first_value() {
        let (b, hq, hkv, s, d) = (1, 2, 2, 6, 4);
        let q = randn(&[b, hq, s, d], 2);
        let k = randn(&[b, hkv, s, d], 3);
        let v = randn(&[b, hkv, s, d], 4);
        let out = attention(&q, &k, &v, Spec::causal(hq, hkv)).unwrap();
        for h in 0..hq {
            for dd in 0..d {
                assert!((out.get4(0, h, 0, dd) - v.get4(0, h, 0, dd)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn window_one_selects_own_value() {
        let (b, hq, hkv, s, d) = (1, 2, 1, 5, 3);
        let q = randn(&[b, hq, s, d], 5);
        let k = randn(&[b, hkv, s, d], 6);
        let v = randn(&[b, hkv, s, d], 7);
        let spec = Spec {
            window: Some(1),
            ..Spec::full(hq, hkv)
        };
        let out = attention(&q, &k, &v, spec).unwrap();
        for h in 0..hq {
            for i in 0..s {
                for dd in 0..d {
                    assert!((out.get4(0, h, i, dd) - v.get4(0, 0, i, dd)).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn kv_grouping_reads_correct_head() {
        // hq=4, hkv=2: heads 0,1 -> kv0; heads 2,3 -> kv1. Zero kv head 1's
        // values; outputs of heads 2,3 must be exactly zero.
        let (b, hq, hkv, s, d) = (1, 4, 2, 6, 4);
        let q = randn(&[b, hq, s, d], 8);
        let k = randn(&[b, hkv, s, d], 9);
        let mut v = randn(&[b, hkv, s, d], 10);
        for i in 0..s {
            for dd in 0..d {
                v.set4(0, 1, i, dd, 0.0);
            }
        }
        let out = attention(&q, &k, &v, Spec::full(hq, hkv)).unwrap();
        for h in 2..4 {
            for i in 0..s {
                for dd in 0..d {
                    assert_eq!(out.get4(0, h, i, dd), 0.0);
                }
            }
        }
    }

    #[test]
    fn rejects_bad_specs() {
        let t = randn(&[1, 3, 4, 2], 0);
        let k = randn(&[1, 2, 4, 2], 0);
        assert!(attention(&t, &k, &k, Spec::full(3, 2)).is_err());
        let err = Spec {
            window: Some(0),
            ..Spec::full(2, 2)
        }
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("window must be positive"), "{err:#}");
        // Pattern validation flows through Spec::validate too.
        let err = Spec::causal(2, 2)
            .with_pattern(MaskPattern::Strided { stride: 0 })
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("pattern stride must be positive"), "{err:#}");
        let err = Spec::causal(2, 2)
            .with_pattern(MaskPattern::Window { window: 0 })
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("pattern window must be positive"), "{err:#}");
    }

    #[test]
    fn dense_pattern_is_identity_and_sparse_patterns_mask_the_oracle() {
        // strided:2 under uniform scores: row i averages the visible keys
        // j <= i with (i - j) % 2 == 0 — directly checkable against the
        // per-element rule.
        let (b, hq, hkv, s, d) = (1, 2, 1, 7, 3);
        let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; hq * s * d]).unwrap();
        let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; s * d]).unwrap();
        let v = randn(&[b, hkv, s, d], 21);
        let dense = attention(&q, &k, &v, Spec::causal(hq, hkv)).unwrap();
        let explicit = attention(
            &q,
            &k,
            &v,
            Spec::causal(hq, hkv).with_pattern(MaskPattern::Dense),
        )
        .unwrap();
        assert_eq!(dense.data, explicit.data, "Dense must be bit-identical");
        let strided = attention(
            &q,
            &k,
            &v,
            Spec::causal(hq, hkv).with_pattern(MaskPattern::Strided { stride: 2 }),
        )
        .unwrap();
        for i in 0..s {
            let vis: Vec<usize> = (0..=i).filter(|j| (i - j) % 2 == 0).collect();
            for dd in 0..d {
                let mean: f32 =
                    vis.iter().map(|&j| v.get4(0, 0, j, dd)).sum::<f32>() / vis.len() as f32;
                let got = strided.get4(0, 0, i, dd);
                assert!((got - mean).abs() < 1e-5, "row {i} dim {dd}: {got} vs {mean}");
            }
        }
    }

    #[test]
    fn fully_masked_bitmap_rows_yield_exact_zeros_not_nan() {
        // Bitmap with an all-zero query-block row: those rows see nothing
        // and must come out exactly zero (denominator-0 path), never NaN.
        let bid = pattern::register_bitmap(
            BlockBitmap::new(2, 3, 3, vec![
                true, false, false, //
                false, false, false, // rows 2..4 fully masked
                true, false, true,
            ])
            .unwrap(),
        );
        let (b, hq, hkv, s, d) = (1, 2, 1, 6, 3);
        let q = randn(&[b, hq, s, d], 31);
        let k = randn(&[b, hkv, s, d], 32);
        let v = randn(&[b, hkv, s, d], 33);
        let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Bitmap(bid));
        let out = attention(&q, &k, &v, spec).unwrap();
        assert!(out.data.iter().all(|x| x.is_finite()), "no NaNs anywhere");
        for h in 0..hq {
            for i in 2..4 {
                for dd in 0..d {
                    assert_eq!(out.get4(0, h, i, dd), 0.0, "masked row {i} head {h}");
                }
            }
        }
        // Row 5 (block 2) sees blocks 0 and 2: keys 0,1,4,5 — nonzero.
        assert!((0..d).any(|dd| out.get4(0, 0, 5, dd) != 0.0));
    }

    #[test]
    fn per_head_tables_give_each_head_its_own_mask() {
        // Head 0 dense, head 1 window:1 (sees only itself) under uniform
        // scores: head 1's rows equal v rows exactly, head 0 averages.
        let tid = pattern::register_head_table(vec![
            MaskPattern::Dense,
            MaskPattern::Window { window: 1 },
        ])
        .unwrap();
        let (b, hq, hkv, s, d) = (1, 2, 1, 5, 3);
        let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; hq * s * d]).unwrap();
        let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; s * d]).unwrap();
        let v = randn(&[b, hkv, s, d], 41);
        let spec = Spec::full(hq, hkv).with_pattern(MaskPattern::PerHead(tid));
        let out = attention(&q, &k, &v, spec).unwrap();
        for i in 0..s {
            for dd in 0..d {
                let mean: f32 = (0..s).map(|j| v.get4(0, 0, j, dd)).sum::<f32>() / s as f32;
                assert!((out.get4(0, 0, i, dd) - mean).abs() < 1e-5, "head 0 row {i}");
                assert!(
                    (out.get4(0, 1, i, dd) - v.get4(0, 0, i, dd)).abs() < 1e-5,
                    "head 1 row {i}"
                );
            }
        }
    }

    #[test]
    fn noncausal_window_is_symmetric() {
        // |i - j| < w on both sides: with w = 2, row i must blend values
        // i-1, i, i+1 — in particular it *must* see one future key, which
        // the old (buggy) masking silently dropped.
        let (b, hq, hkv, s, d) = (1, 1, 1, 6, 3);
        let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; s * d]).unwrap();
        let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; s * d]).unwrap();
        let v = randn(&[b, hkv, s, d], 12);
        let spec = Spec {
            window: Some(2),
            ..Spec::full(hq, hkv)
        };
        let out = attention(&q, &k, &v, spec).unwrap();
        for i in 0..s {
            let (lo, hi) = (i.saturating_sub(1), (i + 2).min(s));
            for dd in 0..d {
                let mean: f32 =
                    (lo..hi).map(|j| v.get4(0, 0, j, dd)).sum::<f32>() / (hi - lo) as f32;
                assert!(
                    (out.get4(0, 0, i, dd) - mean).abs() < 1e-5,
                    "row {i} dim {dd}: {} vs mean {mean}",
                    out.get4(0, 0, i, dd)
                );
            }
        }
    }

    #[test]
    fn causal_window_stays_causal() {
        // Uniform scores + causal window w: row i averages the last
        // min(w, i+1) values and never sees the future.
        let (b, hq, hkv, s, d, w) = (1, 2, 1, 7, 3, 3);
        let q = Tensor::from_vec(&[b, hq, s, d], vec![1.0; hq * s * d]).unwrap();
        let k = Tensor::from_vec(&[b, hkv, s, d], vec![1.0; s * d]).unwrap();
        let v = randn(&[b, hkv, s, d], 13);
        let spec = Spec {
            window: Some(w),
            ..Spec::causal(hq, hkv)
        };
        let out = attention(&q, &k, &v, spec).unwrap();
        for i in 0..s {
            let lo = (i + 1).saturating_sub(w);
            for dd in 0..d {
                let mean: f32 =
                    (lo..=i).map(|j| v.get4(0, 0, j, dd)).sum::<f32>() / (i + 1 - lo) as f32;
                assert!((out.get4(0, 0, i, dd) - mean).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn visible_range_cases() {
        let causal = Spec::causal(1, 1);
        assert_eq!(visible_range(0, 8, causal), (0, 1));
        assert_eq!(visible_range(7, 8, causal), (0, 8));
        let swa = Spec {
            window: Some(3),
            ..Spec::causal(1, 1)
        };
        assert_eq!(visible_range(7, 8, swa), (5, 8));
        assert_eq!(visible_range(1, 8, swa), (0, 2));
        let full = Spec::full(1, 1);
        assert_eq!(visible_range(3, 8, full), (0, 8));
        // Symmetric (non-causal) window: w keys behind and ahead, clamped.
        let sym = Spec {
            window: Some(3),
            ..Spec::full(1, 1)
        };
        assert_eq!(visible_range(0, 8, sym), (0, 3));
        assert_eq!(visible_range(4, 8, sym), (2, 7));
        assert_eq!(visible_range(7, 8, sym), (5, 8));
    }
}
