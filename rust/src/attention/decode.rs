//! Incremental (decode-phase) attention: new query rows against cached K/V.
//!
//! Autoregressive generation never re-attends the whole sequence: after the
//! prompt is prefilled once, each step projects a *single* new token and
//! attends its query row against the session's per-layer KV cache. This is
//! the paper's §2.2/§5 memory-bound regime — the cost of a step is
//! streaming `2 · cache_len · Hkv · d_head` floats of cache, which is why
//! the KV-head count (not the query-head count) governs decode throughput
//! and why xSQA matches GQA here while sSQA deliberately pays more.
//!
//! This module is a thin driver over the tiled kernel's machinery
//! ([`tiled::stream_qtile_at`]): the same `linalg` score/PV micro-GEMMs,
//! the same online softmax, the same mask handling — only the addressing
//! differs. The query slab holds just the `n_new` fresh rows (row 0 of the
//! slab is absolute position `pos0`), while K/V slabs are the cache's
//! absolute rows `0 .. cache_len`. Chunked prefill falls out for free:
//! `n_new > 1` streams multiple query tiles against the same cache.
//!
//! Invariants (pinned by `rust/tests/decode_differential.rs` and the units
//! below): an N-step incremental decode produces, at every step, logits
//! identical (to 1e-4) to a full stateless re-forward of the same prefix —
//! across every head geometry, both attention kernels and both linalg
//! impls.

use super::tiled::{self, TileConfig};
use super::Spec;
use crate::linalg;

/// Attend `n_new` fresh query rows (absolute positions `pos0 ..
/// pos0 + n_new`) against `cache_len` cached key/value rows.
///
/// Layouts are the native backend's head-interleaved slabs:
/// `q`/`out`: `[n_new, Hq·d]`, `k_cache`/`v_cache`: `[≥cache_len, Hkv·d]`
/// (only the first `cache_len` rows are read). Requires
/// `pos0 + n_new == cache_len` — the fresh rows are always the tail of the
/// cache, so causal masking for row `ti` is `visible_range(pos0 + ti,
/// cache_len, spec)` exactly as in the full-sequence kernels.
#[allow(clippy::too_many_arguments)]
pub fn decode_attend(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    out: &mut [f32],
    pos0: usize,
    n_new: usize,
    cache_len: usize,
    d: usize,
    spec: Spec,
    imp: linalg::Impl,
) {
    debug_assert!(n_new > 0 && pos0 + n_new == cache_len);
    let (hq, hkv) = (spec.hq, spec.hkv);
    let group = hq / hkv;
    let (dq, dkv) = (hq * d, hkv * d);
    debug_assert!(q.len() >= n_new * dq && out.len() >= n_new * dq);
    debug_assert!(k_cache.len() >= cache_len * dkv && v_cache.len() >= cache_len * dkv);
    let scale = 1.0 / (d as f32).sqrt();
    let cfg = TileConfig::default().with_linalg(imp);
    for h in 0..hq {
        let hk = h / group;
        // Tile over the fresh rows (n_new is 1 in steady-state decode, a
        // whole prompt chunk during chunked prefill).
        let mut r0 = 0;
        while r0 < n_new {
            let r1 = (r0 + cfg.q_tile).min(n_new);
            tiled::stream_qtile_at(
                q,
                dq,
                h * d,
                k_cache,
                dkv,
                hk * d,
                v_cache,
                &mut out[r0 * dq..],
                dq,
                h * d,
                cache_len,
                d,
                r0,
                pos0 + r0,
                r1 - r0,
                // Cached positions obey the same per-head visibility rules
                // as the full-sequence kernels (one seam, no decode drift).
                spec.for_head(h),
                cfg,
                scale,
            );
            r0 = r1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::tensor::Tensor;
    use crate::attention::{attention, Spec};
    use crate::util::rng::Pcg64;

    fn rand_slab(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..rows * cols).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Reshape a head-interleaved `[s, h*d]` slab into the oracle's
    /// `[1, h, s, d]` tensor.
    fn to_tensor(slab: &[f32], h: usize, s: usize, d: usize) -> Tensor {
        let mut t = Tensor::zeros(&[1, h, s, d]);
        for hh in 0..h {
            for i in 0..s {
                let base = t.idx4(0, hh, i, 0);
                t.data[base..base + d].copy_from_slice(&slab[i * h * d + hh * d..][..d]);
            }
        }
        t
    }

    /// Every step of an incremental decode must reproduce the oracle's row
    /// for the same absolute position over the full cache.
    #[test]
    fn incremental_rows_match_oracle() {
        let (hq, hkv, s, d) = (4usize, 2usize, 21usize, 8usize);
        let (dq, dkv) = (hq * d, hkv * d);
        let q = rand_slab(s, dq, 1);
        let k = rand_slab(s, dkv, 2);
        let v = rand_slab(s, dkv, 3);
        for spec in [
            Spec::causal(hq, hkv),
            Spec {
                window: Some(5),
                ..Spec::causal(hq, hkv)
            },
            Spec::causal(hq, hkv).with_pattern(crate::attention::MaskPattern::Strided { stride: 3 }),
            Spec::causal(hq, hkv)
                .with_pattern(crate::attention::MaskPattern::SinkLocal { sinks: 2, window: 4 }),
        ] {
            let want = attention(
                &to_tensor(&q, hq, s, d),
                &to_tensor(&k, hkv, s, d),
                &to_tensor(&v, hkv, s, d),
                spec,
            )
            .unwrap();
            for imp in [linalg::Impl::Scalar, linalg::Impl::Blocked, linalg::Impl::Simd] {
                // Prefill the first 6 rows in one chunk, then one row at a
                // time; each fresh row must match the oracle's.
                let mut check_rows = |pos0: usize, n_new: usize| {
                    let cache_len = pos0 + n_new;
                    let mut out = vec![f32::NAN; n_new * dq];
                    decode_attend(
                        &q[pos0 * dq..cache_len * dq],
                        &k[..cache_len * dkv],
                        &v[..cache_len * dkv],
                        &mut out,
                        pos0,
                        n_new,
                        cache_len,
                        d,
                        spec,
                        imp,
                    );
                    for ti in 0..n_new {
                        for h in 0..hq {
                            for dd in 0..d {
                                let got = out[ti * dq + h * d + dd];
                                let exp = want.get4(0, h, pos0 + ti, dd);
                                assert!(
                                    (got - exp).abs() < 1e-4,
                                    "{spec:?} {imp:?} row {} h{h} d{dd}: {got} vs {exp}",
                                    pos0 + ti
                                );
                            }
                        }
                    }
                };
                check_rows(0, 6); // chunked prefill
                for i in 6..s {
                    check_rows(i, 1); // token-by-token decode
                }
            }
        }
    }

    #[test]
    fn single_token_sequence() {
        // pos0 = 0, cache_len = 1: row attends only itself.
        let (hq, hkv, d) = (2usize, 1usize, 4usize);
        let q = rand_slab(1, hq * d, 7);
        let k = rand_slab(1, hkv * d, 8);
        let v = rand_slab(1, hkv * d, 9);
        let mut out = vec![f32::NAN; hq * d];
        let spec = Spec::causal(hq, hkv);
        decode_attend(&q, &k, &v, &mut out, 0, 1, 1, d, spec, linalg::Impl::Blocked);
        // softmax over one key is 1.0 -> output is exactly that value row.
        for h in 0..hq {
            for dd in 0..d {
                assert!((out[h * d + dd] - v[dd]).abs() < 1e-5);
            }
        }
    }
}
