//! Block-sparse mask patterns composed with the causal/window mask.
//!
//! [`MaskPattern`] generalizes [`super::Spec`]'s `{causal, window}` mask:
//! the effective visibility of a key `j` from a query row `i` is the AND
//! of the causal constraint, the sliding-window constraint, and the
//! pattern — so `MaskPattern::Dense` reproduces the pre-pattern kernels
//! bit-for-bit, and every sparse pattern only ever *removes* visible
//! positions. All three kernels (naive oracle, tiled streaming forward +
//! backward, incremental decode) dispatch through one seam:
//!
//! * [`ResolvedMask::pattern_visible`] / [`ResolvedMask::visible`] — the
//!   per-element rule the naive oracle applies and the tiled kernels use
//!   inside a visited tile;
//! * [`ResolvedMask::tile_visible`] — the O(1) per-tile test the tile
//!   iterators use to skip whole key tiles. It is exact (a tile is
//!   visited iff it holds at least one visible element) via diagonal-band
//!   arithmetic: for a tile pair the difference `d = i - j` sweeps a
//!   contiguous range, and every built-in pattern constrains `d` to an
//!   arithmetic progression `d = m·stride, |m| ≤ max_m`.
//!
//! Bitmap and per-head patterns carry data too big for a `Copy` spec, so
//! they live in a process-global append-only registry and the spec stores
//! a small id ([`BitmapId`] / [`HeadTableId`]). Per-head tables are
//! resolved to a concrete pattern by [`super::Spec::for_head`] at every
//! head-dispatch site; a `ResolvedMask` is always concrete.
//!
//! This module is clock-free and uses `std::sync` directly (not the loom
//! shim): the registry is append-only configuration state, not part of
//! the model-checked concurrent core, and kernels must stay buildable
//! under `--cfg loom` without exploring its interleavings.

use super::Spec;
use anyhow::{bail, ensure, Context, Result};
use std::sync::{Arc, Mutex, OnceLock};

/// Handle to a registered [`BlockBitmap`] (see [`register_bitmap`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BitmapId(pub u32);

/// Handle to a registered per-head pattern table
/// (see [`register_head_table`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HeadTableId(pub u32);

/// Which key positions a query row may attend, *in addition to* (AND-ed
/// with) the spec-level causal/window mask. Parsed from / rendered to the
/// CLI grammar `dense | window:W | strided:T | dilated:W:T | sink:S:W |
/// bitmap:N | heads:N`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskPattern {
    /// No extra masking: the spec's causal/window mask alone.
    Dense,
    /// Local band: `|i - j| < window`.
    Window { window: usize },
    /// Strided (dilated-full) pattern: `|i - j| % stride == 0`.
    Strided { stride: usize },
    /// Dilated local band: `|i - j| % stride == 0` and
    /// `|i - j| / stride < window` — `window` taps spaced `stride` apart.
    Dilated { window: usize, stride: usize },
    /// Attention-sink + local band: the first `sinks` keys are visible to
    /// every row, plus the local band `|i - j| < window`.
    SinkLocal { sinks: usize, window: usize },
    /// Config-supplied block bitmap, looked up in the registry.
    Bitmap(BitmapId),
    /// Per-head pattern table: head `h` runs `table[h % len]`. Must be
    /// resolved via [`Spec::for_head`] before reaching a kernel.
    PerHead(HeadTableId),
}

impl Default for MaskPattern {
    fn default() -> Self {
        MaskPattern::Dense
    }
}

impl MaskPattern {
    /// Parse the CLI/config grammar. Validates the result, including that
    /// `bitmap:N` / `heads:N` ids are actually registered.
    pub fn parse(s: &str) -> Result<Self> {
        let num = |x: &str, what: &str| -> Result<usize> {
            x.parse::<usize>()
                .ok()
                .with_context(|| format!("bad {what} {x:?} in mask pattern {s:?}"))
        };
        let parts: Vec<&str> = s.split(':').collect();
        let p = match parts.as_slice() {
            ["dense"] => MaskPattern::Dense,
            ["window", w] => MaskPattern::Window {
                window: num(w, "window")?,
            },
            ["strided", t] => MaskPattern::Strided {
                stride: num(t, "stride")?,
            },
            ["dilated", w, t] => MaskPattern::Dilated {
                window: num(w, "window")?,
                stride: num(t, "stride")?,
            },
            ["sink", k, w] => MaskPattern::SinkLocal {
                sinks: num(k, "sink count")?,
                window: num(w, "window")?,
            },
            ["bitmap", n] => MaskPattern::Bitmap(BitmapId(num(n, "bitmap id")? as u32)),
            ["heads", n] => MaskPattern::PerHead(HeadTableId(num(n, "table id")? as u32)),
            _ => bail!(
                "unknown mask pattern {s:?} (want dense | window:W | strided:T | \
                 dilated:W:T | sink:S:W | bitmap:N | heads:N)"
            ),
        };
        p.validate()?;
        Ok(p)
    }

    /// Inverse of [`MaskPattern::parse`].
    pub fn label(&self) -> String {
        match *self {
            MaskPattern::Dense => "dense".into(),
            MaskPattern::Window { window } => format!("window:{window}"),
            MaskPattern::Strided { stride } => format!("strided:{stride}"),
            MaskPattern::Dilated { window, stride } => format!("dilated:{window}:{stride}"),
            MaskPattern::SinkLocal { sinks, window } => format!("sink:{sinks}:{window}"),
            MaskPattern::Bitmap(BitmapId(n)) => format!("bitmap:{n}"),
            MaskPattern::PerHead(HeadTableId(n)) => format!("heads:{n}"),
        }
    }

    /// Reject degenerate parameters (zero window/stride) and dangling
    /// registry ids.
    pub fn validate(&self) -> Result<()> {
        match *self {
            MaskPattern::Dense => {}
            MaskPattern::Window { window }
            | MaskPattern::Dilated { window, stride: _ }
            | MaskPattern::SinkLocal { sinks: _, window } => {
                ensure!(window > 0, "pattern window must be positive");
            }
            MaskPattern::Strided { stride } => {
                ensure!(stride > 0, "pattern stride must be positive");
            }
            MaskPattern::Bitmap(id) => {
                ensure!(bitmap(id).is_some(), "bitmap pattern {} is not registered", id.0);
            }
            MaskPattern::PerHead(id) => {
                ensure!(
                    head_table(id).is_some(),
                    "per-head pattern table {} is not registered",
                    id.0
                );
            }
        }
        // Dilated constrains both knobs; the window arm above only caught
        // its window.
        if let MaskPattern::Dilated { stride, .. } = *self {
            ensure!(stride > 0, "pattern stride must be positive");
        }
        Ok(())
    }
}

/// A block-granular visibility bitmap: query block `qb` may attend key
/// block `kb` iff `bits[qb * k_blocks + kb]`. Positions beyond the mapped
/// `q_blocks * block` × `k_blocks * block` area are invisible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockBitmap {
    pub block: usize,
    pub q_blocks: usize,
    pub k_blocks: usize,
    bits: Vec<bool>,
}

impl BlockBitmap {
    pub fn new(block: usize, q_blocks: usize, k_blocks: usize, bits: Vec<bool>) -> Result<Self> {
        ensure!(block > 0, "bitmap block size must be positive");
        ensure!(
            q_blocks > 0 && k_blocks > 0,
            "bitmap needs at least one block row and column"
        );
        ensure!(
            bits.len() == q_blocks * k_blocks,
            "bitmap has {} bits, want q_blocks x k_blocks = {}",
            bits.len(),
            q_blocks * k_blocks
        );
        Ok(Self {
            block,
            q_blocks,
            k_blocks,
            bits,
        })
    }

    /// Block-coordinate lookup; out-of-range blocks are invisible.
    #[inline]
    pub fn block_visible(&self, qb: usize, kb: usize) -> bool {
        qb < self.q_blocks && kb < self.k_blocks && self.bits[qb * self.k_blocks + kb]
    }

    /// Element-coordinate lookup; positions beyond the mapped area are
    /// invisible.
    #[inline]
    pub fn visible(&self, i: usize, j: usize) -> bool {
        self.block_visible(i / self.block, j / self.block)
    }
}

// ---- registry -------------------------------------------------------------

struct Registry {
    bitmaps: Vec<Arc<BlockBitmap>>,
    tables: Vec<Arc<Vec<MaskPattern>>>,
}

fn registry() -> &'static Mutex<Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        Mutex::new(Registry {
            bitmaps: Vec::new(),
            tables: Vec::new(),
        })
    })
}

// The registry is append-only config state; a panic while holding the
// lock cannot leave it structurally invalid, so poisoning is tolerated
// (same policy as util::sync::lock, which this module avoids to stay
// buildable under `--cfg loom`).
fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    let mut guard = registry().lock().unwrap_or_else(|e| e.into_inner());
    f(&mut guard)
}

/// Register a validated bitmap; the returned id is what `bitmap:N`
/// strings and [`MaskPattern::Bitmap`] specs refer to.
pub fn register_bitmap(b: BlockBitmap) -> BitmapId {
    with_registry(|r| {
        r.bitmaps.push(Arc::new(b));
        BitmapId((r.bitmaps.len() - 1) as u32)
    })
}

/// Look up a registered bitmap.
pub fn bitmap(id: BitmapId) -> Option<Arc<BlockBitmap>> {
    with_registry(|r| r.bitmaps.get(id.0 as usize).cloned())
}

/// Register a per-head pattern table (head `h` runs `table[h % len]`).
/// Entries must be concrete (no nested `PerHead`) and individually valid.
pub fn register_head_table(table: Vec<MaskPattern>) -> Result<HeadTableId> {
    ensure!(!table.is_empty(), "per-head table must name at least one pattern");
    for p in &table {
        ensure!(
            !matches!(p, MaskPattern::PerHead(_)),
            "per-head table entries must be concrete patterns"
        );
        p.validate()?;
    }
    Ok(with_registry(|r| {
        r.tables.push(Arc::new(table));
        HeadTableId((r.tables.len() - 1) as u32)
    }))
}

/// Look up a registered per-head table.
pub fn head_table(id: HeadTableId) -> Option<Arc<Vec<MaskPattern>>> {
    with_registry(|r| r.tables.get(id.0 as usize).cloned())
}

// ---- resolved mask --------------------------------------------------------

/// One head's fully-resolved visibility rule: the spec's causal/window
/// mask AND a concrete pattern, with any bitmap already fetched from the
/// registry — built once per (head, query-tile), then queried lock-free.
#[derive(Debug, Clone)]
pub struct ResolvedMask {
    causal: bool,
    window: Option<usize>,
    pattern: MaskPattern,
    bitmap: Option<Arc<BlockBitmap>>,
}

impl ResolvedMask {
    /// Materialize `spec`'s mask. The pattern must be concrete — resolve
    /// per-head tables with [`Spec::for_head`] first.
    pub fn new(spec: Spec) -> Self {
        let bitmap = match spec.pattern {
            MaskPattern::Bitmap(id) => Some(
                bitmap(id).expect("bitmap pattern not registered (validate the Spec first)"),
            ),
            MaskPattern::PerHead(_) => {
                panic!("resolve PerHead patterns with Spec::for_head before building a ResolvedMask")
            }
            _ => None,
        };
        Self {
            causal: spec.causal,
            window: spec.window,
            pattern: spec.pattern,
            bitmap,
        }
    }

    /// True when the pattern adds no masking beyond causal/window (the
    /// pre-pattern fast path).
    #[inline]
    pub fn is_dense(&self) -> bool {
        matches!(self.pattern, MaskPattern::Dense)
    }

    /// The pattern component alone: may row `i` attend key `j`?
    /// (Causal/window are applied separately by the kernels' existing
    /// `visible_range` clipping; [`ResolvedMask::visible`] combines all.)
    #[inline]
    pub fn pattern_visible(&self, i: usize, j: usize) -> bool {
        let d = (i as i64 - j as i64).unsigned_abs() as usize;
        match self.pattern {
            MaskPattern::Dense => true,
            MaskPattern::Window { window } => d < window,
            MaskPattern::Strided { stride } => d % stride == 0,
            MaskPattern::Dilated { window, stride } => d % stride == 0 && d / stride < window,
            MaskPattern::SinkLocal { sinks, window } => j < sinks || d < window,
            MaskPattern::Bitmap(_) => {
                self.bitmap.as_ref().expect("bitmap resolved").visible(i, j)
            }
            MaskPattern::PerHead(_) => unreachable!("ResolvedMask is always concrete"),
        }
    }

    /// Full per-element rule: causal AND window AND pattern.
    #[inline]
    pub fn visible(&self, i: usize, j: usize) -> bool {
        if self.causal && j > i {
            return false;
        }
        if let Some(w) = self.window {
            if (i as i64 - j as i64).unsigned_abs() as usize >= w {
                return false;
            }
        }
        self.pattern_visible(i, j)
    }

    /// Exact O(1) tile test: does the query-tile `[i0, i1)` × key-tile
    /// `[j0, j1)` rectangle hold at least one fully-visible element?
    pub fn tile_visible(&self, i0: usize, i1: usize, j0: usize, j1: usize) -> bool {
        if i0 >= i1 || j0 >= j1 {
            return false;
        }
        match self.pattern {
            MaskPattern::Dense => self.diag(i0, i1, j0, j1, 1, i64::MAX),
            MaskPattern::Window { window } => self.diag(i0, i1, j0, j1, 1, window as i64 - 1),
            MaskPattern::Strided { stride } => {
                self.diag(i0, i1, j0, j1, stride as i64, i64::MAX)
            }
            MaskPattern::Dilated { window, stride } => {
                self.diag(i0, i1, j0, j1, stride as i64, window as i64 - 1)
            }
            MaskPattern::SinkLocal { sinks, window } => {
                (j0 < sinks && self.diag(i0, i1, j0, j1.min(sinks), 1, i64::MAX))
                    || self.diag(i0, i1, j0, j1, 1, window as i64 - 1)
            }
            MaskPattern::Bitmap(_) => {
                let bm = self.bitmap.as_ref().expect("bitmap resolved");
                let b = bm.block;
                for qb in i0 / b..=(i1 - 1) / b {
                    for kb in j0 / b..=(j1 - 1) / b {
                        if !bm.block_visible(qb, kb) {
                            continue;
                        }
                        let (bi0, bi1) = (i0.max(qb * b), i1.min((qb + 1) * b));
                        let (bj0, bj1) = (j0.max(kb * b), j1.min((kb + 1) * b));
                        if self.diag(bi0, bi1, bj0, bj1, 1, i64::MAX) {
                            return true;
                        }
                    }
                }
                false
            }
            MaskPattern::PerHead(_) => unreachable!("ResolvedMask is always concrete"),
        }
    }

    /// Diagonal-band overlap: over the rectangle, `d = i - j` sweeps the
    /// contiguous range `[i0 - (j1-1), (i1-1) - j0]`; the pattern admits
    /// `d = m * stride` with `|m| <= max_m`, the causal mask `d >= 0`, and
    /// a spec window `w` admits `|d| <= w - 1`. The tile holds a visible
    /// element iff the intersection admits some integer `m`.
    fn diag(&self, i0: usize, i1: usize, j0: usize, j1: usize, stride: i64, max_m: i64) -> bool {
        let mut dlo = i0 as i64 - (j1 as i64 - 1);
        let mut dhi = (i1 as i64 - 1) - j0 as i64;
        if self.causal {
            dlo = dlo.max(0);
        }
        if let Some(w) = self.window {
            dlo = dlo.max(-(w as i64 - 1));
            dhi = dhi.min(w as i64 - 1);
        }
        if dlo > dhi {
            return false;
        }
        let mlo = ceil_div(dlo, stride).max(-max_m);
        let mhi = dhi.div_euclid(stride).min(max_m);
        mlo <= mhi
    }
}

/// `ceil(a / b)` for `b > 0` on signed `a`.
#[inline]
fn ceil_div(a: i64, b: i64) -> i64 {
    -((-a).div_euclid(b))
}

/// Reject bitmap patterns whose block size does not tile evenly into the
/// kernel's tile sizes — keeps every `(q_tile, k_tile)` tile inside an
/// aligned grid of bitmap blocks. Checks per-head table entries too.
pub fn check_tiling(spec: Spec, q_tile: usize, k_tile: usize) -> Result<()> {
    let check_one = |p: MaskPattern| -> Result<()> {
        if let MaskPattern::Bitmap(id) = p {
            let bm = bitmap(id)
                .with_context(|| format!("bitmap pattern {} is not registered", id.0))?;
            ensure!(
                bm.block % q_tile == 0 && bm.block % k_tile == 0,
                "bitmap block {} must be a multiple of the tile sizes {}x{}",
                bm.block,
                q_tile,
                k_tile
            );
        }
        Ok(())
    };
    match spec.pattern {
        MaskPattern::PerHead(id) => {
            let table = head_table(id)
                .with_context(|| format!("per-head pattern table {} is not registered", id.0))?;
            for &p in table.iter() {
                check_one(p)?;
            }
            Ok(())
        }
        p => check_one(p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_with(pattern: MaskPattern, causal: bool, window: Option<usize>) -> Spec {
        Spec {
            hq: 1,
            hkv: 1,
            causal,
            window,
            pattern,
        }
    }

    #[test]
    fn parse_label_round_trips() {
        for s in [
            "dense",
            "window:7",
            "strided:3",
            "dilated:2:5",
            "sink:4:16",
        ] {
            let p = MaskPattern::parse(s).unwrap();
            assert_eq!(p.label(), s);
        }
        let id = register_bitmap(BlockBitmap::new(8, 2, 2, vec![true; 4]).unwrap());
        let s = format!("bitmap:{}", id.0);
        assert_eq!(MaskPattern::parse(&s).unwrap(), MaskPattern::Bitmap(id));
        let tid = register_head_table(vec![MaskPattern::Dense]).unwrap();
        let s = format!("heads:{}", tid.0);
        assert_eq!(MaskPattern::parse(&s).unwrap(), MaskPattern::PerHead(tid));
        assert!(MaskPattern::parse("sliding:3").is_err());
        assert!(MaskPattern::parse("window").is_err());
        assert!(MaskPattern::parse("window:x").is_err());
    }

    #[test]
    fn validation_rejects_degenerate_patterns() {
        let err = MaskPattern::Window { window: 0 }.validate().unwrap_err();
        assert!(err.to_string().contains("pattern window must be positive"), "{err:#}");
        let err = MaskPattern::Strided { stride: 0 }.validate().unwrap_err();
        assert!(err.to_string().contains("pattern stride must be positive"), "{err:#}");
        let err = MaskPattern::Dilated { window: 2, stride: 0 }.validate().unwrap_err();
        assert!(err.to_string().contains("pattern stride must be positive"), "{err:#}");
        let err = MaskPattern::Dilated { window: 0, stride: 2 }.validate().unwrap_err();
        assert!(err.to_string().contains("pattern window must be positive"), "{err:#}");
        let err = MaskPattern::SinkLocal { sinks: 1, window: 0 }.validate().unwrap_err();
        assert!(err.to_string().contains("pattern window must be positive"), "{err:#}");
        let err = MaskPattern::Bitmap(BitmapId(u32::MAX)).validate().unwrap_err();
        assert!(err.to_string().contains("is not registered"), "{err:#}");
        let err = MaskPattern::PerHead(HeadTableId(u32::MAX)).validate().unwrap_err();
        assert!(err.to_string().contains("is not registered"), "{err:#}");
    }

    #[test]
    fn bitmap_shape_validation() {
        let err = BlockBitmap::new(0, 1, 1, vec![true]).unwrap_err();
        assert!(err.to_string().contains("bitmap block size must be positive"), "{err:#}");
        let err = BlockBitmap::new(8, 0, 1, vec![]).unwrap_err();
        assert!(
            err.to_string().contains("bitmap needs at least one block row and column"),
            "{err:#}"
        );
        let err = BlockBitmap::new(8, 2, 2, vec![true; 3]).unwrap_err();
        assert!(
            err.to_string().contains("bitmap has 3 bits, want q_blocks x k_blocks = 4"),
            "{err:#}"
        );
    }

    #[test]
    fn head_table_validation() {
        let err = register_head_table(vec![]).unwrap_err();
        assert!(
            err.to_string().contains("per-head table must name at least one pattern"),
            "{err:#}"
        );
        let tid = register_head_table(vec![MaskPattern::Dense]).unwrap();
        let err = register_head_table(vec![MaskPattern::PerHead(tid)]).unwrap_err();
        assert!(
            err.to_string().contains("per-head table entries must be concrete"),
            "{err:#}"
        );
        let err =
            register_head_table(vec![MaskPattern::Window { window: 0 }]).unwrap_err();
        assert!(err.to_string().contains("pattern window must be positive"), "{err:#}");
    }

    #[test]
    fn check_tiling_rejects_misaligned_bitmap_blocks() {
        let id = register_bitmap(BlockBitmap::new(8, 2, 2, vec![true; 4]).unwrap());
        let spec = spec_with(MaskPattern::Bitmap(id), true, None);
        assert!(check_tiling(spec, 8, 8).is_ok());
        assert!(check_tiling(spec, 4, 8).is_ok(), "block 8 tiles evenly into 4x8");
        let err = check_tiling(spec, 8, 3).unwrap_err();
        assert!(
            err.to_string()
                .contains("bitmap block 8 must be a multiple of the tile sizes 8x3"),
            "{err:#}"
        );
        let tid = register_head_table(vec![MaskPattern::Dense, MaskPattern::Bitmap(id)]).unwrap();
        assert!(check_tiling(spec_with(MaskPattern::PerHead(tid), true, None), 8, 3).is_err());
        assert!(check_tiling(spec_with(MaskPattern::Dense, true, None), 7, 13).is_ok());
    }

    /// `tile_visible` is exact: equals the brute-force OR of `visible`
    /// over the tile rectangle, for every pattern kind × causal/window ×
    /// tile geometry drawn here.
    #[test]
    fn tile_visible_matches_elementwise_brute_force() {
        let bid = register_bitmap(
            BlockBitmap::new(
                4,
                3,
                3,
                vec![
                    true, false, false, //
                    false, false, true, //
                    false, true, false,
                ],
            )
            .unwrap(),
        );
        let patterns = [
            MaskPattern::Dense,
            MaskPattern::Window { window: 1 },
            MaskPattern::Window { window: 5 },
            MaskPattern::Strided { stride: 3 },
            MaskPattern::Dilated { window: 2, stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 3 },
            MaskPattern::Bitmap(bid),
        ];
        let s = 13usize;
        for &pattern in &patterns {
            for &causal in &[false, true] {
                for &window in &[None, Some(4usize)] {
                    let rm = ResolvedMask::new(spec_with(pattern, causal, window));
                    for &(qt, kt) in &[(3usize, 2usize), (4, 4), (5, 3), (1, 1)] {
                        let mut i0 = 0;
                        while i0 < s {
                            let i1 = (i0 + qt).min(s);
                            let mut j0 = 0;
                            while j0 < s {
                                let j1 = (j0 + kt).min(s);
                                let brute = (i0..i1)
                                    .any(|i| (j0..j1).any(|j| rm.visible(i, j)));
                                assert_eq!(
                                    rm.tile_visible(i0, i1, j0, j1),
                                    brute,
                                    "{} causal={causal} window={window:?} \
                                     tile [{i0},{i1})x[{j0},{j1})",
                                    pattern.label()
                                );
                                j0 = j1;
                            }
                            i0 = i1;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn for_head_resolves_per_head_tables() {
        let tid = register_head_table(vec![
            MaskPattern::Dense,
            MaskPattern::Window { window: 2 },
        ])
        .unwrap();
        let spec = Spec {
            hq: 4,
            hkv: 2,
            causal: true,
            window: None,
            pattern: MaskPattern::PerHead(tid),
        };
        assert_eq!(spec.for_head(0).pattern, MaskPattern::Dense);
        assert_eq!(spec.for_head(1).pattern, MaskPattern::Window { window: 2 });
        assert_eq!(spec.for_head(2).pattern, MaskPattern::Dense);
        // Concrete patterns pass through unchanged.
        let dense = spec_with(MaskPattern::Strided { stride: 2 }, true, None);
        assert_eq!(dense.for_head(3).pattern, MaskPattern::Strided { stride: 2 });
    }
}
