//! Flash-style streaming attention **backward**: tile-recomputed score
//! blocks on the `linalg` micro-GEMMs, driven by the forward's logsumexp
//! statistics.
//!
//! The paper's training claim is compute-bound (§3.2: the `H/Hq` FLOP
//! reduction pays in pre-training and full-sequence processing), but until
//! this module the backward half of every train step ran per-head, per-row
//! scalar loops with a full softmax recomputation per row — the backward
//! dominated step time and the measured training speedup never approached
//! the forward ratio. This is the backward analogue of [`super::tiled`]:
//!
//! * the forward tile streamer exports, per query row, one number — the
//!   logsumexp `L_i = m_i + ln(l_i)` of its scaled, masked scores
//!   ([`super::tiled::stream_qtile_at_lse`]) — so the backward recomputes
//!   any probability block directly as `P = exp(scale·QKᵀ − L)` without
//!   re-running the online max/normalizer search;
//! * per `(head, query-tile)` job, every key-tile step is four micro-GEMMs
//!   through [`crate::linalg`]: the score block `scale·Q Kᵀ`
//!   ([`linalg::score_block`]), `dP = dO Vᵀ` (the same block shape), then
//!   with `dS = P ∘ (dP − Δ) · scale` (where `Δ_i = dOᵢ·Oᵢ` is the
//!   softmax-Jacobian row term) the three gradient accumulations
//!   `dQ += dS K` ([`linalg::pv_block`]), `dK += dSᵀ Q` and `dV += Pᵀ dO`
//!   ([`linalg::ptx_block`]);
//! * key tiles outside [`tile_visible_range`] are skipped without touching
//!   K or V — masked-out keys provably receive exactly zero dK/dV
//!   (`rust/tests/properties.rs`);
//! * jobs fan out over the thread pool in fixed-size **waves** whose
//!   per-tile dK/dV accumulation buffers are merged in job order, so the
//!   reduction order — and therefore every gradient bit — is independent
//!   of worker count and scheduling (two runs on different pool sizes are
//!   bitwise equal).
//!
//! Row semantics mirror the forward exactly: a row whose normalizer was 0
//! (fully masked / all `-inf`) or that was poisoned by a `+inf` score
//! exported `lse = -inf`, and the backward emits zero attention gradients
//! for it — the same "zeros, never NaN" totality the forward guarantees.
//!
//! [`backward_naive_slabs`] keeps the PR-1 scalar loops (row-by-row softmax
//! recomputation, per-element dot products) as the differential oracle:
//! `rust/tests/grad_differential.rs` pins the streaming backward against it
//! to 1e-4 over the full variant × mask × length × linalg grid, and
//! finite-difference checks pin both against the loss itself.

use super::tiled::{self, tile_visible_range, TileConfig};
use super::{visible_range, ResolvedMask, Spec};
use crate::linalg;
use crate::util::simd;
use crate::util::threadpool::ThreadPool;
use std::sync::mpsc;

/// Jobs per parallel wave. Each `(head, query-tile)` job carries private
/// dQ/dK/dV tile buffers (worst case ~`2·s·d_head` floats for a causal
/// full-attention tile), so the wave size bounds transient memory at
/// `WAVE · 2·s·d_head` floats while still keeping every pool worker fed;
/// waves are a fixed partition of the job list, which is what makes the
/// merge order independent of the pool size.
const WAVE: usize = 16;

/// Tiled streaming forward over head-interleaved slabs that also exports
/// the per-row logsumexp statistics the streaming backward consumes.
///
/// Layouts match `runtime::native`'s projection slabs: `q`/`dout`-shaped
/// slabs are `[s, Hq·d]`, `k`/`v` are `[s, Hkv·d]`, `out` is `[s, Hq·d]`
/// (fully overwritten), and `lse` is head-major `[Hq, s]`
/// (`lse[h·s + i]` = logsumexp of head `h`, row `i`; `-inf` marks a row
/// whose probabilities are all exactly 0). With a pool, `(head, q-tile)`
/// jobs fan out and write disjoint slices; results are bitwise identical
/// to the serial path. Do not pass a pool from inside a pool job.
#[allow(clippy::too_many_arguments)]
pub fn forward_slabs_lse(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &mut [f32],
    lse: &mut [f32],
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    pool: Option<&ThreadPool>,
) {
    let (hq, hkv) = (spec.hq, spec.hkv);
    let group = hq / hkv;
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    debug_assert!(out.len() >= s * dq_cols && lse.len() >= hq * s);
    // Same drivers as the plain tiled forward (`stream_head` /
    // `stream_slabs_parallel` are thin wrappers over these) — one tile
    // walk serves both paths, with the statistics threaded through.
    match pool {
        Some(pool) if hq * s.div_ceil(cfg.q_tile) > 1 => tiled::stream_slabs_parallel_lse(
            q,
            k,
            v,
            out,
            Some(lse),
            s,
            d,
            spec,
            cfg,
            scale,
            pool,
        ),
        _ => {
            for h in 0..hq {
                let hk = h / group;
                tiled::stream_head_lse(
                    q,
                    dq_cols,
                    h * d,
                    k,
                    dkv_cols,
                    hk * d,
                    v,
                    out,
                    dq_cols,
                    h * d,
                    s,
                    d,
                    spec.for_head(h),
                    cfg,
                    scale,
                    Some(&mut lse[h * s..(h + 1) * s]),
                );
            }
        }
    }
}

/// One `(head, query-tile)` job's gradient contribution: a dense dQ tile
/// plus dK/dV accumulation buffers spanning only the tile's visible key
/// range (`k_lo..k_lo + dk.len()/d`).
struct TileGrad {
    h: usize,
    i0: usize,
    k_lo: usize,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

/// Backward of one query tile `[i0, i1)` of head `h` — the streaming core.
///
/// Recomputes each visible key tile's score block via one micro-GEMM,
/// turns it into probabilities with the forward's `lse` statistics (no
/// max/normalizer search), and accumulates the three gradient products as
/// blocked GEMM calls. Returns `None` when the whole tile is masked.
#[allow(clippy::too_many_arguments)]
fn backward_qtile(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    lse_head: &[f32],
    dout: &[f32],
    s: usize,
    d: usize,
    h: usize,
    hk: usize,
    dq_cols: usize,
    dkv_cols: usize,
    i0: usize,
    i1: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
) -> Option<TileGrad> {
    let tq = i1 - i0;
    let (k_lo, k_hi) = tile_visible_range(i0, i1, s, spec);
    if k_hi <= k_lo {
        return None;
    }
    // Callers hand us a concrete (for_head-resolved) spec; one registry
    // lookup here, then lock-free visibility queries per element.
    let rm = spec.resolved();
    let dense = rm.is_dense();
    let k_tile = cfg.k_tile;
    // Δ_i = dO_i · O_i — the softmax-Jacobian row term. Mathematically
    // Σ_j P_ij dP_ij, but computable from the forward's output without
    // touching the probabilities (the standard flash-backward identity).
    let mut delta = vec![0.0f32; tq];
    for (ti, dl) in delta.iter_mut().enumerate() {
        let base = (i0 + ti) * dq_cols + h * d;
        let dorow = &dout[base..base + d];
        let orow = &o[base..base + d];
        *dl = dorow.iter().zip(orow).map(|(a, b)| a * b).sum();
    }
    let mut dq_buf = vec![0.0f32; tq * d];
    let mut dk_buf = vec![0.0f32; (k_hi - k_lo) * d];
    let mut dv_buf = vec![0.0f32; (k_hi - k_lo) * d];
    // Block scratch: scores + dP + their masked P / dS twins — four
    // [q_tile, k_tile] blocks regardless of S, the same peak-storage
    // contract as the forward streamer.
    let mut scores = vec![0.0f32; tq * k_tile];
    let mut dp = vec![0.0f32; tq * k_tile];
    let mut probs = vec![0.0f32; tq * k_tile];
    let mut ds = vec![0.0f32; tq * k_tile];

    for jt in k_lo / k_tile..k_hi.div_ceil(k_tile) {
        // Clamp the block to the tile's visible union [k_lo, k_hi): unlike
        // the forward (which masks per row into full-width blocks), the
        // dK/dV accumulation buffers are offset by k_lo and sized to the
        // union, so the GEMMs must never address rows outside it.
        let j0 = (jt * k_tile).max(k_lo);
        let j1 = ((jt + 1) * k_tile).min(k_hi);
        let tk = j1 - j0;
        // Pattern-invisible key tiles contribute nothing to any gradient:
        // skip them like the forward does. The dK/dV buffers stay sized to
        // the [k_lo, k_hi) union, so skipped tiles simply remain zero.
        if !dense && !rm.tile_visible(i0, i1, j0, j1) {
            continue;
        }
        // 1. Score block recompute: scale·Q Kᵀ, one micro-GEMM.
        linalg::score_block(
            cfg.linalg, q, dq_cols, h * d, i0, tq, k, dkv_cols, hk * d, j0, tk, d, scale,
            &mut scores, k_tile,
        );
        // 2. dP block: dO Vᵀ — the same strided NT product, scale 1.
        linalg::score_block(
            cfg.linalg, dout, dq_cols, h * d, i0, tq, v, dkv_cols, hk * d, j0, tk, d, 1.0,
            &mut dp, k_tile,
        );
        // 3. P = exp(score − lse) under the row mask; dS = P∘(dP − Δ)·scale.
        for ti in 0..tq {
            let i = i0 + ti;
            let (lo, hi) = visible_range(i, s, spec);
            let (jlo, jhi) = (j0.max(lo), j1.min(hi));
            let prow = &mut probs[ti * k_tile..][..tk];
            let dsrow = &mut ds[ti * k_tile..][..tk];
            let l = lse_head[i];
            if jlo >= jhi || !l.is_finite() {
                // Row sees nothing here, or the forward zeroed it (empty
                // normalizer / poisoned +inf): zero gradients, like the
                // forward's zero outputs.
                prow.fill(0.0);
                dsrow.fill(0.0);
                continue;
            }
            let srow = &scores[ti * k_tile..][..tk];
            let dprow = &dp[ti * k_tile..][..tk];
            // Vectorized fast path (`Impl::Simd`, dense masks only),
            // mirroring the forward streamer: with every visible score
            // finite there is no per-key masking, so P and dS for the
            // segment come from one util::simd pass and the edges outside
            // [jlo, jhi) are zeroed. Non-finite scores fall back to the
            // exact scalar loop below.
            if dense && cfg.linalg == linalg::Impl::Simd {
                let (a, b) = (jlo - j0, jhi - j0);
                if simd::row_max_finite(&srow[a..b]).is_some() {
                    prow[..a].fill(0.0);
                    prow[b..].fill(0.0);
                    dsrow[..a].fill(0.0);
                    dsrow[b..].fill(0.0);
                    simd::probs_dscores(
                        &srow[a..b],
                        &dprow[a..b],
                        l,
                        delta[ti],
                        scale,
                        &mut prow[a..b],
                        &mut dsrow[a..b],
                    );
                    continue;
                }
            }
            for jj in 0..tk {
                let j = j0 + jj;
                let sc = srow[jj];
                // Masked, out-of-window, pattern-invisible, or non-finite
                // scores carry weight exactly 0 (matching the forward's
                // per-key masking).
                let p = if (jlo..jhi).contains(&j)
                    && sc.is_finite()
                    && (dense || rm.pattern_visible(i, j))
                {
                    (sc - l).exp()
                } else {
                    0.0
                };
                prow[jj] = p;
                dsrow[jj] = if p == 0.0 {
                    0.0
                } else {
                    p * (dprow[jj] - delta[ti]) * scale
                };
            }
        }
        // 4. The three gradient micro-GEMMs.
        //    dQ_tile += dS @ K_tile (rows 0..tq of the private buffer);
        linalg::pv_block(
            cfg.linalg, &ds, k_tile, tq, tk, k, dkv_cols, hk * d, j0, d, &mut dq_buf, d, 0,
        );
        //    dK_{j0..j1} += dSᵀ @ Q_tile;
        linalg::ptx_block(
            cfg.linalg, &ds, k_tile, tq, tk, q, dq_cols, h * d, i0, d, &mut dk_buf, d, 0,
            j0 - k_lo,
        );
        //    dV_{j0..j1} += Pᵀ @ dO_tile.
        linalg::ptx_block(
            cfg.linalg, &probs, k_tile, tq, tk, dout, dq_cols, h * d, i0, d, &mut dv_buf, d,
            0, j0 - k_lo,
        );
    }
    Some(TileGrad {
        h,
        i0,
        k_lo,
        dq: dq_buf,
        dk: dk_buf,
        dv: dv_buf,
    })
}

/// Flash-style streaming attention backward over head-interleaved slabs.
///
/// Inputs are the forward's projection slabs (`q`/`o`/`dout`: `[s, Hq·d]`,
/// `k`/`v`: `[s, Hkv·d]`) plus the head-major `[Hq, s]` logsumexp
/// statistics exported by [`forward_slabs_lse`]; `dq`/`dk`/`dv` are
/// **accumulated into** (callers pass zeroed buffers), with KV-head
/// sharing folding every query head's dK/dV into its `h / (Hq/Hkv)` group
/// exactly like the forward read them.
///
/// With a pool, `(head, query-tile)` jobs run in fixed-size waves and are
/// merged in job order — gradients are bitwise identical for any worker
/// count, including the serial `pool: None` path. Do not pass a pool from
/// inside a pool job (bounded-queue deadlock, as everywhere else).
#[allow(clippy::too_many_arguments)]
pub fn backward_tiled_slabs(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    lse: &[f32],
    dout: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    pool: Option<&ThreadPool>,
) {
    let (hq, hkv) = (spec.hq, spec.hkv);
    let group = hq / hkv;
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    debug_assert!(lse.len() >= hq * s);
    debug_assert!(dq.len() >= s * dq_cols && dk.len() >= s * dkv_cols);
    let n_tiles = s.div_ceil(cfg.q_tile);
    let tiles: Vec<(usize, usize)> = (0..hq)
        .flat_map(|h| (0..n_tiles).map(move |t| (h, t * cfg.q_tile)))
        .collect();

    for wave in tiles.chunks(WAVE) {
        let run_tile = |&(h, i0): &(usize, usize)| {
            let hk = h / group;
            let i1 = (i0 + cfg.q_tile).min(s);
            backward_qtile(
                q,
                k,
                v,
                o,
                &lse[h * s..(h + 1) * s],
                dout,
                s,
                d,
                h,
                hk,
                dq_cols,
                dkv_cols,
                i0,
                i1,
                spec.for_head(h),
                cfg,
                scale,
            )
        };
        let results: Vec<Option<TileGrad>> = match pool {
            Some(pool) if wave.len() > 1 => {
                let (tx, rx) = mpsc::channel::<(usize, Option<TileGrad>)>();
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(wave.len());
                for (idx, tile) in wave.iter().enumerate() {
                    let tx = tx.clone();
                    jobs.push(Box::new(move || {
                        let _ = tx.send((idx, run_tile(tile)));
                    }));
                }
                drop(tx);
                pool.run_borrowed(jobs);
                let mut slots: Vec<Option<TileGrad>> =
                    (0..wave.len()).map(|_| None).collect();
                for (idx, g) in rx.try_iter() {
                    slots[idx] = g;
                }
                slots
            }
            _ => wave.iter().map(run_tile).collect(),
        };
        // Merge this wave in job order: the (head, tile) enumeration — not
        // worker scheduling — fixes the floating-point reduction order.
        for g in results.into_iter().flatten() {
            let hk = g.h / group;
            for (ti, row) in g.dq.chunks_exact(d).enumerate() {
                let dst = &mut dq[(g.i0 + ti) * dq_cols + g.h * d..][..d];
                for (a, b) in dst.iter_mut().zip(row) {
                    *a += b;
                }
            }
            for (r, row) in g.dk.chunks_exact(d).enumerate() {
                let dst = &mut dk[(g.k_lo + r) * dkv_cols + hk * d..][..d];
                for (a, b) in dst.iter_mut().zip(row) {
                    *a += b;
                }
            }
            for (r, row) in g.dv.chunks_exact(d).enumerate() {
                let dst = &mut dv[(g.k_lo + r) * dkv_cols + hk * d..][..d];
                for (a, b) in dst.iter_mut().zip(row) {
                    *a += b;
                }
            }
        }
    }
}

/// Softmax of one attention row over its visible range (max-subtracted,
/// identical summation order to the naive oracle's) — the row primitive of
/// the scalar paths: the naive forward in `runtime::native::attend_slabs`
/// and the [`backward_naive_slabs`] oracle below.
///
/// `rm` is the row's (for_head-resolved) visibility rule: pattern-invisible
/// keys are masked to `-inf` before the max, exactly like the
/// [`super::attention`] oracle, and a row with no surviving key yields all
/// zeros — never `exp(-inf − -inf) = NaN`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_probs(
    q: &[f32],
    k: &[f32],
    i: usize,
    h: usize,
    hk: usize,
    s: usize,
    dh: usize,
    dq_cols: usize,
    dkv_cols: usize,
    scale: f32,
    lo: usize,
    hi: usize,
    rm: &ResolvedMask,
    probs: &mut [f32],
) {
    let qi = &q[i * dq_cols + h * dh..][..dh];
    let mut maxv = f32::NEG_INFINITY;
    debug_assert!(hi <= s && lo < hi);
    for j in lo..hi {
        if !rm.pattern_visible(i, j) {
            probs[j - lo] = f32::NEG_INFINITY;
            continue;
        }
        let kj = &k[j * dkv_cols + hk * dh..][..dh];
        let mut acc = 0.0f32;
        for (a, b) in qi.iter().zip(kj) {
            acc += a * b;
        }
        let sc = acc * scale;
        probs[j - lo] = sc;
        maxv = maxv.max(sc);
    }
    let mut denom = 0.0f32;
    for p in probs[..hi - lo].iter_mut() {
        if p.is_finite() {
            *p = (*p - maxv).exp();
            denom += *p;
        } else {
            // Pattern-masked (-inf) and overflowed (±inf/NaN) scores carry
            // weight 0; a +inf score still drives `denom` computation to a
            // zero row below because every finite exp(sc - inf) underflows.
            *p = 0.0;
        }
    }
    let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
    for p in probs[..hi - lo].iter_mut() {
        *p *= inv;
    }
}

/// The scalar attention backward — per-head, per-row loops with full
/// softmax recomputation, no tiling, no GEMMs. This is the PR-1 training
/// backward verbatim, kept (like `linalg::scalar` and the naive attention
/// oracle) purely as the differential reference the streaming backward is
/// pinned against; `Kernel::Naive` still selects it end-to-end.
///
/// Same slab layouts and accumulate-into semantics as
/// [`backward_tiled_slabs`]; needs no `lse` (it recomputes each row's
/// softmax from scratch, which is exactly the cost the tiled path
/// eliminates).
#[allow(clippy::too_many_arguments)]
pub fn backward_naive_slabs(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    s: usize,
    d: usize,
    spec: Spec,
    scale: f32,
) {
    let (hq, hkv) = (spec.hq, spec.hkv);
    let group = hq / hkv;
    let (dq_cols, dkv_cols) = (hq * d, hkv * d);
    let mut probs = vec![0.0f32; s];
    let mut dp = vec![0.0f32; s];
    for h in 0..hq {
        let hk = h / group;
        let rm = spec.for_head(h).resolved();
        for i in 0..s {
            let (lo, hi) = visible_range(i, s, spec);
            attn_probs(q, k, i, h, hk, s, d, dq_cols, dkv_cols, scale, lo, hi, &rm, &mut probs);
            let doi = &dout[i * dq_cols + h * d..][..d];
            let mut sum_pd = 0.0f32;
            for j in lo..hi {
                let vj = &v[j * dkv_cols + hk * d..][..d];
                let mut acc = 0.0f32;
                for (a, b) in doi.iter().zip(vj) {
                    acc += a * b;
                }
                dp[j - lo] = acc;
                sum_pd += probs[j - lo] * acc;
            }
            let qi_base = i * dq_cols + h * d;
            for j in lo..hi {
                let p = probs[j - lo];
                let ds = p * (dp[j - lo] - sum_pd) * scale;
                let kj = &k[j * dkv_cols + hk * d..][..d];
                for (dqv, &kv) in dq[qi_base..qi_base + d].iter_mut().zip(kj) {
                    *dqv += ds * kv;
                }
                let qi = &q[qi_base..qi_base + d];
                let dkj = &mut dk[j * dkv_cols + hk * d..j * dkv_cols + hk * d + d];
                for (dkv_, &qv) in dkj.iter_mut().zip(qi) {
                    *dkv_ += ds * qv;
                }
                if p != 0.0 {
                    let dvj = &mut dv[j * dkv_cols + hk * d..j * dkv_cols + hk * d + d];
                    for (dvv, &dov) in dvj.iter_mut().zip(doi) {
                        *dvv += p * dov;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Impl;
    use crate::util::rng::Pcg64;

    fn randn(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..len).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    }

    type Slabs = (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>);

    fn slabs(hq: usize, hkv: usize, s: usize, d: usize, seed: u64) -> Slabs {
        (
            randn(s * hq * d, seed),
            randn(s * hkv * d, seed + 1),
            randn(s * hkv * d, seed + 2),
            randn(s * hq * d, seed + 3), // dout
        )
    }

    /// lse matches a two-pass logsumexp of the masked, scaled scores.
    #[test]
    fn forward_lse_matches_two_pass_logsumexp() {
        let (hq, hkv, s, d) = (2usize, 1usize, 13usize, 4usize);
        let (q, k, v, _) = slabs(hq, hkv, s, d, 50);
        let spec = Spec {
            window: Some(5),
            ..Spec::causal(hq, hkv)
        };
        let scale = 1.0 / (d as f32).sqrt();
        let cfg = TileConfig::new(4, 4).unwrap();
        let mut out = vec![0.0f32; s * hq * d];
        let mut lse = vec![0.0f32; hq * s];
        forward_slabs_lse(&q, &k, &v, &mut out, &mut lse, s, d, spec, cfg, scale, None);
        for h in 0..hq {
            for i in 0..s {
                let (lo, hi) = visible_range(i, s, spec);
                let qi = &q[i * hq * d + h * d..][..d];
                let mut scores = Vec::new();
                for j in lo..hi {
                    let kj = &k[j * hkv * d..][..d];
                    scores.push(qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale);
                }
                let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let want = m + scores.iter().map(|x| (x - m).exp()).sum::<f32>().ln();
                let got = lse[h * s + i];
                assert!((want - got).abs() < 1e-4, "h={h} i={i}: {got} vs {want}");
            }
        }
    }

    /// Streaming backward agrees with the scalar oracle on a small slab
    /// (the exhaustive grid lives in rust/tests/grad_differential.rs).
    #[test]
    fn tiled_backward_matches_naive_oracle_smoke() {
        let (hq, hkv, s, d) = (4usize, 2usize, 21usize, 4usize);
        let (q, k, v, dout) = slabs(hq, hkv, s, d, 60);
        let spec = Spec::causal(hq, hkv);
        let scale = 1.0 / (d as f32).sqrt();
        for imp in [Impl::Scalar, Impl::Blocked, Impl::Simd] {
            let cfg = TileConfig::new(8, 8).unwrap().with_linalg(imp);
            let mut o = vec![0.0f32; s * hq * d];
            let mut lse = vec![0.0f32; hq * s];
            forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, scale, None);
            let (mut dq_t, mut dk_t, mut dv_t) = (
                vec![0.0f32; s * hq * d],
                vec![0.0f32; s * hkv * d],
                vec![0.0f32; s * hkv * d],
            );
            backward_tiled_slabs(
                &q, &k, &v, &o, &lse, &dout, &mut dq_t, &mut dk_t, &mut dv_t, s, d, spec, cfg,
                scale, None,
            );
            let (mut dq_n, mut dk_n, mut dv_n) = (
                vec![0.0f32; s * hq * d],
                vec![0.0f32; s * hkv * d],
                vec![0.0f32; s * hkv * d],
            );
            backward_naive_slabs(
                &q, &k, &v, &dout, &mut dq_n, &mut dk_n, &mut dv_n, s, d, spec, scale,
            );
            let diff = |a: &[f32], b: &[f32]| {
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
            };
            assert!(diff(&dq_t, &dq_n) < 1e-4, "{imp:?} dq {}", diff(&dq_t, &dq_n));
            assert!(diff(&dk_t, &dk_n) < 1e-4, "{imp:?} dk {}", diff(&dk_t, &dk_n));
            assert!(diff(&dv_t, &dv_n) < 1e-4, "{imp:?} dv {}", diff(&dv_t, &dv_n));
        }
    }

    /// Parallel waves merge in job order: bitwise equal to serial.
    #[test]
    fn parallel_backward_is_bitwise_deterministic() {
        let pool = ThreadPool::new(3, 64);
        let (hq, hkv, s, d) = (4usize, 2usize, 37usize, 4usize);
        let (q, k, v, dout) = slabs(hq, hkv, s, d, 70);
        let spec = Spec::causal(hq, hkv);
        let scale = 1.0 / (d as f32).sqrt();
        let cfg = TileConfig::new(4, 4).unwrap();
        let mut o = vec![0.0f32; s * hq * d];
        let mut lse = vec![0.0f32; hq * s];
        forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, scale, None);
        let run = |pool: Option<&ThreadPool>| {
            let mut dq = vec![0.0f32; s * hq * d];
            let mut dk = vec![0.0f32; s * hkv * d];
            let mut dv = vec![0.0f32; s * hkv * d];
            backward_tiled_slabs(
                &q, &k, &v, &o, &lse, &dout, &mut dq, &mut dk, &mut dv, s, d, spec, cfg, scale,
                pool,
            );
            (dq, dk, dv)
        };
        assert_eq!(run(None), run(Some(&pool)));
    }

    /// Sparse patterns run the same streaming-vs-scalar agreement (the
    /// exhaustive grid lives in rust/tests/grad_differential.rs).
    #[test]
    fn tiled_backward_matches_naive_oracle_under_sparse_patterns() {
        use crate::attention::MaskPattern;
        let (hq, hkv, s, d) = (4usize, 2usize, 21usize, 4usize);
        let (q, k, v, dout) = slabs(hq, hkv, s, d, 80);
        let scale = 1.0 / (d as f32).sqrt();
        let cfg = TileConfig::new(8, 8).unwrap();
        for pat in [
            MaskPattern::Strided { stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 4 },
        ] {
            let spec = Spec::causal(hq, hkv).with_pattern(pat);
            let mut o = vec![0.0f32; s * hq * d];
            let mut lse = vec![0.0f32; hq * s];
            forward_slabs_lse(&q, &k, &v, &mut o, &mut lse, s, d, spec, cfg, scale, None);
            let (mut dq_t, mut dk_t, mut dv_t) = (
                vec![0.0f32; s * hq * d],
                vec![0.0f32; s * hkv * d],
                vec![0.0f32; s * hkv * d],
            );
            backward_tiled_slabs(
                &q, &k, &v, &o, &lse, &dout, &mut dq_t, &mut dk_t, &mut dv_t, s, d, spec, cfg,
                scale, None,
            );
            let (mut dq_n, mut dk_n, mut dv_n) = (
                vec![0.0f32; s * hq * d],
                vec![0.0f32; s * hkv * d],
                vec![0.0f32; s * hkv * d],
            );
            backward_naive_slabs(
                &q, &k, &v, &dout, &mut dq_n, &mut dk_n, &mut dv_n, s, d, spec, scale,
            );
            let diff = |a: &[f32], b: &[f32]| {
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
            };
            assert!(diff(&dq_t, &dq_n) < 1e-4, "{pat:?} dq {}", diff(&dq_t, &dq_n));
            assert!(diff(&dk_t, &dk_n) < 1e-4, "{pat:?} dk {}", diff(&dk_t, &dk_n));
            assert!(diff(&dv_t, &dv_n) < 1e-4, "{pat:?} dv {}", diff(&dv_t, &dv_n));
        }
    }

    /// A row whose every key is pattern-masked yields zero probabilities,
    /// never `exp(-inf - -inf) = NaN`.
    #[test]
    fn attn_probs_zeroes_fully_masked_rows() {
        use crate::attention::{pattern, BlockBitmap, MaskPattern};
        // Query block 0 sees nothing; query block 1 sees key block 0 only.
        let bid = pattern::register_bitmap(
            BlockBitmap::new(4, 2, 2, vec![false, false, true, false]).unwrap(),
        );
        let (hq, hkv, s, d) = (1usize, 1usize, 8usize, 4usize);
        let (q, k, _, _) = slabs(hq, hkv, s, d, 90);
        let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Bitmap(bid));
        let rm = spec.resolved();
        let scale = 1.0 / (d as f32).sqrt();
        let mut probs = vec![f32::NAN; s];
        // Row 2 lives in query block 0, whose bitmap row is all-false.
        attn_probs(&q, &k, 2, 0, 0, s, d, d, d, scale, 0, 3, &rm, &mut probs);
        assert_eq!(&probs[..3], &[0.0, 0.0, 0.0]);
        // Row 5 (query block 1) sees keys 0..4 and normalizes over them.
        attn_probs(&q, &k, 5, 0, 0, s, d, d, d, scale, 0, 6, &rm, &mut probs);
        assert!((probs[..4].iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(&probs[4..6], &[0.0, 0.0]);
    }
}
