//! Flash-style tiled streaming attention: online softmax, no S×S buffer.
//!
//! The naive oracle in [`super::attention`] materializes the full `[S, S]`
//! score matrix per head, so memory — not FLOPs — becomes the binding
//! constraint long before the 32k–200k regime the paper benchmarks. This
//! kernel streams over fixed-size key tiles instead, keeping one running
//! `(max, normalizer, output)` triple per query row:
//!
//! ```text
//!   m' = max(m, max_j s_ij)                    (running max)
//!   α  = exp(m - m')                           (rescale factor)
//!   l' = α·l + Σ_j exp(s_ij - m')              (running normalizer)
//!   o' = α·o + Σ_j exp(s_ij - m')·v_j          (unnormalized output)
//! ```
//!
//! and divides by `l` once at the end. Peak score storage is one
//! `[q_tile, k_tile]` block regardless of S. Key tiles that fall entirely
//! outside the union of the query tile's visible ranges (causal and/or
//! sliding-window masks) are skipped without touching K or V.
//!
//! Compute substrate: each key-tile step runs as two micro-GEMMs through
//! [`crate::linalg`] — the whole `[q_tile, k_tile]` score block is one
//! `Q_tile · K_tileᵀ` product and the output accumulation is one
//! `probs · V_tile` product — instead of per-row scalar dots. Masking is
//! applied to the materialized block (flash-style), so diagonal tiles do at
//! most 2× the visible work while fully-visible tiles run at full GEMM
//! throughput. [`TileConfig::linalg`] selects the blocked kernels or the
//! scalar oracle loops.
//!
//! Invariants the test suites pin down (see `rust/tests/`):
//! * outputs match the naive oracle within 1e-4 for every head geometry
//!   (MHA, GQA, MQA, extreme SQA) and every mask, including sequence
//!   lengths that are not multiples of the tile size;
//! * softmax rows sum to 1 (probed with all-ones values);
//! * rows whose visible range is empty produce exact zeros, never NaN;
//! * the running max keeps large-magnitude logits finite, and non-finite
//!   scores reproduce the oracle bit-for-bit: `-inf`/NaN keys are masked
//!   out individually, while a `+inf` score (which dominates the oracle's
//!   row max and underflows its normalizer) zeroes the whole row;
//! * the set of key tiles visited equals the set of key tiles containing at
//!   least one `(i, j)` pair visible under the full mask — per-row
//!   [`super::visible_range`] AND-ed with the spec's
//!   [`super::MaskPattern`] ([`visited_key_tiles`] is the reference
//!   iterator; sparse patterns make it sub-quadratic in `S / k_tile`).

use super::pattern;
use super::tensor::Tensor;
use super::{check_shapes, visible_range, Spec};
use crate::linalg;
use crate::util::simd;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
use std::sync::mpsc;

/// Default query/key tile edge. 64 rows × 64 keys of f32 scores is 16 KiB —
/// comfortably inside L1/L2 alongside the K/V tile being streamed.
pub const DEFAULT_TILE: usize = 64;

/// Tile geometry + compute lowering of the streaming kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Query rows processed per tile.
    pub q_tile: usize,
    /// Keys consumed per inner step (the score block is `q_tile × k_tile`).
    pub k_tile: usize,
    /// GEMM lowering for the score and `probs @ V` blocks
    /// (`SQA_LINALG` picks the process-wide default; see [`crate::linalg`]).
    pub linalg: linalg::Impl,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self {
            q_tile: DEFAULT_TILE,
            k_tile: DEFAULT_TILE,
            linalg: linalg::Impl::from_env(),
        }
    }
}

impl TileConfig {
    pub fn new(q_tile: usize, k_tile: usize) -> Result<Self> {
        if q_tile == 0 || k_tile == 0 {
            bail!("tile sizes must be positive (got {q_tile}x{k_tile})");
        }
        Ok(Self {
            q_tile,
            k_tile,
            linalg: linalg::Impl::from_env(),
        })
    }

    /// Override the GEMM lowering (builder-style).
    pub fn with_linalg(mut self, imp: linalg::Impl) -> Self {
        self.linalg = imp;
        self
    }
}

/// Union of the visible key ranges of query rows `[i0, i1)`.
///
/// Both `lo(i)` and `hi(i)` of [`visible_range`] are non-decreasing in `i`
/// for every mask kind (causal, symmetric window, causal window, full), and
/// consecutive rows' ranges always touch or overlap (windows are ≥ 1), so
/// the union is exactly the interval `[lo(i0), hi(i1 - 1))` and every key
/// in it is visible to at least one row of the tile.
pub fn tile_visible_range(i0: usize, i1: usize, s: usize, spec: Spec) -> (usize, usize) {
    debug_assert!(i0 < i1 && i1 <= s);
    let (lo, _) = visible_range(i0, s, spec);
    let (_, hi) = visible_range(i1 - 1, s, spec);
    (lo, hi)
}

/// Indices of the key tiles the kernel visits for query tile `[i0, i1)`.
///
/// A key tile `t` covers keys `[t·k_tile, (t+1)·k_tile) ∩ [0, s)`; the
/// kernel visits exactly the tiles inside [`tile_visible_range`] that
/// additionally contain a pattern-visible `(i, j)` pair
/// ([`super::ResolvedMask::tile_visible`] — exact, not conservative).
/// `rust/tests/properties.rs` checks this against the per-element
/// visibility definition. Per-head specs must be resolved with
/// [`Spec::for_head`] first.
pub fn visited_key_tiles(i0: usize, i1: usize, s: usize, spec: Spec, k_tile: usize) -> Vec<usize> {
    let (lo, hi) = tile_visible_range(i0, i1, s, spec);
    if hi <= lo {
        return Vec::new();
    }
    let rm = spec.resolved();
    (lo / k_tile..hi.div_ceil(k_tile))
        .filter(|&jt| {
            let j0 = jt * k_tile;
            let j1 = ((jt + 1) * k_tile).min(s);
            rm.tile_visible(i0, i1, j0, j1)
        })
        .collect()
}

/// Stream one query tile `[i0, i1)` of one head.
///
/// `q`/`k`/`v` are full-sequence slabs addressed as
/// `row j -> slab[j * stride + off ..][..d]`, which covers both the oracle's
/// `[S, d]` per-head layout (`stride = d`, `off = 0`) and the native
/// backend's head-interleaved `[S, H·d]` matrices (`stride = H·d`,
/// `off = h·d`). `out` starts at query row `i0`: row `i` lands at
/// `out[(i - i0) * out_stride + out_off ..][..d]` and is fully overwritten.
///
/// Each key-tile step materializes its full `[q_tile, k_tile]` score block
/// as one `Q · Kᵀ` micro-GEMM ([`linalg::score_block`]), applies masking
/// and the online-softmax update per row, then accumulates the output as
/// one `probs · V` micro-GEMM ([`linalg::pv_block`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_qtile(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    i0: usize,
    i1: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
) {
    stream_qtile_at(
        q, q_stride, q_off, k, kv_stride, kv_off, v, out, out_stride, out_off, s, d, i0, i0,
        i1 - i0, spec, cfg, scale,
    )
}

/// [`stream_qtile`] with the query slab's row base decoupled from the
/// absolute sequence positions — the primitive the incremental decode path
/// ([`super::decode`]) is built on.
///
/// Query rows `q_row0 .. q_row0 + n_rows` of the `q` slab occupy *absolute*
/// positions `pos0 .. pos0 + n_rows` of a sequence whose keys `0 .. s` live
/// in `k`/`v` (for decode: the session KV cache, `s = cache_len`). Masking
/// uses the absolute positions, score/PV micro-GEMMs address the slab rows.
/// `out` rows are relative (`0 .. n_rows`), same as [`stream_qtile`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_qtile_at(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    q_row0: usize,
    pos0: usize,
    n_rows: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
) {
    stream_qtile_at_lse(
        q, q_stride, q_off, k, kv_stride, kv_off, v, out, out_stride, out_off, s, d, q_row0,
        pos0, n_rows, spec, cfg, scale, None,
    )
}

/// [`stream_qtile_at`] additionally exporting per-row softmax statistics:
/// `lse[ti] = m + ln(l)` — the logsumexp of row `pos0 + ti`'s *scaled,
/// masked* scores. This is the one extra number the streaming backward
/// ([`super::backward`]) needs to recompute any probability block as
/// `P = exp(scale·QKᵀ − lse)` without re-running the online max/normalizer
/// search. Rows whose normalizer is 0 (fully masked / all `-inf`) and
/// poisoned rows (a `+inf` score, which the forward degrades to zeros)
/// export `-inf`, marking "every probability of this row is exactly 0" —
/// the backward emits zero gradients for them, matching the forward's zero
/// outputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_qtile_at_lse(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    q_row0: usize,
    pos0: usize,
    n_rows: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    lse_out: Option<&mut [f32]>,
) {
    let tq = n_rows;
    let k_tile = cfg.k_tile;
    for ti in 0..tq {
        out[ti * out_stride + out_off..][..d].fill(0.0);
    }
    let (t_lo, t_hi) = tile_visible_range(pos0, pos0 + n_rows, s, spec);
    if t_hi <= t_lo {
        if let Some(lse) = lse_out {
            lse[..tq].fill(f32::NEG_INFINITY);
        }
        return; // whole tile masked: zeros, by construction not NaN
    }
    // One registry lookup per query tile, then lock-free visibility queries.
    // Callers hand us a concrete (for_head-resolved) spec.
    let rm = spec.resolved();
    let dense = rm.is_dense();
    // Running per-row state; `out` itself holds the unnormalized output.
    let mut m = vec![f32::NEG_INFINITY; tq];
    let mut l = vec![0.0f32; tq];
    // Oracle semantics for non-finite scores: -inf/NaN entries are masked
    // out individually, but a +inf score dominates the row max and drives
    // every exp (and the normalizer) to 0 — the whole row becomes zeros.
    let mut poisoned = vec![false; tq];
    // The only score storage: one [q_tile, k_tile] block, plus its
    // exponentiated twin feeding the probs @ V micro-GEMM.
    let mut scores = vec![0.0f32; tq * k_tile];
    let mut probs = vec![0.0f32; tq * k_tile];
    // Scratch row for the masked SIMD path: the visible segment with
    // pattern-invisible slots overwritten by -inf (which exp flushes to
    // exactly 0). Only the `Impl::Simd` + sparse-pattern combination uses it.
    let mut masked = if !dense && cfg.linalg == linalg::Impl::Simd {
        vec![0.0f32; k_tile]
    } else {
        Vec::new()
    };

    for jt in t_lo / k_tile..t_hi.div_ceil(k_tile) {
        let j0 = jt * k_tile;
        let j1 = ((jt + 1) * k_tile).min(s);
        let tk = j1 - j0;
        // Key tiles with no pattern-visible (i, j) pair are skipped without
        // touching K or V — the same set `visited_key_tiles` enumerates.
        if !dense && !rm.tile_visible(pos0, pos0 + n_rows, j0, j1) {
            continue;
        }
        // 1. The whole score block in one micro-GEMM (overwrites the block,
        //    so nothing stale survives from the previous key tile).
        linalg::score_block(
            cfg.linalg, q, q_stride, q_off, q_row0, tq, k, kv_stride, kv_off, j0, tk, d, scale,
            &mut scores, k_tile,
        );
        // 2. Per-row masking + online-softmax update into the probs block.
        let mut any = false;
        for ti in 0..tq {
            let i = pos0 + ti;
            let (lo, hi) = visible_range(i, s, spec);
            let (jlo, jhi) = (j0.max(lo), j1.min(hi));
            let srow = &scores[ti * k_tile..][..tk];
            let prow = &mut probs[ti * k_tile..][..tk];
            if jlo >= jhi {
                prow.fill(0.0); // row sees nothing in this key tile
                continue;
            }
            // Vectorized fast path (`Impl::Simd`, dense masks): with every
            // visible score finite there is no per-key masking and no
            // poisoning, so the row max, exp + normalizer sum, and output
            // rescale run through the util::simd helpers (fixed
            // lane-then-tail reduction order — deterministic for a given
            // segment length, so pool size still cannot change results).
            // Any non-finite score sends the row to the exact scalar path
            // below, which owns the ±inf/NaN semantics.
            if dense && cfg.linalg == linalg::Impl::Simd {
                let vis = &srow[jlo - j0..jhi - j0];
                if let Some(block_max) = simd::row_max_finite(vis) {
                    let m_new = m[ti].max(block_max);
                    // exp_approx(-inf) = 0 covers the first block.
                    let alpha = simd::exp_approx(m[ti] - m_new);
                    if alpha != 1.0 {
                        l[ti] *= alpha;
                        simd::scale(&mut out[ti * out_stride + out_off..][..d], alpha);
                    }
                    m[ti] = m_new;
                    prow[..jlo - j0].fill(0.0);
                    prow[jhi - j0..].fill(0.0);
                    l[ti] += simd::exp_sub_into(vis, m_new, &mut prow[jlo - j0..jhi - j0]);
                    any = true;
                    continue;
                }
            }
            // Vectorized masked path (`Impl::Simd`, sparse patterns): copy
            // the visible segment into the scratch row with pattern-invisible
            // slots forced to -inf — exp flushes them to exactly 0 on both
            // the AVX2 and scalar-mirror paths (shared `EXP_LO` cutoff), so
            // masked keys carry weight 0 just like the scalar loop below.
            // `row_max_masked` treats -inf as legitimate and bails (`None`)
            // only on NaN/+inf poison, which the scalar path owns; a +inf
            // hidden behind the pattern never reaches it (masked before the
            // max, exactly like the oracle).
            if !dense && cfg.linalg == linalg::Impl::Simd {
                let mrow = &mut masked[..jhi - jlo];
                for (jj, slot) in mrow.iter_mut().enumerate() {
                    let j = jlo + jj;
                    *slot = if rm.pattern_visible(i, j) {
                        srow[j - j0]
                    } else {
                        f32::NEG_INFINITY
                    };
                }
                if let Some(block_max) = simd::row_max_masked(mrow) {
                    if block_max == f32::NEG_INFINITY {
                        // Every visible key is pattern-masked (or -inf).
                        prow.fill(0.0);
                        continue;
                    }
                    let m_new = m[ti].max(block_max);
                    let alpha = simd::exp_approx(m[ti] - m_new);
                    if alpha != 1.0 {
                        l[ti] *= alpha;
                        simd::scale(&mut out[ti * out_stride + out_off..][..d], alpha);
                    }
                    m[ti] = m_new;
                    prow[..jlo - j0].fill(0.0);
                    prow[jhi - j0..].fill(0.0);
                    l[ti] += simd::exp_sub_into(mrow, m_new, &mut prow[jlo - j0..jhi - j0]);
                    any = true;
                    continue;
                }
            }
            let mut block_max = f32::NEG_INFINITY;
            for j in jlo..jhi {
                if !dense && !rm.pattern_visible(i, j) {
                    // Pattern-masked keys are -inf *before* the max in the
                    // oracle: they neither raise the max nor poison the row.
                    continue;
                }
                let sc = srow[j - j0];
                if sc.is_finite() {
                    block_max = block_max.max(sc);
                } else {
                    // -inf/NaN: this key contributes nothing; +inf: the
                    // whole row degrades to zeros like the oracle's.
                    poisoned[ti] |= sc == f32::INFINITY;
                }
            }
            if block_max == f32::NEG_INFINITY {
                // No finite score in this block: nothing to accumulate.
                prow.fill(0.0);
                continue;
            }
            let m_new = m[ti].max(block_max);
            // α = exp(m_old - m_new); exp(-inf) = 0 covers the first block.
            let alpha = (m[ti] - m_new).exp();
            if alpha != 1.0 {
                l[ti] *= alpha;
                for o in out[ti * out_stride + out_off..][..d].iter_mut() {
                    *o *= alpha;
                }
            }
            m[ti] = m_new;
            for (jj, pv) in prow.iter_mut().enumerate() {
                let j = j0 + jj;
                let sc = srow[jj];
                let p = if (jlo..jhi).contains(&j)
                    && sc.is_finite()
                    && (dense || rm.pattern_visible(i, j))
                {
                    (sc - m_new).exp()
                } else {
                    0.0 // masked, out of range, or non-finite
                };
                *pv = p;
                l[ti] += p;
            }
            any = true;
        }
        // 3. Output accumulation as one probs @ V micro-GEMM (masked
        //    entries carry weight exactly 0).
        if any {
            linalg::pv_block(
                cfg.linalg, &probs, k_tile, tq, tk, v, kv_stride, kv_off, j0, d, out,
                out_stride, out_off,
            );
        }
    }
    for ti in 0..tq {
        // l == 0 means no key survived (all masked or all -inf) and a +inf
        // score zeroes the whole row: in both cases emit exact zeros (what
        // the oracle computes) rather than dividing into NaN.
        let orow = &mut out[ti * out_stride + out_off..][..d];
        if l[ti] > 0.0 && !poisoned[ti] {
            let inv = 1.0 / l[ti];
            for o in orow.iter_mut() {
                *o *= inv;
            }
        } else {
            orow.fill(0.0);
        }
    }
    if let Some(lse) = lse_out {
        for ti in 0..tq {
            lse[ti] = if l[ti] > 0.0 && !poisoned[ti] {
                m[ti] + l[ti].ln()
            } else {
                f32::NEG_INFINITY
            };
        }
    }
}

/// Drive every query tile of one head through [`stream_qtile`].
///
/// `out` is the full `[S, ·]` output slab (row 0 based) addressed with the
/// same stride/offset convention as the inputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_head(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
) {
    stream_head_lse(
        q, q_stride, q_off, k, kv_stride, kv_off, v, out, out_stride, out_off, s, d, spec,
        cfg, scale, None,
    )
}

/// [`stream_head`] optionally exporting this head's per-row logsumexp into
/// `lse_out` (`[s]` — see [`stream_qtile_at_lse`] for the statistic's
/// semantics). One driver serves both the plain forward and the
/// backward-feeding forward, so the tile walk can never drift between them.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_head_lse(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    mut lse_out: Option<&mut [f32]>,
) {
    let mut i0 = 0;
    while i0 < s {
        let i1 = (i0 + cfg.q_tile).min(s);
        stream_qtile_at_lse(
            q,
            q_stride,
            q_off,
            k,
            kv_stride,
            kv_off,
            v,
            &mut out[i0 * out_stride..],
            out_stride,
            out_off,
            s,
            d,
            i0,
            i0,
            i1 - i0,
            spec,
            cfg,
            scale,
            lse_out.as_mut().map(|l| &mut l[i0..i1]),
        );
        i0 = i1;
    }
}

/// Fan one sequence's attention across `(head, query-tile)` jobs directly
/// on head-interleaved `[S, H·d]` projection slabs (`q: [S, Hq·d]`,
/// `k`/`v`: `[S, Hkv·d]`, `out: [S, Hq·d]`).
///
/// Jobs *borrow* the slabs via [`ThreadPool::run_borrowed`] — no `Arc`
/// clones, no per-head tensor splits; each job streams one query tile into
/// a private buffer and the caller thread assembles them. Do not call from
/// inside a job already running on `pool` (bounded-queue deadlock).
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_slabs_parallel(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &mut [f32],
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    pool: &ThreadPool,
) {
    stream_slabs_parallel_lse(q, k, v, out, None, s, d, spec, cfg, scale, pool)
}

/// [`stream_slabs_parallel`] optionally exporting the head-major `[Hq, s]`
/// per-row logsumexp (`lse[h·s + i]`; see [`stream_qtile_at_lse`]). Jobs
/// compute their tile's statistics only when requested; writes stay
/// disjoint, so results are bitwise identical to the serial
/// [`stream_head_lse`] walk for any pool size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_slabs_parallel_lse(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    out: &mut [f32],
    mut lse_out: Option<&mut [f32]>,
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
    pool: &ThreadPool,
) {
    let (hq, hkv) = (spec.hq, spec.hkv);
    let group = hq / hkv;
    let (dq, dkv) = (hq * d, hkv * d);
    let n_tiles = s.div_ceil(cfg.q_tile);
    let want_lse = lse_out.is_some();
    let (tx, rx) = mpsc::channel::<(usize, usize, Vec<f32>, Option<Vec<f32>>)>();
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(hq * n_tiles);
    for h in 0..hq {
        let hk = h / group;
        let hspec = spec.for_head(h);
        for t in 0..n_tiles {
            let i0 = t * cfg.q_tile;
            let i1 = (i0 + cfg.q_tile).min(s);
            let tx = tx.clone();
            jobs.push(Box::new(move || {
                let mut buf = vec![0.0f32; (i1 - i0) * d];
                let mut lbuf = if want_lse {
                    Some(vec![0.0f32; i1 - i0])
                } else {
                    None
                };
                stream_qtile_at_lse(
                    q,
                    dq,
                    h * d,
                    k,
                    dkv,
                    hk * d,
                    v,
                    &mut buf,
                    d,
                    0,
                    s,
                    d,
                    i0,
                    i0,
                    i1 - i0,
                    hspec,
                    cfg,
                    scale,
                    lbuf.as_deref_mut(),
                );
                let _ = tx.send((h, i0, buf, lbuf));
            }));
        }
    }
    drop(tx);
    pool.run_borrowed(jobs);
    for (h, i0, buf, lbuf) in rx.try_iter() {
        for (ti, row) in buf.chunks_exact(d).enumerate() {
            out[(i0 + ti) * dq + h * d..][..d].copy_from_slice(row);
        }
        if let (Some(lse), Some(lbuf)) = (lse_out.as_mut(), lbuf) {
            lse[h * s + i0..][..lbuf.len()].copy_from_slice(&lbuf);
        }
    }
}

/// Tiled streaming attention with the default tile geometry.
///
/// Same contract as [`super::attention`]: q `[B, Hq, S, d]`,
/// k/v `[B, Hkv, S, d]` → `[B, Hq, S, d]`.
pub fn attention_tiled(q: &Tensor, k: &Tensor, v: &Tensor, spec: Spec) -> Result<Tensor> {
    attention_tiled_cfg(q, k, v, spec, TileConfig::default())
}

/// Tiled streaming attention with explicit tile geometry (tests use tiny
/// tiles to exercise non-aligned sequence lengths cheaply).
pub fn attention_tiled_cfg(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
    cfg: TileConfig,
) -> Result<Tensor> {
    let (b, hq, s, d) = check_shapes(q, k, v, spec)?;
    pattern::check_tiling(spec, cfg.q_tile, cfg.k_tile)?;
    let group = hq / spec.hkv;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[b, hq, s, d]);
    for ib in 0..b {
        for h in 0..hq {
            let hk = h / group;
            let q_slab = &q.data[q.idx4(ib, h, 0, 0)..][..s * d];
            let k_slab = &k.data[k.idx4(ib, hk, 0, 0)..][..s * d];
            let v_slab = &v.data[v.idx4(ib, hk, 0, 0)..][..s * d];
            let o_base = (ib * hq + h) * s * d;
            let o_slab = &mut out.data[o_base..o_base + s * d];
            stream_head(
                q_slab,
                d,
                0,
                k_slab,
                d,
                0,
                v_slab,
                o_slab,
                d,
                0,
                s,
                d,
                spec.for_head(h),
                cfg,
                scale,
            );
        }
    }
    Ok(out)
}

/// Tiled attention fanned out across `(batch, head, query-tile)` jobs on a
/// [`ThreadPool`]. Each job streams one query tile into a private buffer
/// and *borrows* Q/K/V via [`ThreadPool::run_borrowed`] (no deep copies);
/// the caller thread assembles the buffers, so no unsafe sharing is
/// needed. Falls back to the serial kernel when there is only one job's
/// worth of work.
///
/// Do not call from inside a job already running on `pool` — nested
/// submission can deadlock the bounded queue.
pub fn attention_tiled_parallel(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
    cfg: TileConfig,
    pool: &ThreadPool,
) -> Result<Tensor> {
    let (b, hq, s, d) = check_shapes(q, k, v, spec)?;
    pattern::check_tiling(spec, cfg.q_tile, cfg.k_tile)?;
    let n_tiles = s.div_ceil(cfg.q_tile);
    if b * hq * n_tiles <= 1 {
        return attention_tiled_cfg(q, k, v, spec, cfg);
    }
    let group = hq / spec.hkv;
    let hkv = spec.hkv;
    let scale = 1.0 / (d as f32).sqrt();
    let (tx, rx) = mpsc::channel::<(usize, usize, usize, Vec<f32>)>();
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(b * hq * n_tiles);
    for ib in 0..b {
        for h in 0..hq {
            let hk = h / group;
            let hspec = spec.for_head(h);
            let q_slab = &q.data[(ib * hq + h) * s * d..][..s * d];
            let k_slab = &k.data[(ib * hkv + hk) * s * d..][..s * d];
            let v_slab = &v.data[(ib * hkv + hk) * s * d..][..s * d];
            for t in 0..n_tiles {
                let i0 = t * cfg.q_tile;
                let i1 = (i0 + cfg.q_tile).min(s);
                let tx = tx.clone();
                jobs.push(Box::new(move || {
                    let mut buf = vec![0.0f32; (i1 - i0) * d];
                    stream_qtile(
                        q_slab,
                        d,
                        0,
                        k_slab,
                        d,
                        0,
                        v_slab,
                        &mut buf,
                        d,
                        0,
                        s,
                        d,
                        i0,
                        i1,
                        hspec,
                        cfg,
                        scale,
                    );
                    let _ = tx.send((ib, h, i0, buf));
                }));
            }
        }
    }
    drop(tx);
    pool.run_borrowed(jobs);
    let mut out = Tensor::zeros(&[b, hq, s, d]);
    for (ib, h, i0, buf) in rx.try_iter() {
        let base = out.idx4(ib, h, i0, 0);
        out.data[base..base + buf.len()].copy_from_slice(&buf);
    }
    Ok(out)
}

/// [`attention_tiled_parallel`] taking ownership of Q/K/V. Retained for API
/// compatibility: since the parallel path borrows its inputs through
/// [`ThreadPool::run_borrowed`], ownership no longer buys anything — this
/// is now a thin wrapper.
pub fn attention_tiled_parallel_owned(
    q: Tensor,
    k: Tensor,
    v: Tensor,
    spec: Spec,
    cfg: TileConfig,
    pool: &ThreadPool,
) -> Result<Tensor> {
    attention_tiled_parallel(&q, &k, &v, spec, cfg, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention;
    use crate::util::rng::Pcg64;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
    }

    #[test]
    fn matches_oracle_on_default_tiles() {
        let (b, hq, hkv, s, d) = (2, 4, 2, 97, 8);
        let q = randn(&[b, hq, s, d], 1);
        let k = randn(&[b, hkv, s, d], 2);
        let v = randn(&[b, hkv, s, d], 3);
        for spec in [
            Spec::full(hq, hkv),
            Spec::causal(hq, hkv),
            Spec {
                window: Some(13),
                ..Spec::causal(hq, hkv)
            },
        ] {
            let want = attention(&q, &k, &v, spec).unwrap();
            let got = attention_tiled(&q, &k, &v, spec).unwrap();
            assert!(
                want.max_abs_diff(&got) < 1e-4,
                "{spec:?}: diff {}",
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn both_linalg_impls_match_oracle() {
        let (b, hq, hkv, s, d) = (1, 4, 2, 53, 8);
        let q = randn(&[b, hq, s, d], 21);
        let k = randn(&[b, hkv, s, d], 22);
        let v = randn(&[b, hkv, s, d], 23);
        let spec = Spec::causal(hq, hkv);
        let want = attention(&q, &k, &v, spec).unwrap();
        for imp in [linalg::Impl::Scalar, linalg::Impl::Blocked, linalg::Impl::Simd] {
            let cfg = TileConfig::new(16, 16).unwrap().with_linalg(imp);
            let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
            assert!(
                want.max_abs_diff(&got) < 1e-4,
                "{imp:?}: diff {}",
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4, 64);
        let (b, hq, hkv, s, d) = (2, 4, 1, 83, 8);
        let q = randn(&[b, hq, s, d], 4);
        let k = randn(&[b, hkv, s, d], 5);
        let v = randn(&[b, hkv, s, d], 6);
        let spec = Spec::causal(hq, hkv);
        let cfg = TileConfig::new(16, 16).unwrap();
        let serial = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
        let par = attention_tiled_parallel(&q, &k, &v, spec, cfg, &pool).unwrap();
        // Same per-tile arithmetic, so bitwise equality is expected.
        assert_eq!(serial.data, par.data);
    }

    #[test]
    fn slab_parallel_matches_serial_on_interleaved_layout() {
        let pool = ThreadPool::new(4, 64);
        let (hq, hkv, s, d) = (4usize, 2usize, 45usize, 8usize);
        let (dq, dkv) = (hq * d, hkv * d);
        let mut rng = Pcg64::new(31);
        let q: Vec<f32> = (0..s * dq).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let k: Vec<f32> = (0..s * dkv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..s * dkv).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let spec = Spec::causal(hq, hkv);
        let cfg = TileConfig::new(16, 16).unwrap();
        let scale = 1.0 / (d as f32).sqrt();
        let mut serial = vec![0.0f32; s * dq];
        for h in 0..hq {
            let hk = h / (hq / hkv);
            stream_head(
                &q,
                dq,
                h * d,
                &k,
                dkv,
                hk * d,
                &v,
                &mut serial,
                dq,
                h * d,
                s,
                d,
                spec,
                cfg,
                scale,
            );
        }
        let mut par = vec![0.0f32; s * dq];
        stream_slabs_parallel(&q, &k, &v, &mut par, s, d, spec, cfg, scale, &pool);
        assert_eq!(serial, par);
    }

    #[test]
    fn rows_with_no_surviving_keys_write_zeros_not_nan() {
        // The public masks never produce an empty visible range, but the
        // kernel must stay total: when a row's normalizer ends at 0 (every
        // score overflowed to -inf, the streaming analogue of an all-masked
        // row) the output must be exact zeros, never 0/0 = NaN.
        let s = 8;
        let d = 4;
        let spec = Spec::causal(1, 1);
        // q·k overflows to -inf for every pair: every block is skipped and
        // the normalizer stays 0.
        let q = vec![f32::MAX; s * d];
        let k = vec![f32::MIN; s * d];
        let v: Vec<f32> = (0..s * d).map(|x| x as f32).collect();
        let mut out = vec![f32::NAN; s * d]; // must be fully overwritten
        stream_qtile(
            &q,
            d,
            0,
            &k,
            d,
            0,
            &v,
            &mut out,
            d,
            0,
            s,
            d,
            0,
            s,
            spec,
            TileConfig::new(8, 4).unwrap(),
            1.0,
        );
        assert!(out.iter().all(|&x| x == 0.0), "{out:?}");
    }

    #[test]
    fn stale_scores_from_previous_block_are_not_reused() {
        // Row windows narrower than k_tile leave parts of the score block
        // unwritten on later tiles; those slots must never leak into p.
        let (hq, hkv, s, d) = (1, 1, 11, 4);
        let q = randn(&[1, hq, s, d], 7);
        let k = randn(&[1, hkv, s, d], 8);
        let v = randn(&[1, hkv, s, d], 9);
        let spec = Spec {
            window: Some(2),
            ..Spec::causal(hq, hkv)
        };
        let want = attention(&q, &k, &v, spec).unwrap();
        let got = attention_tiled_cfg(&q, &k, &v, spec, TileConfig::new(4, 4).unwrap()).unwrap();
        assert!(want.max_abs_diff(&got) < 1e-5);
    }

    #[test]
    fn large_magnitude_logits_stay_finite() {
        // Scores ~ ±2500: naive and tiled both max-subtract, so outputs
        // agree and stay finite (softmax saturates onto the argmax key).
        let (hq, hkv, s, d) = (2, 1, 33, 4);
        let mut q = randn(&[1, hq, s, d], 10);
        let mut k = randn(&[1, hkv, s, d], 11);
        for x in q.data.iter_mut() {
            *x *= 50.0;
        }
        for x in k.data.iter_mut() {
            *x *= 50.0;
        }
        let v = randn(&[1, hkv, s, d], 12);
        let cfg = TileConfig::new(8, 8).unwrap();
        for spec in [Spec::causal(hq, hkv), Spec::full(hq, hkv)] {
            let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
            assert!(got.data.iter().all(|x| x.is_finite()));
            let want = attention(&q, &k, &v, spec).unwrap();
            assert!(want.max_abs_diff(&got) < 1e-4);
        }
    }

    #[test]
    fn overflowing_rows_degrade_to_zeros_like_the_oracle() {
        let (hq, hkv, s, d) = (1, 1, 9, 4);
        let v = randn(&[1, hkv, s, d], 13);
        let spec = Spec::causal(hq, hkv);
        let cfg = TileConfig::new(4, 4).unwrap();
        // -inf overflow (q·k = MAX·MIN) and +inf overflow (q·k = MAX·MAX):
        // the oracle zeroes both kinds of row; tiled must agree, not NaN.
        for kval in [f32::MIN, f32::MAX] {
            let q = Tensor::from_vec(&[1, hq, s, d], vec![f32::MAX; s * d]).unwrap();
            let k = Tensor::from_vec(&[1, hkv, s, d], vec![kval; s * d]).unwrap();
            let want = attention(&q, &k, &v, spec).unwrap();
            let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
            assert!(want.data.iter().all(|&x| x == 0.0), "oracle kval={kval}");
            assert_eq!(want.data, got.data, "kval={kval}");
        }
    }

    #[test]
    fn single_plus_inf_score_zeroes_only_that_row() {
        // Key 1 sends row scores to +inf for every query row that sees it
        // (oracle: +inf dominates the row max, denom underflows to 0 ->
        // zeros); rows that never see key 1 must stay untouched and match.
        let (hq, hkv, s, d) = (1, 1, 6, 4);
        let q = Tensor::from_vec(&[1, hq, s, d], vec![1.0; s * d]).unwrap();
        let mut k = randn(&[1, hkv, s, d], 14);
        for dd in 0..d {
            k.set4(0, 0, 1, dd, f32::MAX);
        }
        let spec = Spec::causal(hq, hkv);
        let want = attention(&q, &k, &v_of(&k, 15), spec).unwrap();
        let got =
            attention_tiled_cfg(&q, &k, &v_of(&k, 15), spec, TileConfig::new(4, 4).unwrap())
                .unwrap();
        assert!(got.data.iter().all(|x| !x.is_nan()));
        // Row 0 (sees only key 0) is a plain softmax; rows >= 1 see the
        // poisoned key and must be zeros in both implementations.
        assert!(want.max_abs_diff(&got) < 1e-5);
        for i in 1..s {
            for dd in 0..d {
                assert_eq!(got.get4(0, 0, i, dd), 0.0, "row {i}");
            }
        }
    }

    #[test]
    fn nan_scores_are_masked_like_the_oracle() {
        // A NaN q row makes all its scores NaN: the oracle masks them
        // (weight 0, denom 0 -> zeros); the tiled kernel must agree and
        // must not leak the NaN into neighbouring rows of the tile.
        let (hq, hkv, s, d) = (1, 1, 7, 4);
        let mut q = randn(&[1, hq, s, d], 16);
        for dd in 0..d {
            q.set4(0, 0, 3, dd, f32::NAN);
        }
        let k = randn(&[1, hkv, s, d], 17);
        let v = randn(&[1, hkv, s, d], 18);
        let spec = Spec::causal(hq, hkv);
        let want = attention(&q, &k, &v, spec).unwrap();
        let got = attention_tiled_cfg(&q, &k, &v, spec, TileConfig::new(4, 4).unwrap()).unwrap();
        assert!(got.data.iter().all(|x| !x.is_nan()));
        assert!(want.max_abs_diff(&got) < 1e-5);
        for dd in 0..d {
            assert_eq!(got.get4(0, 0, 3, dd), 0.0);
        }
    }

    fn v_of(k: &Tensor, seed: u64) -> Tensor {
        randn(&k.shape, seed)
    }

    #[test]
    fn tile_range_helpers_agree_with_visible_range() {
        let spec = Spec {
            window: Some(3),
            ..Spec::causal(1, 1)
        };
        let s = 32;
        assert_eq!(tile_visible_range(4, 8, s, spec), (2, 8));
        assert_eq!(visited_key_tiles(4, 8, s, spec, 4), vec![0, 1]);
        // Causal full: tile [8, 16) sees keys [0, 16).
        let causal = Spec::causal(1, 1);
        assert_eq!(tile_visible_range(8, 16, s, causal), (0, 16));
        assert_eq!(visited_key_tiles(8, 16, s, causal, 8), vec![0, 1]);
    }

    #[test]
    fn strided_pattern_skips_interior_key_tiles() {
        // Causal strided:8, query tile [8, 12), k_tile 4. Rows 8..12 see
        // keys j <= i with (i - j) % 8 == 0: {0..3} and {8..11} — the middle
        // tile {4..7} contains no visible pair and must be skipped.
        let spec = Spec::causal(1, 1).with_pattern(super::super::MaskPattern::Strided { stride: 8 });
        assert_eq!(visited_key_tiles(8, 12, 32, spec, 4), vec![0, 2]);
        // And the skip list matches a brute-force per-element check.
        for (i0, i1) in [(0, 4), (8, 12), (12, 16), (28, 32)] {
            let rm = spec.resolved();
            let want: Vec<usize> = (0..32usize.div_ceil(4))
                .filter(|&jt| {
                    (i0..i1).any(|i| {
                        (jt * 4..(jt + 1) * 4).any(|j| rm.visible(i, j))
                    })
                })
                .collect();
            assert_eq!(visited_key_tiles(i0, i1, 32, spec, 4), want, "tile [{i0},{i1})");
        }
    }

    #[test]
    fn sparse_patterns_match_oracle_through_the_tiled_kernel() {
        use super::super::MaskPattern;
        let (b, hq, hkv, s, d) = (1, 4, 2, 29, 8);
        let q = randn(&[b, hq, s, d], 61);
        let k = randn(&[b, hkv, s, d], 62);
        let v = randn(&[b, hkv, s, d], 63);
        for pat in [
            MaskPattern::Window { window: 5 },
            MaskPattern::Strided { stride: 3 },
            MaskPattern::Dilated { window: 2, stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 4 },
        ] {
            for causal in [false, true] {
                let mut spec = Spec::full(hq, hkv).with_pattern(pat);
                spec.causal = causal;
                let want = attention(&q, &k, &v, spec).unwrap();
                let got =
                    attention_tiled_cfg(&q, &k, &v, spec, TileConfig::new(8, 8).unwrap()).unwrap();
                assert!(
                    want.max_abs_diff(&got) < 1e-4,
                    "{pat:?} causal={causal}: diff {}",
                    want.max_abs_diff(&got)
                );
            }
        }
    }

    #[test]
    fn sparse_patterns_match_oracle_under_every_linalg_impl() {
        // The masked SIMD path (scratch row with -inf in invisible slots)
        // must agree with the oracle exactly like the scalar masking loop.
        use super::super::MaskPattern;
        let (b, hq, hkv, s, d) = (1, 2, 1, 37, 8);
        let q = randn(&[b, hq, s, d], 81);
        let k = randn(&[b, hkv, s, d], 82);
        let v = randn(&[b, hkv, s, d], 83);
        for pat in [
            MaskPattern::Window { window: 5 },
            MaskPattern::Strided { stride: 3 },
            MaskPattern::SinkLocal { sinks: 2, window: 4 },
        ] {
            let spec = Spec::causal(hq, hkv).with_pattern(pat);
            let want = attention(&q, &k, &v, spec).unwrap();
            for imp in [linalg::Impl::Scalar, linalg::Impl::Blocked, linalg::Impl::Simd] {
                let cfg = TileConfig::new(8, 8).unwrap().with_linalg(imp);
                let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
                assert!(
                    want.max_abs_diff(&got) < 1e-4,
                    "{pat:?} under {imp:?}: diff {}",
                    want.max_abs_diff(&got)
                );
            }
        }
    }

    #[test]
    fn masked_simd_rows_are_bitwise_deterministic() {
        use super::super::MaskPattern;
        let (b, hq, hkv, s, d) = (1, 2, 1, 45, 8);
        let q = randn(&[b, hq, s, d], 91);
        let k = randn(&[b, hkv, s, d], 92);
        let v = randn(&[b, hkv, s, d], 93);
        let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Dilated {
            window: 2,
            stride: 3,
        });
        let cfg = TileConfig::new(16, 8).unwrap().with_linalg(linalg::Impl::Simd);
        let a = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
        let b2 = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
        assert_eq!(a.data, b2.data);
    }

    #[test]
    fn masked_simd_handles_poison_scores_like_scalar() {
        // A +inf score in a *visible* pattern slot must send the row to the
        // scalar poison path (exact zeros); rows that only see the poison
        // key through pattern-invisible slots must stay healthy. Compare
        // the Simd lowering against Scalar: poisoned rows agree exactly,
        // healthy rows within the usual exp-approximation tolerance.
        use super::super::MaskPattern;
        let (hq, hkv, s, d) = (1, 1, 12, 4);
        let mut k = randn(&[1, hkv, s, d], 94);
        for dd in 0..d {
            k.set4(0, 0, 3, dd, f32::MAX); // q·k_3 = Σ MAX -> +inf
        }
        let q = Tensor::from_vec(&[1, hq, s, d], vec![1.0; s * d]).unwrap();
        let v = randn(&[1, hkv, s, d], 95);
        let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Strided { stride: 3 });
        let scalar_cfg = TileConfig::new(4, 4).unwrap().with_linalg(linalg::Impl::Scalar);
        let simd_cfg = TileConfig::new(4, 4).unwrap().with_linalg(linalg::Impl::Simd);
        let want = attention_tiled_cfg(&q, &k, &v, spec, scalar_cfg).unwrap();
        let got = attention_tiled_cfg(&q, &k, &v, spec, simd_cfg).unwrap();
        assert!(got.data.iter().all(|x| !x.is_nan()));
        assert!(want.max_abs_diff(&got) < 1e-5);
        // Strided:3 rows i >= 3 with i ≡ 0 (mod 3) see key 3: poisoned.
        for i in [3usize, 6, 9] {
            for dd in 0..d {
                assert_eq!(got.get4(0, 0, i, dd), 0.0, "row {i}");
                assert_eq!(want.get4(0, 0, i, dd), 0.0, "row {i}");
            }
        }
        // Row 4 never sees key 3 ((4-3) % 3 != 0): it must stay non-zero.
        assert!((0..d).any(|dd| got.get4(0, 0, 4, dd) != 0.0));
    }

    #[test]
    fn misaligned_bitmap_blocks_are_rejected_with_tile_sizes_in_the_error() {
        use super::super::{BlockBitmap, MaskPattern};
        let bid = pattern::register_bitmap(BlockBitmap::new(6, 2, 2, vec![true; 4]).unwrap());
        let (b, hq, hkv, s, d) = (1, 2, 2, 12, 4);
        let q = randn(&[b, hq, s, d], 71);
        let spec = Spec::causal(hq, hkv).with_pattern(MaskPattern::Bitmap(bid));
        let err = attention_tiled_cfg(&q, &q, &q, spec, TileConfig::new(4, 4).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("bitmap block 6 must be a multiple of the tile sizes 4x4"), "{err}");
        // Aligned tiles accept it and match the oracle.
        let got =
            attention_tiled_cfg(&q, &q, &q, spec, TileConfig::new(6, 6).unwrap()).unwrap();
        let want = attention(&q, &q, &q, spec).unwrap();
        assert!(want.max_abs_diff(&got) < 1e-4);
    }

    #[test]
    fn rejects_zero_tiles() {
        assert!(TileConfig::new(0, 8).is_err());
        assert!(TileConfig::new(8, 0).is_err());
        assert!(TileConfig::new(8, 8).is_ok());
    }
}
