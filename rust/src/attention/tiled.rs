//! Flash-style tiled streaming attention: online softmax, no S×S buffer.
//!
//! The naive oracle in [`super::attention`] materializes the full `[S, S]`
//! score matrix per head, so memory — not FLOPs — becomes the binding
//! constraint long before the 32k–200k regime the paper benchmarks. This
//! kernel streams over fixed-size key tiles instead, keeping one running
//! `(max, normalizer, output)` triple per query row:
//!
//! ```text
//!   m' = max(m, max_j s_ij)                    (running max)
//!   α  = exp(m - m')                           (rescale factor)
//!   l' = α·l + Σ_j exp(s_ij - m')              (running normalizer)
//!   o' = α·o + Σ_j exp(s_ij - m')·v_j          (unnormalized output)
//! ```
//!
//! and divides by `l` once at the end. Peak score storage is one
//! `[q_tile, k_tile]` block regardless of S. Key tiles that fall entirely
//! outside the union of the query tile's visible ranges (causal and/or
//! sliding-window masks) are skipped without touching K or V.
//!
//! Invariants the test suites pin down (see `rust/tests/`):
//! * outputs match the naive oracle within 1e-4 for every head geometry
//!   (MHA, GQA, MQA, extreme SQA) and every mask, including sequence
//!   lengths that are not multiples of the tile size;
//! * softmax rows sum to 1 (probed with all-ones values);
//! * rows whose visible range is empty produce exact zeros, never NaN;
//! * the running max keeps large-magnitude logits finite, and non-finite
//!   scores reproduce the oracle bit-for-bit: `-inf`/NaN keys are masked
//!   out individually, while a `+inf` score (which dominates the oracle's
//!   row max and underflows its normalizer) zeroes the whole row;
//! * the set of key tiles visited equals the set of key tiles that
//!   intersect some row's [`super::visible_range`].

use super::tensor::Tensor;
use super::{check_shapes, visible_range, Spec};
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::sync::{mpsc, Arc};

/// Default query/key tile edge. 64 rows × 64 keys of f32 scores is 16 KiB —
/// comfortably inside L1/L2 alongside the K/V tile being streamed.
pub const DEFAULT_TILE: usize = 64;

/// Tile geometry of the streaming kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Query rows processed per tile.
    pub q_tile: usize,
    /// Keys consumed per inner step (the score block is `q_tile × k_tile`).
    pub k_tile: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self {
            q_tile: DEFAULT_TILE,
            k_tile: DEFAULT_TILE,
        }
    }
}

impl TileConfig {
    pub fn new(q_tile: usize, k_tile: usize) -> Result<Self> {
        if q_tile == 0 || k_tile == 0 {
            bail!("tile sizes must be positive (got {q_tile}x{k_tile})");
        }
        Ok(Self { q_tile, k_tile })
    }
}

/// Union of the visible key ranges of query rows `[i0, i1)`.
///
/// Both `lo(i)` and `hi(i)` of [`visible_range`] are non-decreasing in `i`
/// for every mask kind (causal, symmetric window, causal window, full), and
/// consecutive rows' ranges always touch or overlap (windows are ≥ 1), so
/// the union is exactly the interval `[lo(i0), hi(i1 - 1))` and every key
/// in it is visible to at least one row of the tile.
pub fn tile_visible_range(i0: usize, i1: usize, s: usize, spec: Spec) -> (usize, usize) {
    debug_assert!(i0 < i1 && i1 <= s);
    let (lo, _) = visible_range(i0, s, spec);
    let (_, hi) = visible_range(i1 - 1, s, spec);
    (lo, hi)
}

/// Indices of the key tiles the kernel visits for query tile `[i0, i1)`.
///
/// A key tile `t` covers keys `[t·k_tile, (t+1)·k_tile) ∩ [0, s)`; the
/// kernel visits exactly the tiles intersecting [`tile_visible_range`].
/// `rust/tests/properties.rs` checks this against the per-row
/// [`visible_range`] definition.
pub fn visited_key_tiles(
    i0: usize,
    i1: usize,
    s: usize,
    spec: Spec,
    k_tile: usize,
) -> std::ops::Range<usize> {
    let (lo, hi) = tile_visible_range(i0, i1, s, spec);
    if hi <= lo {
        return 0..0;
    }
    lo / k_tile..hi.div_ceil(k_tile)
}

/// Stream one query tile `[i0, i1)` of one head.
///
/// `q`/`k`/`v` are full-sequence slabs addressed as
/// `row j -> slab[j * stride + off ..][..d]`, which covers both the oracle's
/// `[S, d]` per-head layout (`stride = d`, `off = 0`) and the native
/// backend's head-interleaved `[S, H·d]` matrices (`stride = H·d`,
/// `off = h·d`). `out` starts at query row `i0`: row `i` lands at
/// `out[(i - i0) * out_stride + out_off ..][..d]` and is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_qtile(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    i0: usize,
    i1: usize,
    spec: Spec,
    k_tile: usize,
    scale: f32,
) {
    let tq = i1 - i0;
    for ti in 0..tq {
        out[ti * out_stride + out_off..][..d].fill(0.0);
    }
    let (t_lo, t_hi) = tile_visible_range(i0, i1, s, spec);
    if t_hi <= t_lo {
        return; // whole tile masked: zeros, by construction not NaN
    }
    // Running per-row state; `out` itself holds the unnormalized output.
    let mut m = vec![f32::NEG_INFINITY; tq];
    let mut l = vec![0.0f32; tq];
    // Oracle semantics for non-finite scores: -inf/NaN entries are masked
    // out individually, but a +inf score dominates the row max and drives
    // every exp (and the normalizer) to 0 — the whole row becomes zeros.
    let mut poisoned = vec![false; tq];
    // The only score storage: one [q_tile, k_tile] block.
    let mut scores = vec![0.0f32; tq * k_tile];

    for jt in t_lo / k_tile..t_hi.div_ceil(k_tile) {
        let j0 = jt * k_tile;
        let j1 = ((jt + 1) * k_tile).min(s);
        for ti in 0..tq {
            let i = i0 + ti;
            let (lo, hi) = visible_range(i, s, spec);
            let (jlo, jhi) = (j0.max(lo), j1.min(hi));
            if jlo >= jhi {
                continue; // this row sees nothing in this key tile
            }
            let qi = &q[i * q_stride + q_off..][..d];
            let srow = &mut scores[ti * k_tile..][..k_tile];
            let mut block_max = f32::NEG_INFINITY;
            for j in jlo..jhi {
                let kj = &k[j * kv_stride + kv_off..][..d];
                let mut acc = 0.0f32;
                for (a, b) in qi.iter().zip(kj) {
                    acc += a * b;
                }
                let sc = acc * scale;
                if sc.is_finite() {
                    srow[j - j0] = sc;
                    block_max = block_max.max(sc);
                } else {
                    // -inf/NaN: this key contributes nothing; +inf: the
                    // whole row degrades to zeros like the oracle's.
                    poisoned[ti] |= sc == f32::INFINITY;
                    srow[j - j0] = f32::NEG_INFINITY;
                }
            }
            if block_max == f32::NEG_INFINITY {
                // No finite score in this block: nothing to accumulate.
                continue;
            }
            let m_new = m[ti].max(block_max);
            let orow = &mut out[ti * out_stride + out_off..][..d];
            // α = exp(m_old - m_new); exp(-inf) = 0 covers the first block.
            let alpha = (m[ti] - m_new).exp();
            if alpha != 1.0 {
                l[ti] *= alpha;
                for o in orow.iter_mut() {
                    *o *= alpha;
                }
            }
            m[ti] = m_new;
            for j in jlo..jhi {
                let p = (srow[j - j0] - m_new).exp();
                if p == 0.0 {
                    continue;
                }
                l[ti] += p;
                let vj = &v[j * kv_stride + kv_off..][..d];
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += p * vv;
                }
            }
        }
    }
    for ti in 0..tq {
        // l == 0 means no key survived (all masked or all -inf) and a +inf
        // score zeroes the whole row: in both cases emit exact zeros (what
        // the oracle computes) rather than dividing into NaN.
        let orow = &mut out[ti * out_stride + out_off..][..d];
        if l[ti] > 0.0 && !poisoned[ti] {
            let inv = 1.0 / l[ti];
            for o in orow.iter_mut() {
                *o *= inv;
            }
        } else {
            orow.fill(0.0);
        }
    }
}

/// Drive every query tile of one head through [`stream_qtile`].
///
/// `out` is the full `[S, ·]` output slab (row 0 based) addressed with the
/// same stride/offset convention as the inputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_head(
    q: &[f32],
    q_stride: usize,
    q_off: usize,
    k: &[f32],
    kv_stride: usize,
    kv_off: usize,
    v: &[f32],
    out: &mut [f32],
    out_stride: usize,
    out_off: usize,
    s: usize,
    d: usize,
    spec: Spec,
    cfg: TileConfig,
    scale: f32,
) {
    let mut i0 = 0;
    while i0 < s {
        let i1 = (i0 + cfg.q_tile).min(s);
        stream_qtile(
            q,
            q_stride,
            q_off,
            k,
            kv_stride,
            kv_off,
            v,
            &mut out[i0 * out_stride..],
            out_stride,
            out_off,
            s,
            d,
            i0,
            i1,
            spec,
            cfg.k_tile,
            scale,
        );
        i0 = i1;
    }
}

/// Tiled streaming attention with the default tile geometry.
///
/// Same contract as [`super::attention`]: q `[B, Hq, S, d]`,
/// k/v `[B, Hkv, S, d]` → `[B, Hq, S, d]`.
pub fn attention_tiled(q: &Tensor, k: &Tensor, v: &Tensor, spec: Spec) -> Result<Tensor> {
    attention_tiled_cfg(q, k, v, spec, TileConfig::default())
}

/// Tiled streaming attention with explicit tile geometry (tests use tiny
/// tiles to exercise non-aligned sequence lengths cheaply).
pub fn attention_tiled_cfg(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
    cfg: TileConfig,
) -> Result<Tensor> {
    let (b, hq, s, d) = check_shapes(q, k, v, spec)?;
    let group = hq / spec.hkv;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[b, hq, s, d]);
    for ib in 0..b {
        for h in 0..hq {
            let hk = h / group;
            let q_slab = &q.data[q.idx4(ib, h, 0, 0)..][..s * d];
            let k_slab = &k.data[k.idx4(ib, hk, 0, 0)..][..s * d];
            let v_slab = &v.data[v.idx4(ib, hk, 0, 0)..][..s * d];
            let o_base = (ib * hq + h) * s * d;
            let o_slab = &mut out.data[o_base..o_base + s * d];
            stream_head(
                q_slab,
                d,
                0,
                k_slab,
                d,
                0,
                v_slab,
                o_slab,
                d,
                0,
                s,
                d,
                spec,
                cfg,
                scale,
            );
        }
    }
    Ok(out)
}

/// Tiled attention fanned out across `(batch, head, query-tile)` jobs on a
/// [`ThreadPool`]. Each job streams one query tile into a private buffer;
/// the caller thread assembles them, so no unsafe sharing is needed. Falls
/// back to the serial kernel when there is only one job's worth of work.
///
/// Borrowing wrapper around [`attention_tiled_parallel_owned`]; it must
/// deep-copy Q/K/V to hand `'static` buffers to the pool, so callers that
/// own their projections (e.g. `sqa_layer_with`) should pass them by value
/// instead.
pub fn attention_tiled_parallel(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    spec: Spec,
    cfg: TileConfig,
    pool: &ThreadPool,
) -> Result<Tensor> {
    attention_tiled_parallel_owned(q.clone(), k.clone(), v.clone(), spec, cfg, pool)
}

/// [`attention_tiled_parallel`] taking ownership of Q/K/V — the buffers
/// move straight into the job-shared `Arc`s with no copy.
///
/// Do not call from inside a job already running on `pool` — nested
/// submission can deadlock the bounded queue.
pub fn attention_tiled_parallel_owned(
    q: Tensor,
    k: Tensor,
    v: Tensor,
    spec: Spec,
    cfg: TileConfig,
    pool: &ThreadPool,
) -> Result<Tensor> {
    let (b, hq, s, d) = check_shapes(&q, &k, &v, spec)?;
    let n_tiles = s.div_ceil(cfg.q_tile);
    if b * hq * n_tiles <= 1 {
        return attention_tiled_cfg(&q, &k, &v, spec, cfg);
    }
    let group = hq / spec.hkv;
    let hkv = spec.hkv;
    let scale = 1.0 / (d as f32).sqrt();
    let qa = Arc::new(q.data);
    let ka = Arc::new(k.data);
    let va = Arc::new(v.data);
    let (tx, rx) = mpsc::channel::<(usize, usize, usize, Vec<f32>)>();
    let mut n_jobs = 0usize;
    for ib in 0..b {
        for h in 0..hq {
            let hk = h / group;
            for t in 0..n_tiles {
                let i0 = t * cfg.q_tile;
                let i1 = (i0 + cfg.q_tile).min(s);
                let (qa, ka, va) = (Arc::clone(&qa), Arc::clone(&ka), Arc::clone(&va));
                let tx = tx.clone();
                n_jobs += 1;
                pool.submit(move || {
                    let q_slab = &qa[(ib * hq + h) * s * d..][..s * d];
                    let k_slab = &ka[(ib * hkv + hk) * s * d..][..s * d];
                    let v_slab = &va[(ib * hkv + hk) * s * d..][..s * d];
                    let mut buf = vec![0.0f32; (i1 - i0) * d];
                    stream_qtile(
                        q_slab,
                        d,
                        0,
                        k_slab,
                        d,
                        0,
                        v_slab,
                        &mut buf,
                        d,
                        0,
                        s,
                        d,
                        i0,
                        i1,
                        spec,
                        cfg.k_tile,
                        scale,
                    );
                    let _ = tx.send((ib, h, i0, buf));
                });
            }
        }
    }
    drop(tx);
    let mut out = Tensor::zeros(&[b, hq, s, d]);
    for _ in 0..n_jobs {
        let (ib, h, i0, buf) = rx.recv().context("tiled attention worker lost")?;
        let base = out.idx4(ib, h, i0, 0);
        out.data[base..base + buf.len()].copy_from_slice(&buf);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention;
    use crate::util::rng::Pcg64;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).unwrap()
    }

    #[test]
    fn matches_oracle_on_default_tiles() {
        let (b, hq, hkv, s, d) = (2, 4, 2, 97, 8);
        let q = randn(&[b, hq, s, d], 1);
        let k = randn(&[b, hkv, s, d], 2);
        let v = randn(&[b, hkv, s, d], 3);
        for spec in [
            Spec::full(hq, hkv),
            Spec::causal(hq, hkv),
            Spec {
                hq,
                hkv,
                causal: true,
                window: Some(13),
            },
        ] {
            let want = attention(&q, &k, &v, spec).unwrap();
            let got = attention_tiled(&q, &k, &v, spec).unwrap();
            assert!(
                want.max_abs_diff(&got) < 1e-4,
                "{spec:?}: diff {}",
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4, 64);
        let (b, hq, hkv, s, d) = (2, 4, 1, 83, 8);
        let q = randn(&[b, hq, s, d], 4);
        let k = randn(&[b, hkv, s, d], 5);
        let v = randn(&[b, hkv, s, d], 6);
        let spec = Spec::causal(hq, hkv);
        let cfg = TileConfig::new(16, 16).unwrap();
        let serial = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
        let par = attention_tiled_parallel(&q, &k, &v, spec, cfg, &pool).unwrap();
        // Same per-tile arithmetic, so bitwise equality is expected.
        assert_eq!(serial.data, par.data);
    }

    #[test]
    fn rows_with_no_surviving_keys_write_zeros_not_nan() {
        // The public masks never produce an empty visible range, but the
        // kernel must stay total: when a row's normalizer ends at 0 (every
        // score overflowed to -inf, the streaming analogue of an all-masked
        // row) the output must be exact zeros, never 0/0 = NaN.
        let s = 8;
        let d = 4;
        let spec = Spec::causal(1, 1);
        // q·k overflows to -inf for every pair: every block is skipped and
        // the normalizer stays 0.
        let q = vec![f32::MAX; s * d];
        let k = vec![f32::MIN; s * d];
        let v: Vec<f32> = (0..s * d).map(|x| x as f32).collect();
        let mut out = vec![f32::NAN; s * d]; // must be fully overwritten
        stream_qtile(
            &q,
            d,
            0,
            &k,
            d,
            0,
            &v,
            &mut out,
            d,
            0,
            s,
            d,
            0,
            s,
            spec,
            4,
            1.0,
        );
        assert!(out.iter().all(|&x| x == 0.0), "{out:?}");
    }

    #[test]
    fn stale_scores_from_previous_block_are_not_reused() {
        // Row windows narrower than k_tile leave parts of the score block
        // unwritten on later tiles; those slots must never leak into p.
        let (hq, hkv, s, d) = (1, 1, 11, 4);
        let q = randn(&[1, hq, s, d], 7);
        let k = randn(&[1, hkv, s, d], 8);
        let v = randn(&[1, hkv, s, d], 9);
        let spec = Spec {
            hq,
            hkv,
            causal: true,
            window: Some(2),
        };
        let want = attention(&q, &k, &v, spec).unwrap();
        let got = attention_tiled_cfg(&q, &k, &v, spec, TileConfig::new(4, 4).unwrap()).unwrap();
        assert!(want.max_abs_diff(&got) < 1e-5);
    }

    #[test]
    fn large_magnitude_logits_stay_finite() {
        // Scores ~ ±2500: naive and tiled both max-subtract, so outputs
        // agree and stay finite (softmax saturates onto the argmax key).
        let (hq, hkv, s, d) = (2, 1, 33, 4);
        let mut q = randn(&[1, hq, s, d], 10);
        let mut k = randn(&[1, hkv, s, d], 11);
        for x in q.data.iter_mut() {
            *x *= 50.0;
        }
        for x in k.data.iter_mut() {
            *x *= 50.0;
        }
        let v = randn(&[1, hkv, s, d], 12);
        let cfg = TileConfig::new(8, 8).unwrap();
        for spec in [Spec::causal(hq, hkv), Spec::full(hq, hkv)] {
            let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
            assert!(got.data.iter().all(|x| x.is_finite()));
            let want = attention(&q, &k, &v, spec).unwrap();
            assert!(want.max_abs_diff(&got) < 1e-4);
        }
    }

    #[test]
    fn overflowing_rows_degrade_to_zeros_like_the_oracle() {
        let (hq, hkv, s, d) = (1, 1, 9, 4);
        let v = randn(&[1, hkv, s, d], 13);
        let spec = Spec::causal(hq, hkv);
        let cfg = TileConfig::new(4, 4).unwrap();
        // -inf overflow (q·k = MAX·MIN) and +inf overflow (q·k = MAX·MAX):
        // the oracle zeroes both kinds of row; tiled must agree, not NaN.
        for kval in [f32::MIN, f32::MAX] {
            let q = Tensor::from_vec(&[1, hq, s, d], vec![f32::MAX; s * d]).unwrap();
            let k = Tensor::from_vec(&[1, hkv, s, d], vec![kval; s * d]).unwrap();
            let want = attention(&q, &k, &v, spec).unwrap();
            let got = attention_tiled_cfg(&q, &k, &v, spec, cfg).unwrap();
            assert!(want.data.iter().all(|&x| x == 0.0), "oracle kval={kval}");
            assert_eq!(want.data, got.data, "kval={kval}");
        }
    }

    #[test]
    fn single_plus_inf_score_zeroes_only_that_row() {
        // Key 1 sends row scores to +inf for every query row that sees it
        // (oracle: +inf dominates the row max, denom underflows to 0 ->
        // zeros); rows that never see key 1 must stay untouched and match.
        let (hq, hkv, s, d) = (1, 1, 6, 4);
        let q = Tensor::from_vec(&[1, hq, s, d], vec![1.0; s * d]).unwrap();
        let mut k = randn(&[1, hkv, s, d], 14);
        for dd in 0..d {
            k.set4(0, 0, 1, dd, f32::MAX);
        }
        let spec = Spec::causal(hq, hkv);
        let want = attention(&q, &k, &v_of(&k, 15), spec).unwrap();
        let got =
            attention_tiled_cfg(&q, &k, &v_of(&k, 15), spec, TileConfig::new(4, 4).unwrap())
                .unwrap();
        assert!(got.data.iter().all(|x| !x.is_nan()));
        // Row 0 (sees only key 0) is a plain softmax; rows >= 1 see the
        // poisoned key and must be zeros in both implementations.
        assert!(want.max_abs_diff(&got) < 1e-5);
        for i in 1..s {
            for dd in 0..d {
                assert_eq!(got.get4(0, 0, i, dd), 0.0, "row {i}");
            }
        }
    }

    #[test]
    fn nan_scores_are_masked_like_the_oracle() {
        // A NaN q row makes all its scores NaN: the oracle masks them
        // (weight 0, denom 0 -> zeros); the tiled kernel must agree and
        // must not leak the NaN into neighbouring rows of the tile.
        let (hq, hkv, s, d) = (1, 1, 7, 4);
        let mut q = randn(&[1, hq, s, d], 16);
        for dd in 0..d {
            q.set4(0, 0, 3, dd, f32::NAN);
        }
        let k = randn(&[1, hkv, s, d], 17);
        let v = randn(&[1, hkv, s, d], 18);
        let spec = Spec::causal(hq, hkv);
        let want = attention(&q, &k, &v, spec).unwrap();
        let got = attention_tiled_cfg(&q, &k, &v, spec, TileConfig::new(4, 4).unwrap()).unwrap();
        assert!(got.data.iter().all(|x| !x.is_nan()));
        assert!(want.max_abs_diff(&got) < 1e-5);
        for dd in 0..d {
            assert_eq!(got.get4(0, 0, 3, dd), 0.0);
        }
    }

    fn v_of(k: &Tensor, seed: u64) -> Tensor {
        randn(&k.shape, seed)
    }

    #[test]
    fn tile_range_helpers_agree_with_visible_range() {
        let spec = Spec {
            hq: 1,
            hkv: 1,
            causal: true,
            window: Some(3),
        };
        let s = 32;
        assert_eq!(tile_visible_range(4, 8, s, spec), (2, 8));
        assert_eq!(visited_key_tiles(4, 8, s, spec, 4), 0..2);
        // Causal full: tile [8, 16) sees keys [0, 16).
        let causal = Spec::causal(1, 1);
        assert_eq!(tile_visible_range(8, 16, s, causal), (0, 16));
        assert_eq!(visited_key_tiles(8, 16, s, causal, 8), 0..2);
    }

    #[test]
    fn rejects_zero_tiles() {
        assert!(TileConfig::new(0, 8).is_err());
        assert!(TileConfig::new(8, 0).is_err());
        assert!(TileConfig::new(8, 8).is_ok());
    }
}
