//! `sqa` — CLI launcher for the SQA reproduction.
//!
//! Subcommands:
//!   train       train a (family, variant) through the active backend
//!   serve       start the serving engine (TCP, JSON lines): batched
//!               encode + stateful generate with per-session KV caches
//!   encode      one-shot client call against a running server
//!   generate    autoregressive generation against a running server
//!               (prefill + incremental decode, top-k sampling)
//!   bench       regenerate paper tables: table1 | table2 | table3 |
//!               complexity | ablation | kernels | all
//!   flops       analytic FLOPs/KV-cache model for a (family, variant, seq)
//!   diagram     ASCII head-wiring diagram (paper figures 2-6)
//!   inspect     list the backend's model catalog and parameter layouts
//!
//! The backend is native (pure Rust) by default; builds with
//! `--features pjrt` pick up `artifacts/manifest.json` automatically.
//! `SQA_BACKEND=native|pjrt` forces a choice.

use anyhow::{bail, Context, Result};
use sqa::bench_harness;
use sqa::config::{ServeConfig, TrainConfig};
use sqa::coordinator::{Engine, GenParams};
use sqa::flops;
use sqa::runtime::{open_backend, Backend};
use sqa::server::{Client, Server};
use sqa::train::Trainer;
use sqa::util::cli::Args;
use std::sync::Arc;

fn main() {
    sqa::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &mut Args) -> String {
    args.str("artifacts", "artifacts")
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "encode" => cmd_encode(args),
        "generate" => cmd_generate(args),
        "bench" => cmd_bench(args),
        "flops" => cmd_flops(args),
        "diagram" => cmd_diagram(args),
        "inspect" => cmd_inspect(args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `sqa help`"),
    }
}

const HELP: &str = "\
sqa — Sparse Query Attention reproduction (native Rust backend; optional PJRT)

USAGE: sqa <command> [--flags]

COMMANDS
  train     --family tiny --variant sqa --steps 200 --lr 1e-2 --seed 42
            [--kernel tiled|naive|tiled+scalar|naive+scalar]
            [--pattern dense|window:W|strided:T|dilated:W:T|sink:S:W|bitmap:N]
            [--checkpoint-dir DIR --checkpoint-every N --report OUT.json]
  serve     --family tiny --variant sqa --addr 127.0.0.1:7433
            [--max-batch 8 --max-wait-ms 5 --workers 2 --kernel tiled|naive]
            [--pattern dense|window:W|strided:T|dilated:W:T|sink:S:W|bitmap:N]
            [--kv-dtype f32|f16|bf16]
            [--kv-block-len 0 --kv-pool-blocks 4096 --spill-dir DIR]
            [--max-sessions 4 --session-timeout-ms 30000 --gen-capacity 0
             --conn-threads 8 --conn-idle-ms 30000 --stream-buffer 32
             --prefill-chunk 0]
  encode    --addr 127.0.0.1:7433 (--text \"...\" | --tokens 1,2,3 | --metrics)
  generate  --addr 127.0.0.1:7433 (--text \"...\" | --tokens 1,2,3)
            [--max-tokens 32 --top-k 5 --temperature 1.0 --seed 0 --stream]
  bench     table1|table2|table3|complexity|ablation|kernels|all
            [--steps N --max-seq S --quick --out FILE.md]
  flops     --family bench --variant sqa --seq 8192 [--batch 1 --decode]
  diagram   --variant sqa --h-total 16   (or --hq 8 --hkv 4)
  inspect   [--family F]

Backend: native by default; SQA_BACKEND=pjrt (with --features pjrt builds
and an artifacts/ dir from `make artifacts`) selects the XLA path.
Kernel:  the native backend runs the tiled streaming attention kernel on
blocked GEMMs by default; SQA_KERNEL=naive selects the S×S oracle,
SQA_LINALG=scalar the element-at-a-time GEMM oracle, and SQA_LINALG=simd
the vectorized micro-kernel + online-softmax tier (AVX2+FMA on x86-64,
NEON on aarch64; hosts without the features silently fall back to the
blocked portable path at runtime). `serve --kernel` and `train --kernel`
accept the combined forms (tiled, naive, tiled+scalar, naive+scalar,
tiled+simd, naive+simd); for training the switch selects the attention
*backward* too — flash-style streaming (LSE reuse, blocked micro-GEMMs)
for tiled, the scalar row-loop oracle for naive. `bench kernels` sweeps
naive vs tiled; `cargo bench --bench train_throughput` records the
fwd/bwd split step times (BENCH_train.json).
Pattern: `serve --pattern` and `train --pattern` compose a block-sparse
mask into the lowering (`kernel[+linalg][@pattern]` — a pattern without
--kernel rides on tiled): window:W is a local band |i-j|<W, strided:T keeps
|i-j|%T==0, dilated:W:T is W taps spaced T apart, sink:S:W adds S global
attention-sink keys to a local band, bitmap:N references a registered block
bitmap (JSON configs can inline one as {block,q_blocks,k_blocks,bits}).
Patterns AND with the causal/window mask; the tiled kernels skip whole
invisible key tiles, so sparse patterns drop visited-tile counts
sub-quadratically (see `cargo bench --bench native_attention`).
Generate: prompts prefill once (compute-bound, where SQA wins) into a
per-session KV cache sized by the variant's Hkv, then decode token-by-token
(memory-bound, where the cache size rules); concurrent generations batch
their decode steps per scheduler wake. `generate --stream` requests one
JSON frame per sampled token (the terminal frame carries the full summary
incl. ttft_ms); `serve --stream-buffer N` sizes the per-session flow-control
window (a reader more than N tokens behind pauses only its own session),
`serve --prefill-chunk N` splits long prompts into N-token chunks
interleaved with other sessions' decode steps (0 = whole-prompt prefill,
bit-exact with the unchunked path), and `serve --conn-idle-ms` closes
connections that fail to deliver a complete request line in time
(slow-loris guard). `cargo bench --bench latency_under_load` records
TTFT/inter-token percentiles across the zoo (BENCH_latency.json). `serve --kv-dtype f16|bf16` (or
SQA_KV_DTYPE) stores that cache at half width — rows are narrowed on
write and widened back to f32 on read, halving each session's resident
bytes and per-step cache traffic while the kernels still compute in f32. Generation inherits the *server's*
--pattern (sessions keep the mask from prefill through every decode step);
there is no per-request pattern switch. `cargo bench --bench
decode_throughput` sweeps measured tokens/s and bytes/step across the zoo.
Paged KV: `serve --kv-block-len N` (or SQA_KV_BLOCK_LEN; 0 = off) swaps the
contiguous per-session slabs for a shared block pool of `--kv-pool-blocks`
fixed-size blocks: sessions map logical positions to blocks through a block
table, identical prompt prefixes share refcounted blocks copy-on-write (a
prefix-trie hit skips prefill compute for the shared span), and under pool
pressure idle sessions' blocks spill to files under `--spill-dir` and
restore transparently on their next decode step. `/metrics` gains a
`kv_pool` object (occupancy, alloc/free/COW/evict/restore counters,
prefix-hit rate); `cargo bench --bench decode_throughput -- --kv-paged`
adds the paged axis plus a 64-session shared-prefix sessions/GB probe.
";

fn cmd_train(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args);
    let mut cfg = TrainConfig {
        family: args.str("family", "tiny"),
        variant: args.str("variant", "sqa"),
        steps: args.usize("steps", 200)?,
        eval_every: args.usize("eval-every", 50)?,
        eval_batches: args.usize("eval-batches", 4)?,
        seed: args.usize("seed", 42)? as u64,
        checkpoint_every: args.usize("checkpoint-every", 0)?,
        log_every: args.usize("log-every", 10)?,
        kernel: args.str_opt("kernel"),
        pattern: args.str_opt("pattern"),
        ..TrainConfig::default()
    };
    cfg.schedule.base_lr = args.f64("lr", 1e-2)?;
    cfg.schedule.total_steps = cfg.steps;
    cfg.schedule.warmup_steps = args.usize("warmup", cfg.steps / 10)?;
    if let Some(d) = args.str_opt("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d);
    }
    let report_path = args.str_opt("report");
    if let Some(cfg_path) = args.str_opt("config") {
        cfg = TrainConfig::load(&cfg_path)?;
    }
    args.finish()?;

    let backend = open_backend(&dir)?;
    let mut trainer = Trainer::new(&backend, cfg)?;
    let report = trainer.run()?;
    println!(
        "{}/{}: {} steps in {:.1}s | val_loss {:.4} ppl {:.3} acc {:.2}%",
        report.family,
        report.variant,
        report.steps,
        report.train_secs,
        report.val_loss,
        report.val_ppl,
        report.val_acc * 100.0
    );
    if let Some(p) = report_path {
        std::fs::write(&p, report.to_json().to_string())?;
        println!("report -> {p}");
    }
    Ok(())
}

fn cmd_serve(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args);
    let cfg = ServeConfig {
        family: args.str("family", "tiny"),
        variant: args.str("variant", "sqa"),
        addr: args.str("addr", "127.0.0.1:7433"),
        max_batch: args.usize("max-batch", 8)?,
        max_wait_ms: args.usize("max-wait-ms", 5)? as u64,
        workers: args.usize("workers", 2)?,
        queue_capacity: args.usize("queue", 64)?,
        kernel: args.str_opt("kernel"),
        pattern: args.str_opt("pattern"),
        kv_dtype: args.str_opt("kv-dtype"),
        kv_block_len: args.usize("kv-block-len", 0)?,
        kv_pool_blocks: args.usize("kv-pool-blocks", 4096)?,
        spill_dir: args.str_opt("spill-dir"),
        max_sessions: args.usize("max-sessions", 4)?,
        session_timeout_ms: args.usize("session-timeout-ms", 30_000)? as u64,
        gen_capacity: args.usize("gen-capacity", 0)?,
        conn_threads: args.usize("conn-threads", 8)?,
        conn_idle_ms: args.usize("conn-idle-ms", 30_000)? as u64,
        stream_buffer: args.usize("stream-buffer", 32)?,
        prefill_chunk: args.usize("prefill-chunk", 0)?,
    };
    let ckpt = args.str_opt("checkpoint");
    args.finish()?;

    // The backend reads SQA_KV_DTYPE when it opens, so the flag must land
    // in the environment first (validated here so a typo fails fast with
    // the flag's name instead of a panic inside the backend).
    if let Some(dt) = &cfg.kv_dtype {
        sqa::runtime::KvDtype::parse(dt).context("--kv-dtype")?;
        std::env::set_var("SQA_KV_DTYPE", dt);
    }
    // Same seam for the paged allocator: the native backend reads the
    // SQA_KV_* env at open time (see `PagedConfig::from_env`), so the
    // flags must be exported before `open_backend`.
    if cfg.kv_block_len > 0 {
        std::env::set_var("SQA_KV_BLOCK_LEN", cfg.kv_block_len.to_string());
        std::env::set_var("SQA_KV_POOL_BLOCKS", cfg.kv_pool_blocks.to_string());
        if let Some(d) = &cfg.spill_dir {
            std::env::set_var("SQA_KV_SPILL_DIR", d);
        }
    }
    let backend = open_backend(&dir)?;
    let params = match ckpt {
        Some(p) => {
            let (params, step) = sqa::runtime::checkpoint::load(
                backend.as_ref(),
                &cfg.family,
                &cfg.variant,
                std::path::Path::new(&p),
            )?;
            log::info!("loaded checkpoint {p} (step {step})");
            Some(params)
        }
        None => None,
    };
    let engine = Engine::start(&backend, &cfg, params)?;
    println!(
        "serving {}/{} ({} backend) buckets={:?} gen_capacity={} on {}",
        cfg.family,
        cfg.variant,
        backend.name(),
        engine.buckets(),
        engine.gen_capacity,
        cfg.addr
    );
    Server::bind_with(&cfg.addr, engine, cfg.conn_threads)?
        .with_idle_deadline(std::time::Duration::from_millis(cfg.conn_idle_ms))
        .serve()
}

fn cmd_generate(mut args: Args) -> Result<()> {
    let addr = args.str("addr", "127.0.0.1:7433");
    let text = args.str_opt("text");
    let tokens = args.str_opt("tokens");
    let params = GenParams {
        max_tokens: args.usize("max-tokens", 32)?,
        top_k: args.usize("top-k", 5)?.max(1),
        temperature: args.f64("temperature", 1.0)? as f32,
        seed: args.usize("seed", 0)? as u64,
    };
    let stream = args.bool("stream");
    args.finish()?;
    let mut client = Client::connect(&addr)?;
    let toks: Option<Vec<u32>> = match &tokens {
        Some(t) => Some(
            t.split(',')
                .map(|s| s.trim().parse().context("parsing --tokens"))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    if stream {
        // Streamed path: print each token's piece as it arrives, then the
        // terminal frame's summary line.
        let frames = if let Some(t) = &text {
            client.generate_stream_text(t, &params)?
        } else if let Some(toks) = &toks {
            client.generate_stream(toks, &params)?
        } else {
            bail!("need --text or --tokens");
        };
        let mut last = None;
        for frame in frames {
            let frame = frame?;
            if frame.get("done").and_then(|d| d.as_bool()) == Some(true)
                || frame.get("ok").and_then(|o| o.as_bool()) == Some(false)
            {
                last = Some(frame);
                break;
            }
            if let Some(piece) = frame.get("piece").and_then(|p| p.as_str()) {
                print!("{piece} ");
                use std::io::Write;
                let _ = std::io::stdout().flush();
            }
        }
        println!();
        if let Some(f) = last {
            println!("{f}");
        }
        return Ok(());
    }
    let resp = if let Some(t) = text {
        client.generate_text(&t, &params)?
    } else if let Some(toks) = toks {
        client.generate_tokens(&toks, &params)?
    } else {
        bail!("need --text or --tokens");
    };
    println!("{resp}");
    if resp.get("ok").and_then(|o| o.as_bool()) == Some(true) {
        if let Some(t) = resp.get("text").and_then(|t| t.as_str()) {
            println!("generated: {t}");
        }
    }
    Ok(())
}

fn cmd_encode(mut args: Args) -> Result<()> {
    let addr = args.str("addr", "127.0.0.1:7433");
    let text = args.str_opt("text");
    let tokens = args.str_opt("tokens");
    let metrics = args.bool("metrics");
    args.finish()?;
    let mut client = Client::connect(&addr)?;
    let resp = if metrics {
        client.metrics()?
    } else if let Some(t) = text {
        client.encode_text(&t)?
    } else if let Some(t) = tokens {
        let toks: Vec<u32> = t
            .split(',')
            .map(|s| s.trim().parse().context("parsing --tokens"))
            .collect::<Result<_>>()?;
        client.encode_tokens(&toks)?
    } else {
        bail!("need --text, --tokens or --metrics");
    };
    println!("{resp}");
    Ok(())
}

fn cmd_bench(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args);
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".into());
    let steps = args.usize("steps", 60)?;
    let max_seq = args.usize("max-seq", 0)?;
    let quick = args.bool("quick");
    let seed = args.usize("seed", 42)? as u64;
    let out = args.str_opt("out");
    args.finish()?;
    let backend = open_backend(&dir)?;
    let mut output = String::new();

    let run_one = |name: &str, backend: &Arc<dyn Backend>, output: &mut String| -> Result<()> {
        match name {
            "table1" => {
                let (md, _) = bench_harness::table1(backend, steps, seed)?;
                output.push_str(&format!("\n## Table 1 — dense quality ({steps} steps)\n\n{md}"));
            }
            "table2" => {
                let (md, _) = bench_harness::table2(backend, steps, seed)?;
                output.push_str(&format!("\n## Table 2 — MoE quality ({steps} steps)\n\n{md}"));
            }
            "table3" => {
                let (md, cells) =
                    bench_harness::table3(backend, bench_harness::TABLE3_VARIANTS, max_seq, quick)?;
                output.push_str(&format!("\n## Table 3 — fwd time per step (s)\n\n{md}"));
                std::fs::write(
                    "bench_table3.json",
                    bench_harness::cells_to_json(&cells).to_string(),
                )?;
            }
            "complexity" => {
                let md = bench_harness::complexity(backend, "dense_sm", 32768)
                    .or_else(|_| bench_harness::complexity(backend, "tiny", 32768))?;
                output.push_str(&format!("\n## Complexity (§3.2.1, N=32768)\n\n{md}"));
            }
            "ablation" => {
                let md = bench_harness::ablation_impl(backend, 1024)?;
                output.push_str(&format!("\n## Ablation — attention lowerings\n\n{md}"));
            }
            "kernels" => {
                let seqs: Vec<usize> = [512usize, 1024, 2048, 4096]
                    .into_iter()
                    .filter(|&s| max_seq == 0 || s <= max_seq)
                    .collect();
                let (md, cells) = bench_harness::kernel_table(&seqs, 8, 4, 32, true, quick)?;
                output.push_str(&format!("\n## Kernels — naive vs tiled attention\n\n{md}"));
                std::fs::write(
                    "bench_kernels.json",
                    bench_harness::kernel_cells_to_json(&cells).to_string(),
                )?;
            }
            other => bail!("unknown bench {other:?}"),
        }
        Ok(())
    };

    if which == "all" {
        for name in ["complexity", "kernels", "table3", "ablation", "table2", "table1"] {
            run_one(name, &backend, &mut output)?;
        }
    } else {
        run_one(&which, &backend, &mut output)?;
    }
    println!("{output}");
    if let Some(p) = out {
        std::fs::write(&p, &output)?;
        println!("written -> {p}");
    }
    Ok(())
}

fn cmd_flops(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args);
    let family = args.str("family", "bench");
    let variant = args.str("variant", "sqa");
    let seq = args.usize("seq", 8192)? as u64;
    let batch = args.usize("batch", 1)? as u64;
    let decode = args.bool("decode");
    args.finish()?;
    let backend = open_backend(&dir)?;
    if decode {
        // §5 decode-phase roofline across the family's variant zoo.
        let fam = backend.family(&family)?;
        let variants: Vec<(String, sqa::config::VariantCfg)> = fam
            .variants
            .iter()
            .map(|(n, v)| (n.clone(), v.cfg))
            .collect();
        let rows = flops::decode::decode_table(
            &fam.dims,
            &variants,
            seq,
            flops::decode::Hardware::default(),
        );
        println!("decode roofline (A100-like envelope), {family} @ ctx {seq}:");
        println!(
            "{:8} {:>3} {:>4} {:>10} {:>12} {:>8}",
            "variant", "Hq", "Hkv", "KV MiB", "tok/s", "vs first"
        );
        for r in rows {
            println!(
                "{:8} {:>3} {:>4} {:>10.1} {:>12.1} {:>7.2}x",
                r.variant, r.hq, r.hkv, r.kv_mib, r.tok_per_s, r.vs_first
            );
        }
        return Ok(());
    }
    let fam = backend.family(&family)?;
    let var = backend.variant(&family, &variant)?;
    let b = flops::forward_flops(&fam.dims, &var.cfg, batch, seq);
    println!("forward FLOPs for {family}/{variant} @ batch={batch} seq={seq}:");
    println!(
        "  attention core : {:>16}  ({:.1}% of total)",
        b.attn_core,
        100.0 * b.attn_fraction()
    );
    println!("  attention proj : {:>16}", b.attn_proj);
    println!("  mlp/moe        : {:>16}", b.mlp);
    println!("  lm head        : {:>16}", b.lm_head);
    println!("  total          : {:>16}", b.total());
    println!(
        "  train step     : {:>16}  (~3x fwd)",
        flops::train_flops(&fam.dims, &var.cfg, batch, seq)
    );
    println!(
        "  KV cache       : {:>16} bytes ({:.2} MiB)",
        flops::kv_cache_bytes(&fam.dims, &var.cfg, seq),
        flops::kv_cache_bytes(&fam.dims, &var.cfg, seq) as f64 / (1 << 20) as f64
    );
    println!(
        "  eq.(9) speedup : {:.2}x vs MHA",
        flops::theoretical_speedup(fam.dims.h_total, var.cfg.hq)
    );
    Ok(())
}

fn cmd_diagram(mut args: Args) -> Result<()> {
    let h_total = args.usize("h-total", 16)?;
    let variant = args.str_opt("variant");
    let (hq, hkv) = if let Some(v) = &variant {
        match v.as_str() {
            "mha" => (h_total, h_total),
            "gqa" => (h_total, (h_total / 4).max(1)),
            "mqa" => (h_total, 1),
            "sqa" => (h_total / 2, (h_total / 4).max(1)),
            "ssqa" => (h_total / 2, h_total / 2),
            "xsqa" => ((h_total / 4).max(1), (h_total / 4).max(1)),
            "xsmqa" => ((h_total / 4).max(1), 1),
            other => bail!("unknown variant {other:?}"),
        }
    } else {
        (args.usize("hq", 8)?, args.usize("hkv", 4)?)
    };
    args.finish()?;
    print!("{}", bench_harness::diagram(h_total, hq, hkv));
    Ok(())
}

fn cmd_inspect(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args);
    let family = args.str_opt("family");
    args.finish()?;
    let backend = open_backend(&dir)?;
    println!("backend: {}", backend.name());
    for (fname, fam) in backend.families() {
        if let Some(f) = &family {
            if f != fname {
                continue;
            }
        }
        println!(
            "family {fname}: d_model={} layers={} H={} d_head={} vocab={}{}",
            fam.dims.d_model,
            fam.dims.n_layers,
            fam.dims.h_total,
            fam.dims.d_head,
            fam.dims.vocab,
            if fam.dims.n_experts > 0 {
                format!(" experts={}", fam.dims.n_experts)
            } else {
                String::new()
            }
        );
        for (vname, v) in &fam.variants {
            let buckets = backend.fwd_buckets(fname, vname);
            let train = backend
                .train_shape(fname, vname)
                .map(|(b, s)| format!("{b}x{s}"))
                .unwrap_or_else(|_| "-".into());
            println!(
                "  {vname:6} Hq={:<2} Hkv={:<2} window={:<6} params={:<9} fwd={buckets:?} train={train}",
                v.cfg.hq,
                v.cfg.hkv,
                v.cfg
                    .window
                    .map(|w| w.to_string())
                    .unwrap_or_else(|| "-".into()),
                v.n_params
            );
        }
    }
    Ok(())
}
