//! Regenerates every table and figure of the paper's evaluation section.
//!
//! | paper artifact | entry point | notes |
//! |----------------|-------------|-------|
//! | Table 1 (dense quality)   | [`table1`] | trains all 7 variants on the synthetic corpus |
//! | Table 2 (MoE quality)     | [`table2`] | trains the 5 MoE variants on the story corpus |
//! | Table 3 (long-seq timing) | [`table3`] | fwd time/step across variants × seq buckets |
//! | §3.2.1 complexity         | [`complexity`] | analytic table from `flops/` |
//! | Figures 2–6 (head wiring) | [`diagram`] | ASCII rendering of the variant head graph |
//! | kernel-impl ablation      | [`ablation_impl`] | every attention lowering of the backend |
//! | naive-vs-tiled sweep      | [`kernel_table`] | raw attention kernels across seq lengths |
//!
//! Everything runs through the [`Backend`] trait, so the same harness
//! regenerates the tables on the native CPU path (default) or the PJRT
//! artifact path (`--features pjrt`). Numbers are CPU-scaled; every run
//! also prints the analytic prediction so the *shape* claim is directly
//! checkable.

use crate::config::{TrainConfig, VariantCfg};
use crate::flops;
use crate::runtime::Backend;
use crate::train::{TrainReport, Trainer};
use crate::util::bench::{markdown_table, Bench};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::sync::Arc;

pub const TABLE1_VARIANTS: &[&str] = &["mha", "gqa", "mqa", "sqa", "ssqa", "xsqa", "xsmqa"];
pub const TABLE2_VARIANTS: &[&str] = &["gqa", "mqa", "sqa", "ssqa", "xsqa"];
pub const TABLE3_VARIANTS: &[&str] = &["xsqa", "sqa", "ssqa", "swa", "mqa", "gqa", "mha"];

/// Train every Table-1 variant for `steps` and render the paper's columns.
pub fn table1(
    backend: &Arc<dyn Backend>,
    steps: usize,
    seed: u64,
) -> Result<(String, Vec<TrainReport>)> {
    quality_table(backend, "dense_sm", TABLE1_VARIANTS, steps, seed, 16)
}

/// Train every Table-2 (MoE) variant.
pub fn table2(
    backend: &Arc<dyn Backend>,
    steps: usize,
    seed: u64,
) -> Result<(String, Vec<TrainReport>)> {
    quality_table(backend, "moe_sm", TABLE2_VARIANTS, steps, seed, 8)
}

fn quality_table(
    backend: &Arc<dyn Backend>,
    family: &str,
    variants: &[&str],
    steps: usize,
    seed: u64,
    h_total: usize,
) -> Result<(String, Vec<TrainReport>)> {
    let mut reports = Vec::new();
    for &variant in variants {
        log::info!("=== {family}/{variant}: {steps} steps ===");
        let mut cfg = TrainConfig {
            family: family.into(),
            variant: variant.into(),
            steps,
            seed,
            eval_every: 0,
            eval_batches: 8,
            log_every: (steps / 5).max(1),
            ..TrainConfig::default()
        };
        cfg.schedule.base_lr = 1e-2; // tuned for the catalog's reference models
        cfg.schedule.total_steps = steps;
        cfg.schedule.warmup_steps = (steps / 10).max(1);
        let mut trainer = Trainer::new(backend, cfg)?;
        reports.push(trainer.run()?);
    }
    let header: Vec<String> = [
        "Model", "Hq", "Hkv", "Val. Loss", "Perplexity", "Accuracy (%)", "Time (min)",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let mut rows = Vec::new();
    for r in &reports {
        let entry = backend.variant(family, &r.variant)?;
        rows.push(vec![
            format!("{} ({}H)", r.variant.to_uppercase(), h_total),
            entry.cfg.hq.to_string(),
            entry.cfg.hkv.to_string(),
            format!("{:.4}", r.val_loss),
            format!("{:.4}", r.val_ppl),
            format!("{:.2}", r.val_acc * 100.0),
            format!("{:.2}", r.train_secs / 60.0),
        ]);
    }
    Ok((markdown_table(&header, &rows), reports))
}

/// One cell of Table 3.
#[derive(Debug, Clone)]
pub struct Table3Cell {
    pub variant: String,
    pub seq: usize,
    pub secs: f64,
    pub predicted_vs_mha: f64,
}

/// Forward time-per-step across variants × sequence buckets (Table 3).
///
/// `max_seq` caps the sweep (0 = everything compiled); `quick` shrinks reps.
pub fn table3(
    backend: &Arc<dyn Backend>,
    variants: &[&str],
    max_seq: usize,
    quick: bool,
) -> Result<(String, Vec<Table3Cell>)> {
    let family = "bench";
    let fam = backend.family(family)?.clone();
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let mha_var = VariantCfg {
        hq: fam.dims.h_total,
        hkv: fam.dims.h_total,
        window: None,
    };

    let mut cells = Vec::new();
    let mut seqs_seen: Vec<usize> = Vec::new();
    for &variant in variants {
        let entry = backend.variant(family, variant)?.clone();
        let seqs: Vec<usize> = backend
            .fwd_buckets(family, variant)
            .into_iter()
            .filter(|&s| max_seq == 0 || s <= max_seq)
            .collect();
        // Per-variant params (vector reused across seq buckets).
        let params = backend.init_params(family, variant, 3)?;
        for &seq in &seqs {
            if !seqs_seen.contains(&seq) {
                seqs_seen.push(seq);
            }
            let batch = backend.fwd_batch(family, variant, seq)?;
            let mut rng = Pcg64::new(1234);
            let tokens: Vec<i32> = (0..batch * seq)
                .map(|_| rng.below(fam.dims.vocab as u64) as i32)
                .collect();
            let r = bench.run(
                &format!("{family}/{variant}/s{seq}"),
                Some((batch * seq) as f64),
                || {
                    let out = backend
                        .forward(family, variant, &params, &tokens, batch, seq)
                        .unwrap();
                    // Force use: touch one element.
                    assert!(out[0].is_finite());
                },
            );
            let pred = flops::forward_flops(&fam.dims, &entry.cfg, 1, seq as u64).total() as f64
                / flops::forward_flops(&fam.dims, &mha_var, 1, seq as u64).total() as f64;
            cells.push(Table3Cell {
                variant: variant.to_string(),
                seq,
                secs: r.mean(),
                predicted_vs_mha: pred,
            });
        }
    }

    // Paper layout: rows = seq lengths, columns = variants.
    seqs_seen.sort_unstable();
    let mut header = vec!["Seq. Length".to_string()];
    header.extend(variants.iter().map(|v| v.to_string()));
    let mut rows = Vec::new();
    for &seq in &seqs_seen {
        let mut row = vec![seq.to_string()];
        for &v in variants {
            let cell = cells.iter().find(|c| c.variant == v && c.seq == seq);
            row.push(match cell {
                Some(c) => format!("{:.4}", c.secs),
                None => "-".to_string(),
            });
        }
        rows.push(row);
    }
    // Speed-up summary at the largest sequence (the paper's headline claim).
    if let Some(&top) = seqs_seen.last() {
        let mha = cells
            .iter()
            .find(|c| c.variant == "mha" && c.seq == top)
            .map(|c| c.secs);
        if let Some(mha) = mha {
            let mut row = vec![format!("speedup@{top}")];
            for &v in variants {
                let c = cells.iter().find(|c| c.variant == v && c.seq == top);
                row.push(match c {
                    Some(c) => format!("{:.2}x", mha / c.secs),
                    None => "-".into(),
                });
            }
            rows.push(row);
        }
    }
    Ok((markdown_table(&header, &rows), cells))
}

/// Attention-lowering ablation on the same (variant, seq) point: every
/// impl the backend exposes ("tiled" vs "naive" on native; "xla" vs
/// "pallas" under `--features pjrt`). The table exists to prove each
/// lowering runs end-to-end; numerics are compared in `rust/tests/`.
pub fn ablation_impl(backend: &Arc<dyn Backend>, seq: usize) -> Result<String> {
    let family = "bench";
    // The probe pass below doubles as the warmup iteration.
    let bench = Bench {
        warmup: 0,
        ..Bench::quick()
    };
    let vocab = backend.family(family)?.dims.vocab;
    let mut rows = Vec::new();
    for variant in ["mha", "sqa"] {
        let Ok(batch) = backend.fwd_batch(family, variant, seq) else {
            continue;
        };
        let params = backend.init_params(family, variant, 3)?;
        let mut rng = Pcg64::new(5);
        let tokens: Vec<i32> = (0..batch * seq)
            .map(|_| rng.below(vocab as u64) as i32)
            .collect();
        for impl_ in backend.impls() {
            // One probe: skip lowerings not compiled for this point, and
            // serve as the warmup run for the timing loop below.
            match backend.forward_impl(impl_, family, variant, &params, &tokens, batch, seq) {
                Ok(out) => assert!(out[0].is_finite()),
                Err(_) => continue,
            }
            let r = bench.run(&format!("{variant}/{impl_}/s{seq}"), None, || {
                let out = backend
                    .forward_impl(impl_, family, variant, &params, &tokens, batch, seq)
                    .unwrap();
                assert!(out[0].is_finite());
            });
            rows.push(vec![
                variant.to_string(),
                impl_.to_string(),
                format!("{:.4}", r.mean()),
            ]);
        }
    }
    Ok(markdown_table(
        &["Variant".into(), "Attention impl".into(), "Fwd secs".into()],
        &rows,
    ))
}

/// One (seq, kernel-pair) point of the naive-vs-tiled sweep.
#[derive(Debug, Clone)]
pub struct KernelCell {
    pub seq: usize,
    pub naive_secs: f64,
    pub tiled_secs: f64,
    /// naive_secs / tiled_secs (> 1 means tiled wins).
    pub speedup: f64,
}

/// Naive-vs-tiled wall-clock on the raw attention kernels across sequence
/// lengths (Table-3-style sweep at the attention level, no model around
/// it). This is the datapoint behind the "tiled must not lose at long S"
/// CI guard in `rust/benches/native_attention.rs`.
pub fn kernel_table(
    seqs: &[usize],
    hq: usize,
    hkv: usize,
    d_head: usize,
    causal: bool,
    quick: bool,
) -> Result<(String, Vec<KernelCell>)> {
    use crate::attention::{attention, attention_with, tensor::Tensor, Kernel, Spec};
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let spec = if causal {
        Spec::causal(hq, hkv)
    } else {
        Spec::full(hq, hkv)
    };
    let mut cells = Vec::new();
    for &seq in seqs {
        let mut rng = Pcg64::new(17);
        let mut randn = |shape: &[usize]| -> Result<Tensor> {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        };
        let q = randn(&[1, hq, seq, d_head])?;
        let k = randn(&[1, hkv, seq, d_head])?;
        let v = randn(&[1, hkv, seq, d_head])?;
        let naive = bench.run(&format!("naive/s{seq}"), Some(seq as f64), || {
            let out = attention(&q, &k, &v, spec).unwrap();
            assert!(out.data[0].is_finite());
        });
        let tiled = bench.run(&format!("tiled/s{seq}"), Some(seq as f64), || {
            let out = attention_with(&q, &k, &v, spec, Kernel::Tiled).unwrap();
            assert!(out.data[0].is_finite());
        });
        cells.push(KernelCell {
            seq,
            naive_secs: naive.mean(),
            tiled_secs: tiled.mean(),
            speedup: naive.mean() / tiled.mean(),
        });
    }
    let header: Vec<String> = ["Seq. Length", "naive (s)", "tiled (s)", "tiled speed-up"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.seq.to_string(),
                format!("{:.4}", c.naive_secs),
                format!("{:.4}", c.tiled_secs),
                format!("{:.2}x", c.speedup),
            ]
        })
        .collect();
    Ok((markdown_table(&header, &rows), cells))
}

/// One point of the end-to-end forward lowering sweep.
#[derive(Debug, Clone)]
pub struct ImplCell {
    /// `forward_impl` string (`"tiled"`, `"tiled+scalar"`, …).
    pub impl_name: String,
    pub seq: usize,
    pub secs: f64,
    pub tokens_per_s: f64,
}

/// End-to-end single-row forward wall-clock across `forward_impl`
/// lowerings on a catalog model — e.g. `"tiled"` (tiled kernel + blocked
/// GEMMs, the default) against `"tiled+scalar"` (the PR-2 scalar-loop
/// path). One row of tokens per seq bucket, shared across impls, so the
/// ratio isolates the compute substrate. This is the datapoint behind the
/// `BENCH_attention.json` perf trajectory written by
/// `rust/benches/native_attention.rs`.
pub fn forward_impl_table(
    backend: &Arc<dyn Backend>,
    family: &str,
    variant: &str,
    impls: &[&str],
    seqs: &[usize],
    bench: &Bench,
) -> Result<(String, Vec<ImplCell>)> {
    let vocab = backend.family(family)?.dims.vocab;
    let params = backend.init_params(family, variant, 3)?;
    let mut cells = Vec::new();
    for &seq in seqs {
        let batch = backend.fwd_batch(family, variant, seq)?;
        let mut rng = Pcg64::new(99);
        let tokens: Vec<i32> = (0..batch * seq)
            .map(|_| rng.below(vocab as u64) as i32)
            .collect();
        for &impl_ in impls {
            let r = bench.run(
                &format!("{family}/{variant}/{impl_}/s{seq}"),
                Some((batch * seq) as f64),
                || {
                    let out = backend
                        .forward_impl(impl_, family, variant, &params, &tokens, batch, seq)
                        .unwrap();
                    assert!(out[0].is_finite());
                },
            );
            cells.push(ImplCell {
                impl_name: impl_.to_string(),
                seq,
                secs: r.mean(),
                tokens_per_s: (batch * seq) as f64 / r.mean(),
            });
        }
    }
    // Rows = seq buckets; per-impl seconds plus the speed-up of the first
    // impl (the candidate) over the last (the baseline).
    let mut header = vec!["Seq. Length".to_string()];
    header.extend(impls.iter().map(|i| format!("{i} (s)")));
    if impls.len() >= 2 {
        header.push(format!("{} speed-up vs {}", impls[0], impls[impls.len() - 1]));
    }
    let mut rows = Vec::new();
    for &seq in seqs {
        let mut row = vec![seq.to_string()];
        for &impl_ in impls {
            let cell = cells.iter().find(|c| c.seq == seq && c.impl_name == impl_);
            row.push(match cell {
                Some(c) => format!("{:.4}", c.secs),
                None => "-".into(),
            });
        }
        if impls.len() >= 2 {
            let first = cells
                .iter()
                .find(|c| c.seq == seq && c.impl_name == impls[0]);
            let last = cells
                .iter()
                .find(|c| c.seq == seq && c.impl_name == impls[impls.len() - 1]);
            row.push(match (first, last) {
                (Some(f), Some(l)) => format!("{:.2}x", l.secs / f.secs),
                _ => "-".into(),
            });
        }
        rows.push(row);
    }
    Ok((markdown_table(&header, &rows), cells))
}

/// Serialize end-to-end lowering cells for `BENCH_attention.json`.
pub fn impl_cells_to_json(cells: &[ImplCell]) -> Json {
    Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("impl", Json::str(&c.impl_name)),
            ("seq", Json::num(c.seq as f64)),
            ("secs", Json::num(c.secs)),
            ("tokens_per_s", Json::num(c.tokens_per_s)),
        ])
    }))
}

/// Serialize kernel-sweep cells for the bench regression guard.
pub fn kernel_cells_to_json(cells: &[KernelCell]) -> Json {
    Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("seq", Json::num(c.seq as f64)),
            ("naive_secs", Json::num(c.naive_secs)),
            ("tiled_secs", Json::num(c.tiled_secs)),
            ("speedup", Json::num(c.speedup)),
        ])
    }))
}

/// §3.2.1: analytic complexity table for a family's variant zoo.
pub fn complexity(backend: &Arc<dyn Backend>, family: &str, seq: u64) -> Result<String> {
    let fam = backend.family(family)?;
    let variants: Vec<(String, VariantCfg)> = fam
        .variants
        .iter()
        .map(|(name, v)| (name.clone(), v.cfg))
        .collect();
    let rows = flops::complexity_table(&fam.dims, &variants, seq);
    let header: Vec<String> = [
        "Variant",
        "Hq",
        "Hkv",
        "Attn FLOPs vs MHA",
        "KV cache vs MHA",
        "Theoretical speed-up (eq. 9)",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.variant.clone(),
                r.hq.to_string(),
                r.hkv.to_string(),
                format!("{:.3}", r.attn_flops_factor),
                format!("{:.3}", r.kv_cache_factor),
                format!("{:.2}x", r.theoretical_speedup),
            ]
        })
        .collect();
    Ok(markdown_table(&header, &body))
}

/// Figures 2–6 stand-in: ASCII head-wiring diagram for a variant.
pub fn diagram(h_total: usize, hq: usize, hkv: usize) -> String {
    let group = hq / hkv.max(1);
    let mut out = String::new();
    out.push_str(&format!(
        "H (baseline) = {h_total}, Hq = {hq}, Hkv = {hkv}  |  attention FLOPs x{:.2}, KV cache x{:.2}\n\n",
        hq as f64 / h_total as f64,
        hkv as f64 / h_total as f64
    ));
    out.push_str("Q heads : ");
    for q in 0..hq {
        out.push_str(&format!("Q{q:<2} "));
    }
    out.push_str(&format!("   ({} of {} baseline heads)\n", hq, h_total));
    out.push_str("          ");
    for q in 0..hq {
        out.push_str(if q % group == group / 2 { " |  " } else { " .  " });
    }
    out.push('\n');
    out.push_str("KV heads: ");
    for k in 0..hkv {
        let w = 4 * group;
        let label = format!("KV{k}");
        out.push_str(&format!("{label:^w$}"));
    }
    out.push('\n');
    out
}

/// Serialize table-3 cells for EXPERIMENTS.md tooling.
pub fn cells_to_json(cells: &[Table3Cell]) -> Json {
    Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("variant", Json::str(&c.variant)),
            ("seq", Json::num(c.seq as f64)),
            ("secs", Json::num(c.secs)),
            ("predicted_vs_mha", Json::num(c.predicted_vs_mha)),
        ])
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagram_renders_all_variants() {
        for (hq, hkv) in [(16, 16), (16, 4), (16, 1), (8, 4), (8, 8), (4, 4), (4, 1)] {
            let d = diagram(16, hq, hkv);
            assert!(d.contains(&format!("Hq = {hq}")));
            assert!(d.lines().count() >= 4, "{d}");
        }
    }

    #[test]
    fn complexity_runs_on_the_native_catalog() {
        let backend: Arc<dyn Backend> = Arc::new(crate::runtime::NativeBackend::new());
        let md = complexity(&backend, "dense_sm", 32768).unwrap();
        assert!(md.contains("xsqa"));
        assert!(md.contains("0.250"), "{md}");
    }
}
