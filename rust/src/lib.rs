//! # SQA — Sparse Query Attention, a three-layer reproduction
//!
//! Reproduction of *"Sparse Query Attention (SQA): A Computationally
//! Efficient Attention Mechanism with Query Heads Reduction"* (Filipek,
//! 2025): query-head reduction cuts attention-core FLOPs by `H / Hq`
//! where KV-head sharing (MQA/GQA) only shrinks the KV cache.
//!
//! ## Backends
//!
//! Everything above the [`runtime::Backend`] trait — serving engine,
//! training loop, bench harness, CLI — is backend-agnostic:
//!
//! | build | backend | needs |
//! |-------|---------|-------|
//! | default | **native** — pure Rust on the in-crate attention oracle | nothing |
//! | `--features pjrt` | **pjrt** — AOT HLO artifacts via the PJRT C API | `make artifacts` + a real `xla` crate |
//!
//! The native backend is the reference implementation and what CI runs:
//! `cargo build --release && cargo test -q` exercises the full stack
//! (router → dynamic batcher → worker pool → forward; fused AdamW training;
//! table regeneration) with no Python, no XLA and no artifacts present.
//! The PJRT path type-checks offline against `rust/xla-stub` and comes
//! alive when a real `xla` crate is patched in (see `rust/README.md`).
//!
//! ## Modules
//!
//! * [`runtime`] — the [`runtime::Backend`] trait, the native backend +
//!   model catalog, checkpoints, and the feature-gated PJRT client.
//! * [`train`] — the training coordinator (the paper's compute-bound
//!   pre-training scenario): fused AdamW state, LR schedule, checkpoints.
//! * [`coordinator`] + [`server`] — the encoder-serving engine (the paper's
//!   prompt-processing scenario): length-bucket router, dynamic batcher,
//!   worker pool, backpressure, TCP front-end.
//! * [`data`] — deterministic synthetic corpora + tokenizer + batcher.
//! * [`attention`] — the pure-Rust attention oracle covering the whole
//!   variant zoo (MHA/GQA/MQA/SQA/sSQA/xSQA/xSMQA/SWA); the native
//!   backend's forward path is built on it.
//! * [`flops`] — the paper's §3.2.1 analytic complexity model.
//! * [`bench_harness`] — regenerates every table of the paper's evaluation.
//! * [`util`] — substrates the offline image lacks crates for: JSON,
//!   CLI parsing, RNG, thread pool, stats, property testing, bench timing.

// Numeric-kernel code is written as explicit index loops on flat buffers
// (mirroring the math it reproduces); silence the style lints that would
// force iterator rewrites of those kernels.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod attention;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod runtime;
pub mod server;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
