//! # SQA — Sparse Query Attention, a three-layer reproduction
//!
//! Reproduction of *"Sparse Query Attention (SQA): A Computationally
//! Efficient Attention Mechanism with Query Heads Reduction"* (Filipek,
//! 2025): query-head reduction cuts attention-core FLOPs by `H / Hq`
//! where KV-head sharing (MQA/GQA) only shrinks the KV cache.
//!
//! ## Backends
//!
//! Everything above the [`runtime::Backend`] trait — serving engine,
//! training loop, bench harness, CLI — is backend-agnostic:
//!
//! | build | backend | needs |
//! |-------|---------|-------|
//! | default | **native** — pure Rust on the in-crate attention oracle | nothing |
//! | `--features pjrt` | **pjrt** — AOT HLO artifacts via the PJRT C API | `make artifacts` + a real `xla` crate |
//!
//! The native backend is the reference implementation and what CI runs:
//! `cargo build --release && cargo test -q` exercises the full stack
//! (router → dynamic batcher → worker pool → forward; fused AdamW training;
//! table regeneration) with no Python, no XLA and no artifacts present.
//! The PJRT path type-checks offline against `rust/xla-stub` and comes
//! alive when a real `xla` crate is patched in (see `rust/README.md`).
//!
//! ## Attention kernels
//!
//! The native backend executes attention through one of two lowerings,
//! selected by [`attention::Kernel`] (`SQA_KERNEL=naive|tiled`, `serve
//! --kernel`, or the backend's `forward_impl`):
//!
//! * **naive** — the S×S-materializing oracle; simple by design, kept as
//!   the reference every differential suite compares against.
//! * **tiled** (default) — flash-style streaming kernel: fixed query/key
//!   tiles, online softmax, mask-aware key-tile skipping, parallelized
//!   across `(batch, head, query-tile)` on the [`util::threadpool`].
//!
//! The online softmax maintains, per query row, a running maximum `m`, a
//! running normalizer `l`, and an unnormalized output `o`; consuming a key
//! tile rescales the pair by `α = exp(m_old − m_new)` before accumulating
//! `exp(s − m_new)` terms. The test suites pin the invariants this
//! transformation must preserve: agreement with the oracle to 1e-4 across
//! the full spec grid including non-tile-aligned lengths
//! (`rust/tests/tiled_differential.rs`); probability rows summing to 1;
//! insensitivity to keys/values outside the visible window; visited key
//! tiles exactly matching [`attention::visible_range`]
//! (`rust/tests/properties.rs`); and totality — all-masked or
//! `-inf`-saturated rows yield zeros, never NaN, and large-magnitude
//! logits never overflow the accumulator (`attention::tiled` unit tests).
//!
//! ### Sparse mask patterns and the visibility seam
//!
//! On top of the causal/window flags, [`attention::Spec`] carries a
//! per-head [`attention::MaskPattern`] — `dense`, `window:W`, `strided:T`,
//! `dilated:W:T`, `sink:S:W`, a registered block [`attention::BlockBitmap`],
//! or a per-head table (`heads:N`) — parsed from the same grammar strings
//! the CLI (`--pattern`), the configs and the backend's
//! `kernel[+linalg][@pattern]` impl strings use. Effective visibility is
//! always the *conjunction* `causal ∧ window ∧ pattern`. Every kernel
//! consults one seam, [`attention::ResolvedMask`], and the suites pin its
//! invariants:
//!
//! * **one definition** — `ResolvedMask::visible(i, j)` is the per-element
//!   truth; the naive oracle applies it directly, and the tiled forward,
//!   the streaming backward and the decode path must agree with the
//!   oracle to 1e-4 for every pattern × geometry × length
//!   (`tiled_differential.rs`, `grad_differential.rs`,
//!   `decode_differential.rs` — so prefilled sessions can never drift
//!   from the stateless forward);
//! * **exact tile pruning** — `tile_visible` decides a whole
//!   `[q_tile × k_tile]` block from the diagonal interval it spans
//!   (`i − j` bands for window/strided/dilated, a sink rectangle union,
//!   block lookups for bitmaps), and `visited_key_tiles` must equal the
//!   per-element visible-tile set exactly — no tile skipped that holds a
//!   visible key, none touched that doesn't (`properties.rs`), with
//!   sub-dense counts at scale pinned as integers
//!   (`pattern_tiles` in `BENCH_attention.json`, the `--enforce-sparse`
//!   CI guard);
//! * **totality under patterns** — a row whose whole pattern row is masked
//!   streams to exactly-zero outputs, `lse = −inf`, and exactly-zero
//!   gradients, never NaN; pattern-invisible keys contribute neither to
//!   the running block max nor to dK/dV;
//! * **bitmap alignment** — block bitmaps must tile evenly
//!   (`block % tile == 0`, checked up front), and registry ids
//!   (`bitmap:N`, `heads:N`) must be registered before use — misuse is a
//!   validation error, not a silent dense fallback.
//!
//! ## Generation (prefill + incremental decode)
//!
//! The paper's second axis — memory-bound token-by-token decode governed
//! by the KV-head count (§2.2, §5) — runs as a real stateful path, not
//! just the `flops::decode` roofline:
//!
//! * [`attention::decode`] attends fresh query rows against cached K/V
//!   through the same tile streamer / linalg micro-GEMMs as the tiled
//!   kernel ([`attention::tiled`]'s `stream_qtile_at`);
//! * [`runtime::session::KvCache`] is the per-session, per-layer
//!   contiguous K/V append buffer, sized by the variant's `Hkv` — sSQA
//!   observably allocates and streams 2x a GQA/xSQA session's bytes —
//!   storing rows in f32, f16 or bf16 ([`runtime::KvDtype`]; `serve
//!   --kv-dtype` / `SQA_KV_DTYPE`): appends narrow with IEEE
//!   round-to-nearest-even, reads widen back to f32 for the math, and
//!   the half formats halve every byte account at a bounded narrowing
//!   error — a second, orthogonal lever on the same memory axis;
//! * [`runtime::session`]'s **paged allocator** (`serve --kv-block-len` /
//!   `SQA_KV_BLOCK_LEN`; [`runtime::PagedConfig`]) replaces the
//!   per-session slab with fixed-size blocks drawn from a global
//!   per-geometry [`runtime::KvPoolStats`]-instrumented pool: block
//!   tables allocate lazily, a token-chunk trie shares identical prompt
//!   prefixes across sessions (refcounted, copy-on-write on divergence —
//!   a trie hit skips the shared span's prefill compute), and idle
//!   sessions' exclusive blocks spill to disk under pool pressure (LRU),
//!   restoring transparently on their next step;
//! * [`runtime::Backend`] gains `prefill` (prompt → session + logits),
//!   `decode_step` (token → logits), `close_session` and `session_stats`;
//! * the [`coordinator`]'s generation scheduler admits sessions (cap +
//!   eviction on time since last progress), samples top-k tokens, and
//!   coalesces decode steps from many sessions into shared worker ticks
//!   (continuous batching) alongside encode batches. The scheduler is
//!   **event-driven**, never a sleep-loop: it blocks on its event channel
//!   and wakes only on (a) a new request, (b) a worker completion
//!   (prefill / prefill-extend / decode), (c) a stream consumer ack or
//!   cancel, (d) shutdown, or (e) the earliest *known* deadline —
//!   soonest session progress-timeout or batch-defer expiry — computed
//!   per iteration, so an idle engine burns no CPU and there is no fixed
//!   polling interval anywhere on the serving path;
//! * [`Engine::generate_stream`](coordinator::Engine::generate_stream)
//!   delivers each sampled token as it happens over a credit flow-controlled
//!   [`coordinator::TokenStream`] (at most `stream_buffer` tokens in
//!   flight; a stalled consumer pauses only its own session's decode, and
//!   a dropped stream cancels the generation and frees its KV session),
//!   token-for-token identical to blocking [`Engine::generate`](coordinator::Engine::generate)
//!   for the same prompt/params/seed; `serve --prefill-chunk` splits long
//!   prompt prefills into bounded chunks so they cannot starve running
//!   decodes or a short request's time-to-first-token on a busy worker;
//! * `sqa generate [--stream]` / the server's `{"cmd":"generate"}`
//!   endpoint (`"stream":true` for one frame per token — grammar in
//!   [`server`]) expose it end-to-end;
//!   `rust/benches/decode_throughput.rs` records tokens/s and measured
//!   KV bytes/step per variant (`BENCH_decode.json`), cross-checked
//!   against the roofline, and `rust/benches/latency_under_load.rs`
//!   records consumer-side TTFT / inter-token percentiles under
//!   concurrent streams plus the chunked-prefill starvation guard
//!   (`BENCH_latency.json`).
//!
//! The invariant suite is `rust/tests/decode_differential.rs`: N-step
//! incremental decode logits equal a full stateless re-forward to 1e-4
//! for every variant, both attention kernels and all three linalg impls;
//! the f16/bf16 caches track the f32 logits within the narrowing error
//! at exactly half the reported bytes. The paged allocator adds its own
//! contracts, pinned by the same suite plus `runtime::session`'s unit
//! tests: a paged session is *bitwise* identical to its contiguous twin
//! at every dtype and under every sparse pattern (the allocator changes
//! layout, never values); block refcounts never underflow and a block
//! referenced by more than one owner is never written in place — writes
//! to shared blocks copy first (COW), so an adopted prefix can never be
//! corrupted by its adopters; resident bytes are exactly
//! `blocks_in_use × block_bytes` at all times; and an evicted session's
//! spill/restore round-trip is byte-exact, so post-restore decode is
//! bitwise indistinguishable from a session that never left the pool.
//!
//! ## Compute kernels ([`linalg`])
//!
//! Underneath both attention lowerings sits a second, orthogonal switch:
//! [`linalg::Impl`] (`SQA_LINALG=blocked|scalar|simd`) selects the GEMM
//! substrate every dense product runs on — Q/K/V/O projections, the tiled
//! kernel's `[q_tile, k_tile]` score blocks and `probs @ V` accumulation,
//! the LM head, and the training backward's `xᵀ·dy` / `dy·wᵀ` reductions.
//! `blocked` (default) is a cache-blocked, register-tiled f32 GEMM
//! (`MR×NR` micro-kernel over packed, zero-padded A/B panels; `KC/MC/NC`
//! cache blocking; strided views cover every orientation and the
//! head-interleaved attention slabs) written so LLVM auto-vectorizes it;
//! `simd` reuses that packing/blocking verbatim but lowers the inner
//! `MR×NR` update through hand-written AVX2+FMA (x86-64) or NEON
//! (aarch64) intrinsics ([`linalg::simd`]) and vectorizes the tiled
//! kernel's dense online-softmax rows ([`util::simd`]), detecting CPU
//! features once at runtime and silently degrading to the portable
//! micro-kernel when they are absent — its scalar tails share the same
//! exp polynomial as the vector lanes, so results stay bitwise identical
//! across lane/tail splits and thread-pool sizes; `scalar` keeps the
//! element-at-a-time PR-2 loops as the differential oracle and perf
//! baseline. Large products optionally fan row blocks out over the
//! thread pool via `ThreadPool::run_borrowed` (scoped jobs that borrow
//! caller buffers — no `Arc` clones, no per-request copies of the
//! parameter vector), and pack buffers come from a per-worker
//! thread-local arena, so steady-state GEMMs allocate nothing. The
//! native backend composes the two switches in its `forward_impl`
//! strings: `"tiled"`, `"naive"`, `"tiled+scalar"`, `"naive+scalar"`,
//! `"tiled+simd"`, `"naive+simd"` — and
//! `rust/benches/native_attention.rs` records the
//! blocked-vs-scalar-vs-simd end-to-end trajectory in
//! `BENCH_attention.json`.
//!
//! ## Training backward ([`attention::backward`])
//!
//! The same kernel switch governs the *gradient* path of the fused train
//! step. Under `Kernel::Tiled` the backward is a flash-style streaming
//! replay: the forward tile streamer exports, per query row, the
//! logsumexp `L = m + ln(l)` of its scaled masked scores, and the
//! backward recomputes any probability block as `P = exp(scale·QKᵀ − L)`
//! — no second online-softmax search, no `[S, S]` buffer — then runs the
//! four per-tile products (`scale·QKᵀ`, `dP = dO Vᵀ`, `dQ += dS K`,
//! `dK += dSᵀ Q` / `dV += Pᵀ dO`) as `linalg` micro-GEMMs with mask-aware
//! key-tile skipping. Invariants the suites pin
//! (`rust/tests/grad_differential.rs`, `rust/tests/properties.rs`):
//!
//! * agreement with the scalar row-loop oracle
//!   ([`attention::backward::backward_naive_slabs`], the `Kernel::Naive`
//!   path) to 1e-4 across the full variant × mask × length × linalg grid,
//!   and with central-difference gradients of the actual loss on every
//!   parameter block;
//! * **LSE reuse**: the exported statistic equals the two-pass
//!   logsumexp, so forward and backward see the same probabilities;
//! * **poisoned-row semantics matching the forward**: rows the forward
//!   zeroed (empty normalizer or a `+inf` score) export `lse = −inf` and
//!   receive exactly zero attention gradients — zeros, never NaN;
//! * masked keys get *exactly* zero dK/dV (skipped tiles are untouched);
//! * **deterministic reduction**: `(head, query-tile)` jobs fan out in
//!   fixed waves merged in job order, so gradients are bitwise identical
//!   for any thread-pool size — training stays bit-reproducible.
//!
//! The train step checkpoints one contiguous activation slab per row
//! (layer inputs, projection slabs, per-row LSE) instead of per-layer
//! activation clones; `rust/benches/train_throughput.rs` records the
//! fwd/bwd split step time across the variant zoo and both backward
//! implementations (`BENCH_train.json`), with the `train-smoke` CI job
//! failing if the streaming backward ever loses to the scalar oracle at
//! S ≥ 4096 or if SQA's measured step stops beating MHA's.
//!
//! ## Concurrency & unsafety invariants
//!
//! The concurrent core is written against [`util::sync`], a thin shim
//! over `std::sync` that re-exports the mutexes, condvars, atomics and
//! `Arc` the runtime uses — and swaps them for
//! [loom](https://github.com/tokio-rs/loom)'s permutation-exploring
//! doubles under `--cfg loom`, so the thread-pool, latch and
//! session-table protocols are *model-checked* (`rust/tests/loom_models.rs`),
//! not just stress-tested. Two repo-wide policies are machine-enforced by
//! the in-tree linter (`cargo run -p xtask -- lint`, CI's required
//! `invariant-lint` job):
//!
//! * **Every `unsafe` carries a `// SAFETY:` contract.** The crate's
//!   unsafe surface is two seams: the concurrency seam (the
//!   lifetime-erased scoped jobs behind `ThreadPool::run_borrowed` and
//!   the `Send`/`Sync` impls for the pool's shared inner state) and the
//!   intrinsic seam (`#[target_feature]` kernels in [`linalg::simd`] /
//!   [`util::simd`], guarded by one-time runtime feature detection) —
//!   and each use states the invariant that makes it sound. The
//!   concurrency seam is additionally run under Miri
//!   (`cargo +nightly miri test --test unsafe_seams`) and nightly
//!   TSan/ASan CI sweeps; the intrinsic seam is pinned against its
//!   portable oracle by the differential suites.
//! * **Lock poisoning is a policy, not a crash.** The serving stack
//!   acquires locks through the poison-tolerant [`util::sync::lock`] /
//!   [`util::sync::wait`] helpers (a worker that panicked mid-batch has
//!   already failed its own job; the shared maps/counters it guarded
//!   remain structurally valid, and sibling sessions must not cascade).
//!   Bare `.lock().unwrap()` in the concurrent subsystems is a lint
//!   finding.
//!
//! Four more linted invariants keep the measurement story honest: the
//! [`attention`]/[`linalg`] kernels are clock-free (timing lives in the
//! benches and [`util::bench`], keeping kernels deterministic and
//! Miri/loom-runnable); every bench report goes through the schema'd
//! [`util::bench::write_bench_json`] writer so the committed
//! `BENCH_*.json` baselines stay diffable by `xtask bench-check`;
//! architecture intrinsics (`core::arch`, `#[target_feature]`, feature
//! detection) are confined to the two seams [`linalg::simd`] and
//! [`util::simd`] — everything else stays portable and Miri-runnable
//! (`simd-confinement`); and the paged-KV allocator's raw block state
//! (`PoolInner`, block data, the spill sentinel) never leaks outside
//! `runtime/session.rs` — every other layer goes through the
//! `PagedKvCache`/`BlockPool` API, so the refcount/COW invariants have
//! exactly one owner (`kv-block-confinement`).
//!
//! ## Modules
//!
//! * [`runtime`] — the [`runtime::Backend`] trait (stateless forward/train
//!   *and* stateful prefill/decode), the native backend + model catalog,
//!   per-session KV caches, checkpoints, and the feature-gated PJRT client.
//! * [`train`] — the training coordinator (the paper's compute-bound
//!   pre-training scenario): fused AdamW state, LR schedule, checkpoints.
//! * [`coordinator`] + [`server`] — the serving engine: length-bucket
//!   router + dynamic batcher for encode, session scheduler + continuous
//!   decode batching for generate, backpressure, per-phase metrics, TCP
//!   front-end on a bounded connection-handler pool.
//! * [`data`] — deterministic synthetic corpora + tokenizer + batcher.
//! * [`attention`] — both attention kernels (naive oracle + tiled
//!   streaming) covering the whole variant zoo
//!   (MHA/GQA/MQA/SQA/sSQA/xSQA/xSMQA/SWA); the native backend's forward
//!   path is built on them.
//! * [`linalg`] — blocked GEMM micro-kernels + scalar oracles behind the
//!   [`linalg::Impl`] switch; the compute substrate of everything above.
//! * [`flops`] — the paper's §3.2.1 analytic complexity model.
//! * [`bench_harness`] — regenerates every table of the paper's evaluation.
//! * [`util`] — substrates the offline image lacks crates for: JSON,
//!   CLI parsing, RNG, thread pool, stats, property testing, bench timing.

// Numeric-kernel code is written as explicit index loops on flat buffers
// (mirroring the math it reproduces); silence the style lints that would
// force iterator rewrites of those kernels.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod attention;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod linalg;
pub mod runtime;
pub mod server;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
